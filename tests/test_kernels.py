"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode).

Shapes deliberately include non-divisible sizes (padding paths) and both
dtypes; hypothesis drives random shape/config combos for matmul.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; collection must not die
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.attention import flash_attention_pallas
from repro.kernels.matmul import MATMUL_SPACE, matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.xent import softmax_xent_pallas


def _rand(rs, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rs.randn(*shape) * scale, dtype)


# ----------------------------------------------------------------- matmul
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (128, 128, 128, 64, 128, 128),
        (200, 300, 150, 64, 128, 128),   # non-divisible: padding path
        (8, 512, 128, 8, 128, 256),
        (256, 128, 512, 128, 256, 128),
    ],
)
def test_matmul_shapes(rs, m, k, n, bm, bn, bk):
    x, w = _rand(rs, (m, k)), _rand(rs, (k, n))
    out = matmul_pallas(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_bf16(rs):
    x = _rand(rs, (64, 128), jnp.bfloat16)
    w = _rand(rs, (128, 128), jnp.bfloat16)
    out = matmul_pallas(x, w, bm=64, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.matmul(x, w), np.float32),
        rtol=5e-2, atol=5e-2,
    )


@given(
    m=st.integers(1, 130), k=st.integers(1, 140), n=st.integers(1, 130),
    seed=st.integers(0, 100),
)
@settings(max_examples=12, deadline=None)
def test_matmul_property(m, k, n, seed):
    rs = np.random.RandomState(seed)
    x, w = _rand(rs, (m, k)), _rand(rs, (k, n))
    out = matmul_pallas(x, w, bm=64, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_space_vmem_constraint():
    for cfg in MATMUL_SPACE.enumerate():
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        assert bm * bk * 2 + bk * bn * 2 + bm * bn * 6 <= 64 * 1024 * 1024


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 32])
def test_flash_attention(rs, causal, window):
    b, h, kv, s, d = 2, 4, 2, 128, 32
    q = _rand(rs, (b, h, s, d), scale=0.3)
    k = _rand(rs, (b, kv, s, d), scale=0.3)
    v = _rand(rs, (b, kv, s, d))
    out = flash_attention_pallas(
        q, k, v, block_q=64, block_k=64, causal=causal, window=window, interpret=True
    )
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(32, 128), (128, 32), (128, 128)])
def test_flash_attention_blocks(rs, block_q, block_k):
    """Every valid tile must give identical math (variant equivalence)."""
    b, h, kv, s, d = 1, 2, 1, 128, 32
    q = _rand(rs, (b, h, s, d), scale=0.3)
    k = _rand(rs, (b, kv, s, d), scale=0.3)
    v = _rand(rs, (b, kv, s, d))
    out = flash_attention_pallas(
        q, k, v, block_q=block_q, block_k=block_k, causal=True, interpret=True
    )
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_shape(rs):
    b, h, kv, s, d = 2, 4, 4, 64, 16
    q = _rand(rs, (b, h, 1, d), scale=0.3)
    k = _rand(rs, (b, kv, s, d), scale=0.3)
    v = _rand(rs, (b, kv, s, d))
    out = flash_attention_pallas(q, k, v, block_q=64, block_k=64, causal=True, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("rows,d,br", [(64, 128, 16), (100, 256, 32), (7, 64, 8)])
def test_rmsnorm(rs, rows, d, br):
    x, w = _rand(rs, (rows, d)), _rand(rs, (d,))
    out = rmsnorm_pallas(x, w, block_rows=br, interpret=True)
    np.testing.assert_allclose(out, ref.rmsnorm(x, w), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ xent
@pytest.mark.parametrize("rows,v,br,bv", [(64, 512, 16, 128), (70, 1000, 16, 256)])
def test_xent(rs, rows, v, br, bv):
    logits = _rand(rs, (rows, v), scale=3.0)
    labels = jnp.asarray(rs.randint(0, v, rows), jnp.int32)
    out = softmax_xent_pallas(logits, labels, block_rows=br, block_v=bv, interpret=True)
    np.testing.assert_allclose(out, ref.softmax_xent(logits, labels), rtol=1e-4, atol=1e-4)


@given(rows=st.integers(1, 40), v=st.integers(2, 300), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_xent_property(rows, v, seed):
    rs = np.random.RandomState(seed)
    logits = _rand(rs, (rows, v), scale=2.0)
    labels = jnp.asarray(rs.randint(0, v, rows), jnp.int32)
    out = softmax_xent_pallas(logits, labels, block_rows=16, block_v=128, interpret=True)
    np.testing.assert_allclose(out, ref.softmax_xent(logits, labels), rtol=1e-4, atol=1e-4)

