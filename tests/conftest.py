import os

# Tests run on the real 1-device CPU; only the dry-run uses fake devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Isolate the tuning database per test session.
os.environ.setdefault("REPRO_TUNING_DB", "/tmp/repro_test_tuning.json")

import numpy as np
import pytest


@pytest.fixture
def rs():
    return np.random.RandomState(0)
