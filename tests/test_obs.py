"""The observability plane: metric primitives, collector scoping, spans,
exporters, CLI, hot-path integration, and telemetry snapshot merging."""
import json
import math
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.obs.collect import ObsCollector, current_collector
from repro.obs.metrics import Counter, Gauge, Histogram, percentile_row, tags_key
from repro.obs.trace import current_span, span, span_tree


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_histogram_quantiles_log_bucket_accuracy():
    h = Histogram()
    rs = np.random.RandomState(0)
    samples = rs.lognormal(mean=-7.0, sigma=1.0, size=5000)
    for v in samples:
        h.observe(v)
    for q in (0.50, 0.95, 0.99):
        exact = float(np.quantile(samples, q))
        approx = h.quantile(q)
        # 4 buckets/octave => bucket midpoint within ~9% of any member
        assert abs(approx - exact) / exact < 0.12, (q, approx, exact)
    assert h.count == 5000
    assert math.isclose(h.sum, float(samples.sum()), rel_tol=1e-9)


def test_histogram_small_sample_clamps_to_observed_range():
    h = Histogram()
    h.observe(3e-3)
    snap = h.snapshot()
    assert snap["p50"] == snap["p99"] == 3e-3   # clamped to min==max
    assert snap["count"] == 1


def test_histogram_zero_and_negative_share_underflow_bucket():
    h = Histogram()
    h.observe(0.0)
    h.observe(-1.0)
    assert h.count == 2
    assert h.min == -1.0 and h.max == 0.0
    # underflow midpoint is 0.0, already inside the observed range
    assert h.quantile(0.5) == 0.0


def test_histogram_merge_equals_union():
    a, b, u = Histogram(), Histogram(), Histogram()
    rs = np.random.RandomState(1)
    xs, ys = rs.rand(200) * 1e-3, rs.rand(300) * 1e-2
    for v in xs:
        a.observe(v)
        u.observe(v)
    for v in ys:
        b.observe(v)
        u.observe(v)
    a.merge(b)
    sa, su = a.snapshot(), u.snapshot()
    for field in ("count", "min", "max", "p50", "p95", "p99"):
        assert sa[field] == su[field], field
    assert math.isclose(sa["sum"], su["sum"])   # addition order differs


def test_empty_histogram_snapshot():
    assert Histogram().snapshot()["count"] == 0
    assert Histogram().quantile(0.5) == 0.0


def test_counter_gauge_and_tags_key():
    c, g = Counter(), Gauge()
    c.add()
    c.add(2.5)
    g.set(4)
    g.set(7)
    assert c.snapshot() == {"value": 3.5}
    assert g.snapshot() == {"value": 7.0, "updates": 2}
    assert tags_key({"b": 2, "a": "x"}) == (("a", "x"), ("b", "2"))


# ---------------------------------------------------------------------------
# collector: scoping, sampling, warnings, events
# ---------------------------------------------------------------------------

def test_default_collector_disabled_and_records_nothing():
    col = current_collector()
    assert not col.enabled
    obs.counter("t.never")
    obs.observe("t.never_h", 1.0)
    with obs.collect(name="t") as inner:
        obs.counter("t.yes")
    assert "t.never" not in inner.snapshot()["counters"]
    assert inner.snapshot()["counters"]["t.yes"][0]["value"] == 1


def test_nested_scopes_innermost_wins():
    with obs.collect(name="outer") as outer:
        with obs.collect(name="inner") as inner:
            assert current_collector() is inner
            obs.counter("c")
        assert current_collector() is outer
        obs.counter("c")
    assert inner.snapshot()["counters"]["c"][0]["value"] == 1
    assert outer.snapshot()["counters"]["c"][0]["value"] == 1


def test_thread_isolation():
    seen = {}

    def worker():
        # fresh thread: falls back to the (disabled) process default
        seen["col"] = current_collector()

    with obs.collect(name="main-scope"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert not seen["col"].enabled
    assert seen["col"].name == "default"


def test_tagged_rows_are_separate():
    with obs.collect(name="t") as col:
        col.counter("calls", kernel="matmul", tier="exact")
        col.counter("calls", kernel="matmul", tier="exact")
        col.counter("calls", kernel="rmsnorm", tier="cover")
    rows = col.snapshot()["counters"]["calls"]
    by_tags = {tuple(sorted(r["tags"].items())): r["value"] for r in rows}
    assert by_tags[(("kernel", "matmul"), ("tier", "exact"))] == 2
    assert by_tags[(("kernel", "rmsnorm"), ("tier", "cover"))] == 1


def test_sampling_deterministic_one_in_n():
    col = ObsCollector(name="s", sample_rate=0.25)
    hits = sum(col.sample() for _ in range(100))
    assert hits == 25
    always = ObsCollector(name="s1", sample_rate=1.0)
    assert all(always.sample() for _ in range(10))
    never = ObsCollector(name="s0", sample_rate=0.0)
    assert not any(never.sample() for _ in range(10))


def test_warn_once_dedup_and_fires_when_disabled():
    col = ObsCollector(name="w", enabled=False)
    assert col.warn_once("hazard", key="k1", detail="d") is True
    assert col.warn_once("hazard", key="k1") is False      # deduped
    assert col.warn_once("hazard", key="k2") is True       # distinct key
    warnings = col.events(kind="warning")
    assert len(warnings) == 2
    assert warnings[0]["key"] == "k1" and warnings[0]["detail"] == "d"
    # disabled collector still surfaces the hazard in its snapshot
    assert len(col.snapshot()["warnings"]) == 2


def test_event_ring_buffer_bounded():
    col = ObsCollector(name="rb", max_events=16)
    for i in range(100):
        col.event("e", i=i)
    evs = col.events()
    assert len(evs) == 16
    assert [e["i"] for e in evs] == list(range(84, 100))


def test_bad_event_kind_rejected():
    with pytest.raises(ValueError):
        ObsCollector(name="x").event("e", kind="bogus")


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_disabled_yields_none_and_records_nothing():
    with span("s") as sp:
        assert sp is None
    assert current_span() is None


def test_span_tree_and_histogram():
    with obs.collect(name="t") as col:
        with span("outer", step=3) as sp:
            assert current_span() is sp
            with span("inner") as child:
                assert child.parent_id == sp.span_id
            sp.set(extra="field")
        assert current_span() is None
    snap = col.snapshot()
    # histograms carry NO per-call tags (cardinality protection)...
    assert snap["histograms"]["span.outer"][0]["tags"] == {}
    assert snap["histograms"]["span.inner"][0]["count"] == 1
    # ...the tags live on the span events
    spans = {e["name"]: e for e in col.events(kind="span")}
    assert spans["outer"]["step"] == 3
    assert spans["outer"]["extra"] == "field"
    tree = span_tree(col.events())
    assert [e["name"] for e in tree[None]] == ["outer"]
    assert [e["name"] for e in tree[spans["outer"]["span_id"]]] == ["inner"]


def test_span_xla_annotations_do_not_crash():
    with obs.collect(name="t", xla_annotations=True) as col:
        with span("annotated"):
            pass
    assert col.snapshot()["histograms"]["span.annotated"][0]["count"] == 1


# ---------------------------------------------------------------------------
# export: snapshot round-trip, jsonl, prom, diff, percentile_row
# ---------------------------------------------------------------------------

def _sample_snapshot(scale=1.0):
    with obs.collect(name="exp") as col:
        col.counter("reqs", 3, route="a")
        col.gauge("depth", 7)
        for v in (1e-3, 2e-3, 4e-3):
            col.observe("lat_s", v * scale)
        col.event("boot", phase="init")
    return col


def test_snapshot_write_load_roundtrip(tmp_path):
    from repro.obs.export import load_snapshot, write_snapshot

    col = _sample_snapshot()
    p = str(tmp_path / "m.json")
    write_snapshot(col.snapshot(), p)
    snap = load_snapshot(p)
    assert snap["counters"]["reqs"][0] == {"tags": {"route": "a"}, "value": 3}
    assert snap["gauges"]["depth"][0]["value"] == 7
    assert snap["histograms"]["lat_s"][0]["count"] == 3


def test_load_snapshot_missing_path_exits():
    from repro.obs.export import load_snapshot

    with pytest.raises(SystemExit):
        load_snapshot("/nonexistent/metrics.json")


def test_jsonl_sink_appends(tmp_path):
    from repro.obs.export import read_jsonl, write_jsonl

    p = str(tmp_path / "events.jsonl")
    col = _sample_snapshot()
    write_jsonl(col.events(), p)
    write_jsonl([{"kind": "event", "name": "later"}], p)
    evs = read_jsonl(p)
    assert evs[-1]["name"] == "later"
    assert any(e["name"] == "boot" for e in evs)


def test_prom_textfile(tmp_path):
    p = str(tmp_path / "metrics.prom")
    _sample_snapshot().write_prom(p)
    text = open(p).read()
    assert '# TYPE repro_reqs counter' in text
    assert 'repro_reqs{route="a"} 3' in text
    assert 'repro_depth 7' in text
    assert 'repro_lat_s{quantile="0.95"}' in text
    assert 'repro_lat_s_count 3' in text


def test_diff_snapshots_names_the_shift():
    from repro.obs.export import diff_snapshots, format_diff

    a = _sample_snapshot().snapshot()
    b = _sample_snapshot(scale=10.0).snapshot()
    d = diff_snapshots(a, b)
    row = d["histograms"]["lat_s"][0]
    assert row["p50"]["ratio"] > 5
    assert "lat_s" in format_diff(d)
    assert "(no differences)" in format_diff(diff_snapshots(a, a))


def test_percentile_row_lookup():
    snap = _sample_snapshot().snapshot()
    row = percentile_row(snap, "lat_s")
    assert row["count"] == 3
    assert percentile_row(snap, "nope") is None
    assert percentile_row(snap, "reqs") is None          # not a histogram
    tagged = percentile_row(snap, "lat_s", tags={"missing": "t"})
    assert tagged is None


# ---------------------------------------------------------------------------
# CLI: report / diff
# ---------------------------------------------------------------------------

def test_cli_report_and_diff(tmp_path, capsys):
    from repro.obs.cli import main

    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    _sample_snapshot().write(a)
    _sample_snapshot(scale=10.0).write(b)
    assert main(["report", "--metrics", a, "--events", "5"]) == 0
    out = capsys.readouterr().out
    assert "obs snapshot [exp]" in out and "lat_s" in out
    assert main(["diff", a, b]) == 0
    assert "lat_s" in capsys.readouterr().out
    assert main(["report"]) == 2                         # needs an input
    assert main(["report", "--drift"]) == 2              # --drift needs --db


# ---------------------------------------------------------------------------
# hot-path integration: dispatch resolution + trainer-style phases
# ---------------------------------------------------------------------------

def test_runtime_resolve_records_metrics():
    import jax.numpy as jnp

    from repro.core.runtime import TunedRuntime
    from repro.kernels.matmul import matmul as matmul_tunable

    rt = TunedRuntime(mode="kernel", name="obs-test")
    x = jnp.zeros((32, 16), jnp.float32)
    w = jnp.zeros((16, 8), jnp.float32)
    with obs.collect(name="t") as col:
        rt.resolve(matmul_tunable, (x, w))
        rt.resolve(matmul_tunable, (x, w))               # cache hit
    snap = col.snapshot()
    rows = snap["histograms"]["dispatch.resolve_s"]
    cached = {r["tags"]["cached"] for r in rows}
    assert cached == {"hit", "miss"}
    calls = snap["counters"]["dispatch.calls"]
    assert all(r["tags"]["kernel"] == "matmul" for r in calls)
    assert sum(r["value"] for r in calls) == 2


def test_dispatch_runs_inside_span():
    import jax.numpy as jnp

    from repro.core.runtime import TunedRuntime
    from repro.kernels.matmul import matmul as matmul_tunable

    rt = TunedRuntime(mode="reference", name="obs-test")
    x = jnp.ones((8, 4), jnp.float32)
    w = jnp.ones((4, 4), jnp.float32)
    with obs.collect(name="t") as col, rt:
        rt.dispatch(matmul_tunable, x, w)
    spans = col.events(kind="span")
    assert [e["name"] for e in spans] == ["dispatch"]
    assert spans[0]["kernel"] == "matmul"
    assert spans[0]["phase"] == "fwd"


def test_dp_approx_key_warns_once():
    import jax.numpy as jnp

    from repro.core.runtime import TunedRuntime
    from repro.distributed import sharding as shd
    from repro.kernels.matmul import matmul as matmul_tunable
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    layout = shd.Layout()
    rt = TunedRuntime(mode="kernel", name="dp-approx-test")
    x = jnp.zeros((8, 4), jnp.float32)
    w = jnp.zeros((4, 4), jnp.float32)
    with obs.collect(name="t") as col:
        with shd.mesh_context(mesh, layout, dp_degree=1, dp_approx=True):
            rt.resolve(matmul_tunable, (x, w))
            rt.resolve(matmul_tunable, (x, w))
        # same key outside the approx scope: no new warning
        with shd.mesh_context(mesh, layout, dp_degree=1):
            rt.resolve(matmul_tunable, (x, w))
    warnings = col.events(kind="warning")
    assert len(warnings) == 1
    w0 = warnings[0]
    assert w0["name"] == "dispatch.local_key_approx"
    assert w0["key"].startswith("matmul|")               # includes the key


# ---------------------------------------------------------------------------
# telemetry snapshot merging across resumed campaign runs (satellite)
# ---------------------------------------------------------------------------

def _telemetry_snap(calls, tiers, phases, by_key, by_key_phase, hits=0):
    return {
        "calls": calls, "cache_hits": hits, "cache_evictions": 0,
        "cache_hit_rate": hits / calls if calls else 0.0,
        "tiers": tiers, "tier_rates": {t: n / calls for t, n in tiers.items()},
        "by_key": by_key, "phases": phases, "by_key_phase": by_key_phase,
    }


def test_merge_snapshots_accumulates_all_sections():
    from repro.campaign.runner import _merge_snapshots

    a = _telemetry_snap(
        4, {"exact": 3, "heuristic": 1},
        phases={"fwd": {"exact": 3}, "bwd": {"heuristic": 1}},
        by_key={"matmul|k1": {"exact": 3}, "rmsnorm|k2": {"heuristic": 1}},
        by_key_phase={"fwd": {"matmul|k1": {"exact": 3}},
                      "bwd": {"rmsnorm|k2": {"heuristic": 1}}},
        hits=2,
    )
    b = _telemetry_snap(
        6, {"exact": 2, "cover": 4},
        phases={"fwd": {"exact": 2, "cover": 1}, "opt": {"cover": 3}},
        by_key={"matmul|k1": {"exact": 2}, "xent|k3": {"cover": 4}},
        by_key_phase={"fwd": {"matmul|k1": {"exact": 2, "cover": 1}},
                      "opt": {"xent|k3": {"cover": 3}}},
        hits=1,
    )
    m = _merge_snapshots(a, b)
    assert m["calls"] == 10
    assert m["cache_hits"] == 3 and m["cache_hit_rate"] == 0.3
    assert m["tiers"] == {"exact": 5, "heuristic": 1, "cover": 4}
    assert m["tier_rates"]["exact"] == 0.5
    # phases: shared phase accumulates, disjoint phases survive
    assert m["phases"]["fwd"] == {"exact": 5, "cover": 1}
    assert m["phases"]["bwd"] == {"heuristic": 1}
    assert m["phases"]["opt"] == {"cover": 3}
    # by_key / by_key_phase: per-key tier counts add
    assert m["by_key"]["matmul|k1"] == {"exact": 5}
    assert m["by_key_phase"]["fwd"]["matmul|k1"] == {"exact": 5, "cover": 1}
    assert m["by_key_phase"]["bwd"]["rmsnorm|k2"] == {"heuristic": 1}
    assert m["by_key_phase"]["opt"]["xent|k3"] == {"cover": 3}


def test_merge_snapshots_none_prev_is_identity():
    from repro.campaign.runner import _merge_snapshots

    b = _telemetry_snap(2, {"exact": 2}, phases={"fwd": {"exact": 2}},
                        by_key={}, by_key_phase={})
    assert _merge_snapshots(None, b) is b
    assert _merge_snapshots({}, b) is b


def test_merge_snapshots_live_roundtrip():
    """Two real Telemetry snapshots merge to the union accounting —
    the resumed-campaign path in run_campaign."""
    from repro.campaign.runner import _merge_snapshots
    from repro.core.runtime import Telemetry, dispatch_phase

    t1, t2 = Telemetry(), Telemetry()
    t1.record("matmul", "matmul|a", "exact")
    with dispatch_phase("bwd"):
        t1.record("matmul", "matmul|a", "cover")
        t2.record("rmsnorm", "rmsnorm|b", "exact")
    t2.record("matmul", "matmul|a", "exact", cached=True)
    m = _merge_snapshots(t1.snapshot(), t2.snapshot())
    assert m["calls"] == 4
    assert m["phases"]["fwd"] == {"exact": 2}
    assert m["phases"]["bwd"] == {"cover": 1, "exact": 1}
    assert m["by_key_phase"]["fwd"]["matmul|a"] == {"exact": 2}
    assert m["by_key_phase"]["bwd"]["rmsnorm|b"] == {"exact": 1}


def test_run_campaign_merges_resumed_telemetry(tmp_path):
    """A resumed campaign accumulates the banked manifest telemetry instead
    of overwriting it (the `_merge_snapshots` call inside run_campaign)."""
    from repro.campaign import planner, runner, scheduler
    from repro.core.database import TuningDatabase
    from repro.core.evaluate import WallClockEvaluator
    from repro.core.runtime import Telemetry, dispatch_phase

    jobs = planner.plan_jobs(
        ["qwen2_0_5b"], train_shapes=[], serving=(2, 32),
        kernels=("rmsnorm",), reduced=True,
    )
    manifest = scheduler.build_manifest(
        jobs, total_budget=4, path=str(tmp_path / "m.json"),
        min_budget=2, max_budget=2,
    )
    assert manifest.jobs
    # bank a prior invocation's accounting the way run_campaign would
    prior = Telemetry()
    prior.record("matmul", "matmul|a", "exact")
    with dispatch_phase("bwd"):
        prior.record("matmul", "matmul|a", "cover")
    manifest.meta["telemetry"] = prior.snapshot()
    db = TuningDatabase(None)
    ev = WallClockEvaluator(repeats=1, warmup=0)
    runner.run_campaign(manifest, db, evaluator=ev, max_jobs=1)
    merged = manifest.meta["telemetry"]
    # the prior run's counts survived the resume (merge, not overwrite)
    assert merged["calls"] >= 2
    assert merged["by_key"]["matmul|a"] == {"exact": 1, "cover": 1}
    assert merged["phases"]["bwd"] == {"cover": 1}
    assert merged["by_key_phase"]["fwd"]["matmul|a"] == {"exact": 1}
    # ...and the persisted manifest round-trips it
    reloaded = scheduler.CampaignManifest.load(str(tmp_path / "m.json"))
    assert reloaded.meta["telemetry"]["by_key"]["matmul|a"] == {
        "exact": 1, "cover": 1}


# ---------------------------------------------------------------------------
# serving percentiles (satellite): engine histograms feed the stats report
# ---------------------------------------------------------------------------

def test_serving_engine_records_latency_histograms():
    import dataclasses

    import jax

    from repro.configs.base import SHAPES, get_config
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_config("qwen2_0_5b").reduced()
    run = defaults.default_run(cfg, SHAPES["decode_32k"])
    run = dataclasses.replace(run, q_chunk=32, k_chunk=64, loss_chunk=32)
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(
        cfg, run, params, make_host_mesh(), defaults.default_layout(cfg),
        EngineConfig(max_batch=2, max_seq=64),
    )
    rs = np.random.RandomState(0)
    with obs.collect(name="serve-test") as col:
        for i in range(3):
            engine.submit(Request(
                prompt=rs.randint(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4, temperature=0.0, seed=i,
            ))
        done = engine.serve()
    assert len(done) == 3
    snap = col.snapshot()
    adm = percentile_row(snap, "serve.admission_s")
    tok = percentile_row(snap, "serve.per_token_s")
    lat = percentile_row(snap, "serve.latency_s")
    assert adm["count"] == 3 and lat["count"] == 3 and tok["count"] == 3
    assert 0 < lat["p50"] and lat["p50"] <= lat["p99"]
    reqs = snap["counters"]["serve.requests"][0]["value"]
    assert reqs == 3
    assert snap["counters"]["serve.tokens"][0]["value"] == sum(
        len(r.output) for r in done
    )
