"""BackgroundTune: dynamic tuning under live traffic, without blocking it.

The acceptance story (ISSUE 10 / ROADMAP item 2): a runtime with a cold
database serving simulated traffic through :func:`background_policy` must
converge to 100%% ExactHit — with ZERO ``tune``-tier resolutions (nothing
tunes on the request path) and resolve latency bounded even while the
worker is busy. Failure drills ride along: a crashed worker demotes the
tier to plain heuristic serving, a full queue sheds (and later re-offers),
a job that exhausts retries is parked, and a torn database file degrades
to a cold start instead of an unhandled exception.
"""
import json
import os
import time

import jax.numpy as jnp
import pytest

import repro.obs as obs
from repro.core import (
    BackgroundTuner,
    Record,
    TunedRuntime,
    TuningDatabase,
    background_policy,
    make_key,
)
from repro.core.platform import detect_platform
from repro.testing import FaultPlan, FaultRule


def _mat_args(m=64):
    return jnp.ones((m, 128), jnp.float32), jnp.ones((128, 64), jnp.float32)


def _rms_args():
    return jnp.ones((64, 32), jnp.float32), jnp.ones((32,), jnp.float32)


def _traffic(rt):
    """One simulated request batch: two kernels, one bucket each."""
    x, w = _mat_args()
    a, g = _rms_args()
    return rt.dispatch("matmul", x, w), rt.dispatch("rmsnorm", a, g)


# ---------------------------------------------------------------------------
# The convergence gate
# ---------------------------------------------------------------------------


def test_cold_db_converges_to_exact_without_inline_tuning(tmp_path):
    db = TuningDatabase(None)
    delta_path = str(tmp_path / "bgtune_delta.json")
    tuner = BackgroundTuner(budget=3, export_path=delta_path, backoff_s=0.01)
    col = obs.collect(name="bgtune-e2e")
    try:
        with col, TunedRuntime(
            db=db, mode="kernel", policy=background_policy(tuner)
        ) as rt:
            # Cold start: both buckets answer immediately at tier "bgtune"
            # (heuristic config, uncached) while jobs queue up behind them.
            _traffic(rt)
            t = rt.telemetry.snapshot()["tiers"]
            assert t.get("bgtune") == 2 and "tune" not in t

            assert tuner.drain(timeout=180), f"tuner did not drain: {tuner!r}"
            assert tuner.promotions == 2 and tuner.failures == 0

            # Hot swap: same traffic now resolves ExactHit (uncached miss,
            # because bgtune resolutions were never cached)...
            out_m, out_r = _traffic(rt)
            t = rt.telemetry.snapshot()["tiers"]
            assert t.get("exact") == 2, t
            # ...and the round after that is served from the resolve cache.
            _traffic(rt)
            snap = rt.telemetry.snapshot()
            assert snap["cache_hits"] == 2
            assert "tune" not in snap["tiers"], "tuning ran on the request path"

            # Promoted configs are numerically sound.
            x, w = _mat_args()
            assert jnp.allclose(out_m, x @ w, rtol=1e-4, atol=1e-4)
    finally:
        tuner.stop()

    # The promoted records landed under the request keys themselves.
    for rec in tuner._promoted:
        assert db.lookup(rec.key) is not None
        assert rec.meta["source"] == "bgtune"

    # Delta export: a standalone database of exactly the promoted records,
    # loadable as-is (the fleet-shipping artifact).
    assert os.path.exists(delta_path)
    delta = TuningDatabase(delta_path)
    for rec in tuner._promoted:
        assert delta.lookup(rec.key) is not None

    # Satellite: the bgtune metric names surface through the obs plane.
    snap = col.snapshot()
    assert "bgtune.promotions" in snap["counters"]
    assert "bgtune.queue_depth" in snap["gauges"]
    assert "bgtune.promote_latency_s" in snap["histograms"]
    prom_path = str(tmp_path / "bgtune.prom")
    col.write_prom(prom_path)
    with open(prom_path) as f:
        text = f.read()
    for name in ("bgtune_promotions", "bgtune_queue_depth",
                 "bgtune_promote_latency_s"):
        assert name in text, f"{name} missing from Prometheus export"


def test_resolve_never_blocks_on_a_busy_worker_and_parks_failures():
    """While the worker grinds (here: failing with backoff), request-path
    resolves of the pending bucket stay at cache-lookup speed; once the job
    exhausts its attempts the bucket parks on the heuristic config forever
    (no re-queue spin)."""
    db = TuningDatabase(None)
    tuner = BackgroundTuner(max_attempts=3, backoff_s=0.2)
    plan = FaultPlan([FaultRule(site="bgtune.worker:matmul", kind="error")])
    plan.install()
    col = obs.collect(name="bgtune-park")
    try:
        with col, TunedRuntime(
            db=db, mode="kernel", policy=background_policy(tuner)
        ) as rt:
            x, w = _mat_args()
            assert rt.resolve("matmul", (x, w)).tier == "bgtune"
            # Worker is now inside its ~0.6s retry/backoff loop. The resolve
            # path must not feel it: each re-resolve is a dedup'd offer plus
            # a heuristic config — microseconds, bounded here at 50ms.
            lat = []
            for _ in range(50):
                t0 = time.perf_counter()
                res = rt.resolve("matmul", (x, w))
                lat.append(time.perf_counter() - t0)
                assert res.tier == "bgtune" and res.cache is False
            assert max(lat) < 0.05, f"resolve blocked: max {max(lat):.3f}s"

            assert tuner.drain(timeout=30)
            assert tuner.failures == 1 and tuner.promotions == 0
            assert plan.count("bgtune.worker:matmul", kind="error") == 3

            # Parked: still tier "bgtune" (key stays claimed, no new job),
            # worker still alive and accepting other buckets.
            assert rt.resolve("matmul", (x, w)).tier == "bgtune"
            assert tuner.snapshot()["inflight"] == 0
            assert tuner.accepting
        warns = [e for e in col.events("warning") if e["name"] == "bgtune.job_failed"]
        assert len(warns) == 1 and "InjectedFault" in warns[0]["error"]
    finally:
        plan.uninstall()
        tuner.stop()


# ---------------------------------------------------------------------------
# Failure drills
# ---------------------------------------------------------------------------


def test_worker_crash_demotes_to_heuristic_serving():
    db = TuningDatabase(None)
    tuner = BackgroundTuner()
    # InjectedWorkerCrash is a BaseException: it escapes the per-job retry
    # loop and kills the worker thread — the crash-isolation drill.
    plan = FaultPlan([FaultRule(site="bgtune.worker:*", kind="crash")])
    plan.install()
    col = obs.collect(name="bgtune-crash")
    try:
        with col, TunedRuntime(
            db=db, mode="kernel", policy=background_policy(tuner)
        ) as rt:
            x, w = _mat_args()
            assert rt.resolve("matmul", (x, w)).tier == "bgtune"
            assert not tuner.drain(timeout=30), "drain should report the death"
            assert not tuner.accepting
            assert "InjectedWorkerCrash" in tuner.snapshot()["death"]

            # A NEW bucket demotes past the dead tier to plain Heuristic —
            # and that resolution caches, so serving stays on the fast path.
            a, g = _rms_args()
            assert rt.resolve("rmsnorm", (a, g)).tier == "heuristic"
            assert rt.resolve("rmsnorm", (a, g)).tier == "heuristic"
            assert rt.telemetry.snapshot()["cache_hits"] == 1
        assert any(
            e["name"] == "bgtune.worker_dead" for e in col.events("warning")
        )
    finally:
        plan.uninstall()
        tuner.stop()


def test_full_queue_sheds_then_reoffers():
    db = TuningDatabase(None)
    # Hold the worker busy on the first job (3 failing attempts x 0.25s
    # backoff) with a single queue slot behind it.
    tuner = BackgroundTuner(max_queue=1, max_attempts=3, backoff_s=0.25)
    plan = FaultPlan([FaultRule(site="bgtune.worker:*", kind="error")])
    plan.install()
    col = obs.collect(name="bgtune-shed")
    try:
        with col, TunedRuntime(
            db=db, mode="kernel", policy=background_policy(tuner)
        ) as rt:
            assert rt.resolve("matmul", _mat_args()).tier == "bgtune"
            deadline = time.monotonic() + 5
            while tuner.snapshot()["queue_depth"] > 0:  # worker picked it up
                assert time.monotonic() < deadline
                time.sleep(0.005)
            assert rt.resolve("rmsnorm", _rms_args()).tier == "bgtune"  # queued
            # Third distinct bucket: queue is full — shed, but the caller
            # still gets the bgtune answer (uncached), never an error.
            res = rt.resolve("matmul", _mat_args(m=256))
            assert res.tier == "bgtune" and res.cache is False
            assert tuner.shed == 1

            assert tuner.drain(timeout=30)
            # The shed key was released: re-resolving re-offers it.
            assert rt.resolve("matmul", _mat_args(m=256)).tier == "bgtune"
            assert tuner.snapshot()["inflight"] == 1
            assert tuner.drain(timeout=30)
        snap = col.snapshot()
        assert "bgtune.shed" in snap["counters"]
    finally:
        plan.uninstall()
        tuner.stop()


# ---------------------------------------------------------------------------
# Database robustness (satellite: torn reads degrade, not crash)
# ---------------------------------------------------------------------------


def test_torn_db_file_degrades_to_cold_start(tmp_path):
    path = str(tmp_path / "torn.json")
    with open(path, "w") as f:
        f.write('{"records": {"k": ')  # a torn (half-written) file
    db = TuningDatabase(path)  # must not raise
    key = make_key("matmul", detect_platform().name, [(64, 128), (128, 64)],
                   "float32")
    assert db.lookup(key) is None
    # The db is live after the cold start: put() persists a valid file.
    db.put(Record(key, {"bm": 64, "bn": 64, "bk": 128}, 1e-6, "wallclock", 1, 0.0))
    with open(path) as f:
        json.load(f)
    assert TuningDatabase(path).lookup(key) is not None


def test_injected_torn_read_matches_real_corruption(tmp_path):
    path = str(tmp_path / "good.json")
    key = make_key("matmul", detect_platform().name, [(64, 128), (128, 64)],
                   "float32")
    good = TuningDatabase(path)
    good.put(Record(key, {"bm": 64, "bn": 64, "bk": 128}, 1e-6, "wallclock", 1, 0.0))
    # Same file, read through an injected torn-read fault: identical
    # degradation path as a genuinely corrupt file.
    with FaultPlan([FaultRule(site=f"db.load:{path}", kind="torn")]) as plan:
        assert TuningDatabase(path).lookup(key) is None
        assert plan.count(kind="torn") == 1
    assert TuningDatabase(path).lookup(key) is not None  # file was never harmed
