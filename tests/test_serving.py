"""Serving engine: greedy decode matches step-by-step model decode, batching
and temperature sampling behave, caches respect windows."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.serving.engine import EngineConfig, Request, ServingEngine

RUN = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(), EngineConfig(max_batch=4, max_seq=64)
    )
    return cfg, params, eng


def test_greedy_decode_matches_manual(served):
    cfg, params, eng = served
    rs = np.random.RandomState(0)
    prompt = rs.randint(0, cfg.vocab_size, 12).astype(np.int32)
    req = Request(prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    (done,) = eng.serve()

    # manual reference: prefill + argmax decode loop
    toks = jnp.asarray(prompt)[None]
    logits, caches = lm.prefill(params, {"tokens": toks}, cfg, RUN, cache_len=64)
    out = []
    cur = int(jnp.argmax(logits[0]))
    for step in range(6):
        out.append(cur)
        logits, caches = lm.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), caches,
            jnp.asarray(12 + step, jnp.int32), cfg, RUN,
        )
        cur = int(jnp.argmax(logits[0]))
    np.testing.assert_array_equal(done.output, np.asarray(out, np.int32))


def test_batching_equal_prompts(served):
    cfg, params, eng = served
    rs = np.random.RandomState(1)
    prompts = [rs.randint(0, cfg.vocab_size, 10).astype(np.int32) for _ in range(5)]
    for p in prompts:
        eng.submit(Request(prompt=p, max_new_tokens=4))
    done = eng.serve()
    assert len(done) == 5
    assert all(r.output.shape == (4,) for r in done)
    # identical prompts in one batch give identical greedy outputs
    eng.submit(Request(prompt=prompts[0], max_new_tokens=4))
    eng.submit(Request(prompt=prompts[0], max_new_tokens=4))
    a, b = eng.serve()
    np.testing.assert_array_equal(a.output, b.output)


def test_temperature_sampling_seeded(served):
    cfg, params, eng = served
    rs = np.random.RandomState(2)
    p = rs.randint(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(prompt=p, max_new_tokens=8, temperature=1.0, seed=11))
    eng.submit(Request(prompt=p, max_new_tokens=8, temperature=1.0, seed=11))
    a, b = eng.serve()
    np.testing.assert_array_equal(a.output, b.output)  # same seed -> same draw
    eng.submit(Request(prompt=p, max_new_tokens=8, temperature=1.0, seed=12))
    (c,) = eng.serve()
    assert not np.array_equal(a.output, c.output)


def test_swa_arch_serves():
    cfg = get_config("mixtral_8x7b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(), EngineConfig(max_batch=2, max_seq=32)
    )
    rs = np.random.RandomState(3)
    eng.submit(Request(prompt=rs.randint(0, cfg.vocab_size, 10).astype(np.int32),
                       max_new_tokens=5))
    (done,) = eng.serve()
    assert done.output.shape == (5,)


def test_frontend_arch_rejected():
    cfg = get_config("paligemma_3b").reduced()
    with pytest.raises(NotImplementedError):
        ServingEngine(cfg, RUN, {}, make_host_mesh(), Layout(), EngineConfig())
