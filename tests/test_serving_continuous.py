"""Continuous-batching correctness: any arrival pattern + per-request
max_new_tokens yields token-for-token the outputs of running each request
alone (greedy, seeded), and a freed slot's cache never leaks into the next
occupant.

Property-based via hypothesis when installed; a seeded-random fallback
sweep runs the same check otherwise, so the equivalence property is always
exercised.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.serving.engine import EngineConfig, Request, ServingEngine

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

RUN = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16)
MAX_SEQ = 64
PROMPT_LENS = (3, 9, 12, 17)   # few distinct lengths: solo refs jit per length


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=3, max_seq=MAX_SEQ),
    )
    return cfg, params, eng


def _prompt(cfg, length: int, seed: int) -> np.ndarray:
    rs = np.random.RandomState(10_000 + 17 * length + seed)
    return rs.randint(0, cfg.vocab_size, length).astype(np.int32)


_SOLO_CACHE = {}


def _solo_greedy(cfg, params, prompt: np.ndarray, max_new: int) -> np.ndarray:
    """Reference: exact-length prefill + scalar-pos greedy decode, alone."""
    key = (prompt.tobytes(), max_new)
    if key in _SOLO_CACHE:
        return _SOLO_CACHE[key]
    L = len(prompt)
    logits, caches = lm.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg, RUN, cache_len=MAX_SEQ
    )
    out = [int(jnp.argmax(logits[0]))]
    for step in range(min(max_new, MAX_SEQ - L) - 1):
        logits, caches = lm.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches,
            jnp.asarray(L + step, jnp.int32), cfg, RUN,
        )
        out.append(int(jnp.argmax(logits[0])))
    ref = np.asarray(out, np.int32)
    _SOLO_CACHE[key] = ref
    return ref


def _check_schedule(cfg, params, eng, schedule):
    """schedule: list of (arrival_gap, prompt_len, max_new, prompt_seed)."""
    reqs = []
    t = 0.0
    for gap, length, max_new, seed in schedule:
        t += gap
        reqs.append(Request(
            prompt=_prompt(cfg, length, seed), max_new_tokens=max_new,
            arrival_time=t,
        ))
    for r in reqs:
        eng.submit(r)
    done = eng.serve()
    assert len(done) == len(reqs)
    assert all(s is None for s in eng._slots), "slots must drain"
    for r in done:
        ref = _solo_greedy(cfg, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(
            r.output, ref,
            err_msg=f"arrival={r.arrival_time} len={len(r.prompt)} "
                    f"max_new={r.max_new_tokens} slot={r.slot}",
        )


if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None, derandomize=True)
    @given(
        st.lists(
            st.tuples(
                st.integers(0, 6),                  # arrival gap (ticks)
                st.sampled_from(PROMPT_LENS),       # prompt length
                st.integers(1, 6),                  # max_new_tokens
                st.integers(0, 3),                  # prompt content seed
            ),
            min_size=1, max_size=6,
        )
    )
    def test_any_arrival_pattern_matches_solo(served, schedule):
        cfg, params, eng = served
        _check_schedule(cfg, params, eng, schedule)
else:
    @pytest.mark.parametrize("case_seed", range(12))
    def test_any_arrival_pattern_matches_solo(served, case_seed):
        cfg, params, eng = served
        rs = np.random.RandomState(500 + case_seed)
        n = rs.randint(1, 7)
        schedule = [
            (int(rs.randint(0, 7)),
             int(PROMPT_LENS[rs.randint(len(PROMPT_LENS))]),
             int(rs.randint(1, 7)),
             int(rs.randint(0, 4)))
            for _ in range(n)
        ]
        _check_schedule(cfg, params, eng, schedule)


def test_invalid_requests_rejected_at_submit(served):
    cfg, params, eng = served
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=_prompt(cfg, 9, 0), max_new_tokens=0))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros((0,), np.int32), max_new_tokens=4))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=_prompt(cfg, MAX_SEQ, 0), max_new_tokens=4))


def test_single_token_requests_admit_through_one_slot(served):
    """max_new=1 completes at admission and recycles the slot immediately."""
    cfg, params, eng = served
    eng.reset_stats()
    for i in range(5):
        eng.submit(Request(prompt=_prompt(cfg, 9, i % 4), max_new_tokens=1))
    done = eng.serve()
    assert all(len(r.output) == 1 for r in done)
    assert eng.stats["decode_steps"] == 0          # prefill logits only
    assert eng.stats["prefill_calls"] == 5
    for r in done:
        ref = _solo_greedy(cfg, params, r.prompt, 1)
        np.testing.assert_array_equal(r.output, ref)


def test_freed_slot_cache_never_leaks(served):
    """A long occupant then a fresh request in the SAME slot: the second's
    output equals its solo run — the insert overwrites the whole region."""
    cfg, params, eng = served
    # single-slot engine forces reuse of slot 0
    one = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=1, max_seq=MAX_SEQ),
    )
    a = Request(prompt=_prompt(cfg, 17, 0), max_new_tokens=12)
    b = Request(prompt=_prompt(cfg, 3, 1), max_new_tokens=8)
    one.submit(a)
    one.submit(b)
    da, db = one.serve()
    assert da.slot == db.slot == 0
    np.testing.assert_array_equal(db.output, _solo_greedy(cfg, params, b.prompt, 8))
    # and the occupant that ran first was itself correct
    np.testing.assert_array_equal(da.output, _solo_greedy(cfg, params, a.prompt, 12))


def test_slot_reuse_matches_fresh_engine(served):
    """Output from a reused slot is bit-identical to a never-used engine."""
    cfg, params, eng = served
    req = lambda: Request(prompt=_prompt(cfg, 12, 2), max_new_tokens=10)
    # dirty the pool with varied traffic, then serve the probe
    for i in range(4):
        eng.submit(Request(prompt=_prompt(cfg, 17, i % 4), max_new_tokens=6))
    eng.serve()
    eng.submit(req())
    (dirty,) = eng.serve()
    fresh_eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=3, max_seq=MAX_SEQ),
    )
    fresh_eng.submit(req())
    (fresh,) = fresh_eng.serve()
    np.testing.assert_array_equal(dirty.output, fresh.output)


def test_seeded_temperature_matches_solo_timing_independent(served):
    """Same seed + temperature gives the same draws regardless of when the
    request is admitted or which slot it lands in."""
    cfg, params, eng = served
    mk = lambda arrival: Request(
        prompt=_prompt(cfg, 9, 3), max_new_tokens=8, temperature=1.0, seed=7,
        arrival_time=arrival,
    )
    filler = [Request(prompt=_prompt(cfg, 12, i), max_new_tokens=5 + i)
              for i in range(3)]
    eng.submit(mk(0.0))
    early = eng.serve()[0]
    for f in filler:
        eng.submit(f)
    eng.submit(mk(4.0))                    # admitted mid-flight, different slot mix
    late = [r for r in eng.serve() if r.temperature > 0][0]
    np.testing.assert_array_equal(early.output, late.output)


def test_ssm_arch_exact_length_prefill_matches_solo():
    """SSM mixers can't mask pad tokens out of their state: the engine
    prefills them at exact length and must still match solo decode."""
    cfg = get_config("jamba_1_5_large").reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = lm.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=32),
    )
    rs = np.random.RandomState(9)
    prompts = [rs.randint(0, cfg.vocab_size, n).astype(np.int32) for n in (5, 11)]
    eng.submit(Request(prompt=prompts[0], max_new_tokens=6))
    eng.submit(Request(prompt=prompts[1], max_new_tokens=3))
    done = eng.serve()
    for r, prompt in zip(done, prompts):
        L = len(prompt)
        logits, caches = lm.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, cfg, RUN, cache_len=32
        )
        ref = [int(jnp.argmax(logits[0]))]
        for step in range(r.max_new_tokens - 1):
            logits, caches = lm.decode_step(
                params, jnp.asarray([[ref[-1]]], jnp.int32), caches,
                jnp.asarray(L + step, jnp.int32), cfg, RUN,
            )
            ref.append(int(jnp.argmax(logits[0])))
        np.testing.assert_array_equal(r.output, np.asarray(ref, np.int32))
