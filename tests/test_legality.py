"""Static kernel-legality plane (repro.core.gridmodel): race/OOB/alignment
checks on abstract grid models, space-level pruning on TPU fingerprints, and
the tuner's filter-before-measurement pre-pass."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evaluate import Evaluator, Measurement
from repro.core.gridmodel import (
    GridModel,
    RefModel,
    check_alignment,
    check_oob,
    check_races,
    config_verdict,
    registered_models,
    space_illegal,
    space_report,
    sublanes_for,
)
from repro.core.platform import PROFILES, set_platform_override

TPU = PROFILES["tpu-v5e"]
CPU = PROFILES["cpu-host"]


def _register_all():
    from repro.core.runtime import ensure_registered

    ensure_registered()


# ---------------------------------------------------------------------------
# Race detector
# ---------------------------------------------------------------------------


def _dw_model(semantics):
    """An rmsnorm_bwd-shaped model: dw accumulator invariant along the row
    axis. Legal only when that axis is sequential ("arbitrary")."""
    return GridModel(
        kernel="synthetic_rmsnorm_bwd",
        grid=(8,),
        semantics=semantics,
        refs=(
            RefModel("dx", (128, 4096), lambda i: (i, 0), (1024, 4096), role="out"),
            RefModel("dw", (1, 4096), lambda i: (0, 0), (1, 4096), role="out"),
        ),
    )


def test_race_detector_flags_parallelized_accumulator():
    reason = check_races(_dw_model(("parallel",)))
    assert reason is not None
    assert "dw" in reason and "race" in reason


def test_race_detector_accepts_sequential_accumulator():
    assert check_races(_dw_model(("arbitrary",))) is None


def test_race_detector_ignores_input_refs():
    m = GridModel(
        kernel="k",
        grid=(4,),
        semantics=("parallel",),
        refs=(RefModel("w", (1, 128), lambda i: (0, 0), (1, 128), role="in"),),
    )
    assert check_races(m) is None


def test_shipped_sequential_kernels_are_race_free():
    """The shipped rmsnorm_bwd dw accumulator and ssm_scan chunk carry ride
    'arbitrary' axes — the detector must not flag them (ground truth)."""
    _register_all()
    for kernel in registered_models():
        for platform in ("tpu-v5e", "tpu-v4", "cpu-host"):
            r = space_report(kernel, platform)
            assert r["by_category"].get("race", 0) == 0, (kernel, platform, r)
            assert r["by_category"].get("oob", 0) == 0, (kernel, platform, r)


# ---------------------------------------------------------------------------
# OOB + alignment
# ---------------------------------------------------------------------------


def test_oob_detector_flags_overrunning_index_map():
    m = GridModel(
        kernel="k",
        grid=(2,),
        semantics=("parallel",),
        # block row i of size 8 over a dim of 8: i=1 spans [8, 16) — OOB.
        refs=(RefModel("x", (8, 128), lambda i: (i, 0), (8, 128), role="out"),),
    )
    reason = check_oob(m)
    assert reason is not None and "outside padded dim" in reason


def test_alignment_lane_rule_and_full_dim_exemption():
    bad = GridModel(
        kernel="k", grid=(2,), semantics=("parallel",),
        refs=(RefModel("x", (8, 64), lambda i: (0, i), (8, 4096)),),
    )
    assert "lanes" in check_alignment(bad, TPU)
    full = GridModel(
        kernel="k", grid=(1,), semantics=("parallel",),
        refs=(RefModel("x", (8, 4096), lambda i: (0, 0), (8, 4096)),),
    )
    assert check_alignment(full, TPU) is None
    # Off-TPU nothing is pruned.
    assert check_alignment(bad, CPU) is None


def test_alignment_sublane_rule_is_dtype_aware():
    assert sublanes_for(TPU, "float32") == 8
    assert sublanes_for(TPU, "bfloat16") == 16
    m = GridModel(
        kernel="k", grid=(8,), semantics=("parallel",),
        refs=(RefModel("x", (4, 128), lambda i: (i, 0), (64, 128)),),
    )
    assert "sublanes" in check_alignment(m, TPU, "float32")
    # A single-row (1, N) block is representable — flash bwd's lse rows.
    row = GridModel(
        kernel="k", grid=(8,), semantics=("parallel",),
        refs=(RefModel("lse", (1, 128), lambda i: (i, 0), (8, 4096)),),
    )
    assert check_alignment(row, TPU) is None


# ---------------------------------------------------------------------------
# Space-level verdicts on the shipped kernels
# ---------------------------------------------------------------------------

EXPECTED_TPU_V5E = {
    "matmul": (160, 160),
    "expert_gemm": (160, 160),
    "rmsnorm": (8, 8),
    "rmsnorm_bwd": (8, 8),
    "softmax_xent": (53, 53),
    "softmax_xent_bwd": (53, 53),
    "flash_attention": (25, 25),
    "flash_attention_bwd": (25, 25),
    "ssm_scan": (49, 21),
    "ssm_update": (49, 21),
}


def test_space_reports_on_tpu_v5e_match_ground_truth():
    _register_all()
    got = {
        k: (space_report(k, "tpu-v5e")["total"], space_report(k, "tpu-v5e")["legal"])
        for k in EXPECTED_TPU_V5E
    }
    assert got == EXPECTED_TPU_V5E


def test_ssm_pruning_is_exactly_the_sub_lane_tiles():
    """On tpu-v5e the ssm spaces lose exactly the block_d < 128 tiles (a
    block_d that tiles d_inner must span full lanes); everything pruned is
    'align', never race/oob."""
    _register_all()
    illegal = space_illegal("ssm_scan", "tpu-v5e")
    assert len(illegal) == 28
    assert all(cat == "align" for cat, _ in illegal.values())
    assert all("block_d=" in key for key in illegal)
    for key in illegal:
        bd = int(dict(kv.split("=") for kv in key.split(","))["block_d"])
        assert bd < 128


def test_cpu_host_prunes_nothing():
    _register_all()
    from repro.kernels.ssm_scan import SSM_SCAN_SPACE

    full = list(SSM_SCAN_SPACE.enumerate())
    assert SSM_SCAN_SPACE.legal_configs("cpu-host") == full
    assert space_illegal("ssm_scan", "cpu-host") == {}


def test_legal_configs_shrinks_on_tpu_and_keeps_aligned_tiles():
    _register_all()
    from repro.kernels.ssm_scan import SSM_SCAN_SPACE

    full = list(SSM_SCAN_SPACE.enumerate())
    legal = SSM_SCAN_SPACE.legal_configs("tpu-v5e")
    assert len(legal) == 21 < len(full) == 49
    assert all(cfg["block_d"] >= 128 for cfg in legal)
    pruned = [c for c in full if c not in legal]
    assert all(cfg["block_d"] < 128 for cfg in pruned)


def test_space_without_grid_model_enumerates_fully():
    _register_all()
    from repro.kernels.ssm_scan import SSM_SCAN_BWD_SPACE

    full = list(SSM_SCAN_BWD_SPACE.enumerate())
    assert SSM_SCAN_BWD_SPACE.legal_configs("tpu-v5e") == full
    assert len(full) == 7


def test_pruned_configs_are_infeasible_not_wrong():
    """Acceptance: pruning must be conservative — a config pruned on the TPU
    fingerprint still computes the right answer under interpret mode (it is
    merely unlowerable/mispadded on real hardware, not incorrect)."""
    _register_all()
    from repro.kernels.ssm_scan import (
        _ssm_scan_example, ssm_scan_chunked, ssm_scan_pallas,
    )

    (xc, dt, B, C, A, h0), _ = _ssm_scan_example()
    y_ref, h_ref = ssm_scan_chunked(xc, dt, B, C, A, h0)
    illegal = space_illegal("ssm_scan", "tpu-v5e")
    sampled = sorted(illegal)[:2]
    for key in sampled:
        cfg = {k: int(v) for k, v in (kv.split("=") for kv in key.split(","))}
        y, hn = ssm_scan_pallas(xc, dt, B, C, A, h0, interpret=True, **cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hn), np.asarray(h_ref),
                                   rtol=2e-4, atol=2e-4)


def test_best_interpret_config_survives_pruning():
    """Acceptance: pruning changes nothing about the best-found config on
    interpret platforms — cpu-host legality is the full space, so any winner
    found there is by construction un-pruned."""
    _register_all()
    from repro.kernels.ssm_scan import SSM_SCAN_SPACE

    legal_keys = {
        SSM_SCAN_SPACE.config_key(c)
        for c in SSM_SCAN_SPACE.legal_configs("cpu-host")
    }
    assert {SSM_SCAN_SPACE.config_key(c) for c in SSM_SCAN_SPACE.enumerate()} \
        == legal_keys


# ---------------------------------------------------------------------------
# Tuner integration: the static pre-pass
# ---------------------------------------------------------------------------


class SmallestTileEvaluator(Evaluator):
    """Deterministic objective preferring the smallest tiles: without the
    legality pre-pass, block_d=8 would always win."""

    name = "smallest-tile"

    def evaluate(self, fn, args, reference=None):
        cfg = getattr(fn, "keywords", {})
        score = sum(float(v) for v in cfg.values() if isinstance(v, int))
        return Measurement(objective=score or 1.0, ok=True)


def test_autotune_prunes_statically_illegal_configs_on_tpu_fingerprint():
    _register_all()
    from repro.core.annotate import get_tunable
    from repro.core.database import TuningDatabase
    from repro.core.search import ExhaustiveSearch
    from repro.core.tuner import autotune

    rs = np.random.RandomState(0)
    b, s, di, ds = 2, 64, 256, 16
    args = (
        jnp.asarray(rs.randn(b, s, di) * 0.5, jnp.float32),
        jnp.asarray(np.abs(rs.randn(b, s, di)) * 0.1 + 0.01, jnp.float32),
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),
        jnp.asarray(-np.abs(rs.randn(di, ds)) - 0.1, jnp.float32),
        jnp.asarray(rs.randn(b, di, ds) * 0.3, jnp.float32),
    )
    set_platform_override("tpu-v5e")
    try:
        result = autotune(
            get_tunable("ssm_scan"), args,
            search=ExhaustiveSearch(),
            evaluator=SmallestTileEvaluator(),
            db=TuningDatabase(None), save=False,
        )
    finally:
        set_platform_override(None)
    # The surrogate prefers block_d=8, but every block_d < 128 tile is
    # statically illegal at di=256 on tpu-v5e — the winner must be aligned.
    assert result.best_config["block_d"] >= 128
    pruned = [
        t for t in result.search.trials
        if not t.ok and t.meta.get("pruned", "").startswith("align")
    ]
    assert pruned, "no trial carries the static-prune marker"
    assert all(t.config["block_d"] < 128 for t in pruned)


def test_autotune_pre_pass_is_inert_on_cpu():
    _register_all()
    from repro.core.annotate import get_tunable
    from repro.core.database import TuningDatabase
    from repro.core.search import ExhaustiveSearch
    from repro.core.tuner import autotune

    rs = np.random.RandomState(1)
    b, s, di, ds = 2, 12, 8, 4
    args = (
        jnp.asarray(rs.randn(b, s, di) * 0.5, jnp.float32),
        jnp.asarray(np.abs(rs.randn(b, s, di)) * 0.1 + 0.01, jnp.float32),
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),
        jnp.asarray(-np.abs(rs.randn(di, ds)) - 0.1, jnp.float32),
        jnp.asarray(rs.randn(b, di, ds) * 0.3, jnp.float32),
    )
    result = autotune(
        get_tunable("ssm_scan"), args,
        search=ExhaustiveSearch(),
        evaluator=SmallestTileEvaluator(),
        db=TuningDatabase(None), save=False,
    )
    assert not any(t.meta.get("pruned") for t in result.search.trials)
    assert result.best_config == {"chunk": 8, "block_d": 8}


# ---------------------------------------------------------------------------
# Scheduler integration: legality stamped into the manifest
# ---------------------------------------------------------------------------


def test_build_manifest_stamps_legality_counts(tmp_path):
    _register_all()
    from repro.campaign.planner import TuningJob
    from repro.campaign.scheduler import CampaignManifest, build_manifest

    job = TuningJob(
        kernel="ssm_scan",
        arg_shapes=((2, 64, 256), (2, 64, 256), (2, 64, 16), (2, 64, 16),
                    (256, 16), (2, 256, 16)),
        arg_dtypes=("float32",) * 6,
        scenarios=("jamba/train_4k",),
    )
    path = str(tmp_path / "m.json")
    m = build_manifest([job], 24, path=path, platform="tpu-v5e",
                       profile=PROFILES["tpu-v5e"])
    assert m.meta["legality"]["ssm_scan"] == {
        "total": 49, "legal": 21, "pruned": 28,
    }
    assert m.summary()["configs_pruned"] == 28
    # survives the JSON round trip `campaign status` reads
    loaded = CampaignManifest.load(path)
    assert loaded.meta["legality"]["ssm_scan"]["pruned"] == 28
    assert loaded.summary()["configs_pruned"] == 28


def test_config_verdict_unknown_kernel_is_legal():
    assert config_verdict("no_such_kernel", {"a": 1}, "tpu-v5e") is None
