"""Roofline machinery: trip-aware HLO parsing and the analytic FLOP model
validated against XLA's own counters on an unscanned module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeSpec, get_config
from repro.core.evaluate import collective_stats, roofline_from_compiled
from repro.tools.analytic import analytic_roofline, step_flops, step_hbm_bytes


SYNTH_HLO = """
HloModule m

%inner_body.9 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar2 = f32[64]{0} all-reduce(%y), replica_groups={}
  ROOT %t2 = tuple()
}

%inner_cond.9 (p: (s32[], f32[64])) -> pred[] {
  %c2 = s32[] constant(4)
  ROOT %cmp2 = pred[] compare(%gte, %c2), direction=LT
}

%body.1 (p: (s32[], f32[896])) -> (s32[], f32[896]) {
  %ar = f32[896]{0} all-reduce(%x), replica_groups={}
  %w2 = (s32[], f32[64]) while(%init2), condition=%inner_cond.9, body=%inner_body.9
  ROOT %t = tuple()
}

%cond.1 (p: (s32[], f32[896])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main.2 (a: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[896]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[128,256] add(%a, %a)
}
"""


def test_trip_aware_collectives_nested():
    s = collective_stats(SYNTH_HLO)
    # outer loop 24x: 24 * 896*4 ; nested 24*4 * 64*4 ; entry all-gather once
    assert s["bytes_by_kind"]["all-reduce"] == 24 * 896 * 4 + 24 * 4 * 64 * 4
    assert s["bytes_by_kind"]["all-gather"] == 128 * 256 * 4
    assert s["count"] == 3


def test_le_direction_trip_count():
    hlo = SYNTH_HLO.replace("direction=LT", "direction=LE")
    s = collective_stats(hlo)
    assert s["bytes_by_kind"]["all-reduce"] == 25 * 896 * 4 + 25 * 5 * 64 * 4


def test_analytic_flops_match_xla_on_unscanned_matmul():
    """Sanity-anchor the analytic convention (2 flops per MAC) to XLA."""
    m, k, n = 256, 512, 128
    fn = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    compiled = fn.lower(a, b).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert abs(ca["flops"] - 2 * m * k * n) / (2 * m * k * n) < 0.05


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "mixtral_8x7b", "jamba_1_5_large"])
def test_step_flops_vs_6nd(arch):
    """Train FLOPs must bracket 6·N_active·D: above it (attention/remat), but
    within a small factor for these dense-ish models."""
    from repro.models import lm

    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    fl = step_flops(cfg, shape, remat="none")
    n_active = lm.active_param_count(cfg)
    model = 6 * n_active * shape.global_batch * shape.seq_len
    assert fl["total"] > 0.7 * model
    assert fl["total"] < 4.0 * model, (fl["total"] / model)


def test_decode_memory_dominated_by_weights_and_cache():
    cfg = get_config("qwen2_0_5b")
    shape = SHAPES["decode_32k"]
    hbm = step_hbm_bytes(cfg, shape, chips=256, model_par=16)
    assert hbm["total"] == pytest.approx(hbm["weights"] + hbm["cache"])
    assert hbm["weights"] > 0 and hbm["cache"] > 0


def test_analytic_roofline_terms_positive():
    cfg = get_config("minitron_4b")
    shape = SHAPES["train_4k"]
    ar = analytic_roofline(
        cfg, shape, chips=256,
        collective_bytes_by_kind={"all-reduce": 1e9, "all-gather": 5e8},
        model_par=16,
    )
    assert ar.compute_s > 0 and ar.memory_s > 0 and ar.collective_s > 0
    assert ar.dominant in ("compute", "memory", "collective")
    assert 0 < ar.useful_ratio < 1.5
    assert 0 < ar.roofline_fraction <= 1.0


def test_windowed_cache_shrinks_memory():
    g = get_config("gemma3_27b")
    shape = SHAPES["decode_32k"]
    from repro.tools.analytic import _cache_bytes

    with_window = _cache_bytes(g, shape.global_batch, shape.seq_len, 256, 16)
    import dataclasses

    no_window = _cache_bytes(
        dataclasses.replace(g, window=0, local_global_ratio=0),
        shape.global_batch, shape.seq_len, 256, 16,
    )
    assert with_window < 0.3 * no_window  # 52/62 layers cache 1k not 32k
