"""Gradient compression + ring all-reduce. Multi-device cases run in a
subprocess with 8 fake host devices (the parent process stays 1-device)."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import compress_grads, ef_init


def test_bf16_compression_lossy_but_close():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(128), jnp.float32)}
    out, _ = compress_grads(g, None, "bf16")
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    assert 0 < err < 2e-2


def test_int8_ef_residual_carries():
    rs = np.random.RandomState(1)
    g = {"w": jnp.asarray(rs.randn(256) * 0.01, jnp.float32)}
    ef = ef_init(g)
    acc_c = np.zeros(256)
    acc_t = np.zeros(256)
    for i in range(60):
        gi = {"w": g["w"] * (1.0 + 0.1 * np.sin(i))}
        c, ef = compress_grads(gi, ef, "int8_ef")
        acc_c += np.asarray(c["w"])
        acc_t += np.asarray(gi["w"])
    rel = np.max(np.abs(acc_c - acc_t)) / np.max(np.abs(acc_t))
    assert rel < 0.02, rel  # error feedback keeps the accumulated signal


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        compress_grads({"w": jnp.zeros(3)}, None, "fp4")


_RING_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import ring_all_reduce
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((8,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((8,), ("x",))
    x = jnp.asarray(np.random.RandomState(0).randn(8, 37), jnp.float32)
    out = jax.jit(lambda v: ring_all_reduce(v, mesh, "x"))(x)
    want = jnp.broadcast_to(x.sum(0), x.shape)
    err = float(jnp.max(jnp.abs(out - want)))
    assert err < 1e-4, err
    print("RING_OK")
    """
)


def test_ring_all_reduce_8dev():
    r = subprocess.run(
        [sys.executable, "-c", _RING_SCRIPT],
        capture_output=True, text=True, timeout=300,
        # JAX_PLATFORMS=cpu: without it jax probes the bundled libtpu on this
        # image and hangs for minutes before falling back to CPU
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=".",
    )
    assert "RING_OK" in r.stdout, r.stderr[-2000:]
