"""Second-order autodiff through dispatch-vjp tunables + the flash-backward
pass-count regression.

The dispatch runtime's custom_vjp used to declare ``vjp="none"`` on the
backward tunables, so ``jax.grad(jax.grad(...))`` through any dispatch site
died in the second differentiation. The lift routes nesting ≥ 2 (and
forward-mode over the custom_vjp) to the reference path, which JAX can
differentiate arbitrarily deep — these tests pin grad-of-grad parity against
the pure-jnp oracles under kernel mode.

The pass-count test pins the residual contract's structural win: with the
forward's (o, lse) saved into the VJP residuals, ``flash_attention_bwd``
realizes exactly two pallas_calls (dq pass + dkv pass) — the
forward-recompute pass is gone.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import TuningDatabase
from repro.kernels import ref
from repro.kernels.attention import flash_attention_bwd_pallas


def _hvp(f, x, v):
    """Hessian-vector product: grad of (grad(f) · v) — true second order."""
    return jax.grad(lambda y: jnp.sum(jax.grad(f)(y) * v))(x)


def test_grad_of_grad_matmul_matches_reference(rs):
    x = jnp.asarray(rs.randn(32, 64), jnp.float32)
    w = jnp.asarray(rs.randn(64, 16), jnp.float32)
    v = jnp.asarray(rs.randn(32, 64), jnp.float32)

    def f_dispatch(y):
        return jnp.sum(jnp.tanh(repro.dispatch("matmul", y, w)))

    def f_ref(y):
        return jnp.sum(jnp.tanh(y @ w))

    want = _hvp(f_ref, x, v)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)):
        got = _hvp(f_dispatch, x, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_grad_of_grad_rmsnorm_matches_reference(rs):
    x = jnp.asarray(rs.randn(16, 128), jnp.float32)
    scale = jnp.asarray(rs.randn(128) * 0.1 + 1.0, jnp.float32)
    v = jnp.asarray(rs.randn(16, 128), jnp.float32)

    def f_dispatch(y):
        return jnp.sum(jnp.sin(repro.dispatch("rmsnorm", y, scale)))

    def f_ref(y):
        return jnp.sum(jnp.sin(ref.rmsnorm(y, scale)))

    want = _hvp(f_ref, x, v)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)):
        got = _hvp(f_dispatch, x, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (tuple, list)) else (p,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    n += _count_pallas_calls(inner)
    return n


def test_flash_attention_bwd_is_exactly_two_pallas_calls(rs):
    """Residual-threaded backward: dq pass + dkv pass, no recompute pass."""
    b, h, kv, s, d = 1, 2, 1, 128, 16
    q = jnp.asarray(rs.randn(b, h, s, d) * 0.3, jnp.float32)
    k = jnp.asarray(rs.randn(b, kv, s, d) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(b, kv, s, d), jnp.float32)
    ct = jnp.asarray(rs.randn(b, h, s, d), jnp.float32)
    o, lse = ref.attention_res(q, k, v, causal=True)
    fn = functools.partial(
        flash_attention_bwd_pallas, block_q=64, block_k=64, causal=True,
        interpret=True,
    )
    jaxpr = jax.make_jaxpr(fn)(ct, q, k, v, o, lse)
    assert _count_pallas_calls(jaxpr.jaxpr) == 2, jaxpr.pretty_print()
