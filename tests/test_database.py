"""TuningDatabase invariants: schema gating, bucket edge cases, put/merge
semantics, per-platform export, and cover-set storage/lookup."""
import json
import math

import pytest

from repro.core import (
    Record,
    TuningDatabase,
    make_key,
    shape_bucket,
    shape_distance,
    split_key,
)
from repro.core.database import SCHEMA_VERSION


def rec(key, config, objective, ts=0.0):
    return Record(key, config, objective, "wallclock", 1, ts)


# ---------------------------------------------------------------- shape keys


def test_shape_bucket_edge_cases():
    assert shape_bucket((0,)) == (0,)            # degenerate dim kept exact
    assert shape_bucket((1,)) == (1,)
    assert shape_bucket((8,)) == (8,)            # boundary: <= 8 stays exact
    assert shape_bucket((9,)) == (16,)           # first bucketed size
    assert shape_bucket((2**20,)) == (2**20,)    # exact power of two unchanged
    assert shape_bucket((2**20 + 1,)) == (2**21,)


def test_shape_bucket_non_int_dims():
    import numpy as np

    # numpy scalar dims (what jax shapes sometimes carry) must coerce
    assert shape_bucket((np.int64(100), np.int32(8))) == (128, 8)
    assert shape_bucket((float(9.0),)) == (16,)


def test_split_key_roundtrip():
    key = make_key("matmul", "tpu-v5e", [(100, 128), (128, 64)], "bfloat16", "cTruew0")
    kernel, platform, shapes, dtype, extra = split_key(key)
    assert kernel == "matmul" and platform == "tpu-v5e"
    assert shapes == ((128, 128), (128, 64))      # bucketed by make_key
    assert dtype == "bfloat16" and extra == "cTruew0"


def test_shape_distance():
    assert shape_distance([(64, 64)], [(64, 64)]) == 0.0
    assert shape_distance([(64,)], [(128,)]) == 1.0
    assert math.isinf(shape_distance([(64,)], [(64, 64)]))   # rank mismatch
    assert math.isinf(shape_distance([(4, 4)], [(4,)]))


# ---------------------------------------------------------------- put / load


def test_schema_mismatch_drops_all_records(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDatabase(path)
    db.put(rec("k|cpu-host|8|f32", {"a": 1}, 1.0))
    blob = json.load(open(path))
    blob["schema"] = SCHEMA_VERSION - 1
    json.dump(blob, open(path, "w"))
    # old-schema records must not be misread — a fresh pass rebuilds them
    db2 = TuningDatabase(path)
    assert len(db2) == 0
    assert db2.lookup("k|cpu-host|8|f32") is None


def test_put_better_record_wins(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDatabase(path)
    key = "k|cpu-host|64|f32"
    db.put(rec(key, {"a": 1}, 2.0, ts=0.0))
    db.put(rec(key, {"a": 2}, 3.0, ts=1.0))      # worse (noise): ignored
    assert db.lookup(key).config == {"a": 1}
    db.put(rec(key, {"a": 3}, 2.0, ts=2.0))      # tie: newer record accepted
    assert db.lookup(key).config == {"a": 3}
    db.put(rec(key, {"a": 4}, 0.5, ts=3.0))      # better: replaces
    assert TuningDatabase(path).lookup(key).config == {"a": 4}


def test_merge_better_record_wins(tmp_path):
    a = TuningDatabase(str(tmp_path / "a.json"))
    b = TuningDatabase(str(tmp_path / "b.json"))
    a.put(rec("k1|p|8|f32", {"a": 1}, 1.0))
    a.put(rec("k2|p|8|f32", {"a": 1}, 5.0))
    b.put(rec("k2|p|8|f32", {"a": 9}, 1.0))      # better than a's k2
    b.put(rec("k3|p|8|f32", {"a": 7}, 2.0))      # new key
    accepted = a.merge(b)
    assert accepted == 2
    assert a.lookup("k1|p|8|f32").config == {"a": 1}
    assert a.lookup("k2|p|8|f32").config == {"a": 9}
    assert a.lookup("k3|p|8|f32").config == {"a": 7}
    # merge persisted through the atomic writer
    assert len(TuningDatabase(str(tmp_path / "a.json"))) == 3


def test_export_filters_platform(tmp_path):
    db = TuningDatabase(str(tmp_path / "db.json"))
    db.put(rec(make_key("k", "cpu-host", [(64,)], "f32"), {"a": 1}, 1.0))
    db.put(rec(make_key("k", "tpu-v5e", [(64,)], "f32"), {"a": 2}, 1.0))
    db.put_cover("k", "cpu-host", [{"config": {"a": 1}, "support": [[[64]]], "share": 1.0}])
    db.put_cover("k", "tpu-v5e", [{"config": {"a": 2}, "support": [[[64]]], "share": 1.0}])
    out = db.export(str(tmp_path / "tpu.json"), platform="tpu-v5e")
    assert out.platforms() == {"tpu-v5e": 1}
    loaded = TuningDatabase(str(tmp_path / "tpu.json"))
    assert loaded.platforms() == {"tpu-v5e": 1}
    assert loaded.lookup_cover("k", "tpu-v5e")[0]["config"] == {"a": 2}
    assert loaded.lookup_cover("k", "cpu-host") == []


def test_cover_lookup_ranks_by_shape_distance(tmp_path):
    db = TuningDatabase(None)
    db.put_cover("k", "p", [
        {"config": {"a": "small"}, "support": [[[16]]], "share": 0.6},
        {"config": {"a": "big"}, "support": [[[4096]]], "share": 0.4},
    ])
    # no shapes: descending-share order preserved
    assert db.lookup_cover("k", "p")[0]["config"] == {"a": "small"}
    # a big query re-ranks the far cluster first
    assert db.lookup_cover("k", "p", [(2048,)])[0]["config"] == {"a": "big"}
    assert db.lookup_cover("k", "p", [(16,)])[0]["config"] == {"a": "small"}
