"""Straggler monitor + restart policy + recovery loop."""
import pytest

from repro.train.resilience import (
    RestartPolicy,
    StragglerMonitor,
    run_with_recovery,
)


def test_straggler_detection():
    events = []
    m = StragglerMonitor(threshold_mads=5.0, min_samples=8,
                         on_straggler=lambda s, t, med: events.append(s))
    for i in range(20):
        assert not m.record(i, 1.0 + 0.01 * (i % 3))
    assert m.record(20, 10.0)       # 10x the median
    assert events == [20]
    assert not m.record(21, 1.01)   # recovery not flagged


def test_straggler_needs_history():
    m = StragglerMonitor(min_samples=8)
    for i in range(7):
        assert not m.record(i, 100.0 if i == 3 else 1.0)


def test_restart_policy_budget():
    p = RestartPolicy(max_failures=3, backoff_base_s=0.1, backoff_cap_s=1.0)
    assert p.on_failure() == 0.1
    assert p.on_failure() == 0.2
    assert p.on_failure() == 0.4
    with pytest.raises(RuntimeError, match="budget"):
        p.on_failure()


def test_run_with_recovery_replays_from_checkpoint():
    state = {"step": 0, "ckpt": 0, "fail_armed": True}
    executed = []

    def step_fn(step):
        if state["fail_armed"] and step == 5:
            state["fail_armed"] = False
            raise RuntimeError("simulated node failure")
        executed.append(step)
        state["step"] = step + 1
        if (step + 1) % 3 == 0:
            state["ckpt"] = step + 1
        return {"loss": 1.0}

    def restore_fn():
        state["step"] = state["ckpt"]
        return state["ckpt"]

    run_with_recovery(step_fn, restore_fn, total_steps=8,
                      policy=RestartPolicy(max_failures=2), sleep=lambda s: None)
    # failed at 5 -> restored to ckpt 3 -> replayed 3,4,5
    assert executed == [0, 1, 2, 3, 4, 3, 4, 5, 6, 7]


def test_run_with_recovery_gives_up():
    def step_fn(step):
        raise RuntimeError("always broken")

    with pytest.raises(RuntimeError, match="budget"):
        run_with_recovery(step_fn, lambda: 0, total_steps=4,
                          policy=RestartPolicy(max_failures=2), sleep=lambda s: None)
