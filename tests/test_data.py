"""Data pipeline: determinism, host sharding, resumability, arch layouts."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline


CFG = get_config("qwen2_0_5b").reduced()


def test_determinism():
    a = SyntheticPipeline(CFG, DataConfig(seed=7, batch_size=4, seq_len=32))
    b = SyntheticPipeline(CFG, DataConfig(seed=7, batch_size=4, seq_len=32))
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_seed_changes_stream():
    a = SyntheticPipeline(CFG, DataConfig(seed=1, batch_size=4, seq_len=32))
    b = SyntheticPipeline(CFG, DataConfig(seed=2, batch_size=4, seq_len=32))
    assert not np.array_equal(a.next_batch()["tokens"], b.next_batch()["tokens"])


def test_resume_state():
    a = SyntheticPipeline(CFG, DataConfig(seed=3, batch_size=4, seq_len=32))
    a.next_batch()
    a.next_batch()
    state = a.state_dict()
    want = a.next_batch()
    b = SyntheticPipeline(CFG, DataConfig(seed=3, batch_size=4, seq_len=32))
    b.load_state_dict(state)
    np.testing.assert_array_equal(b.next_batch()["tokens"], want["tokens"])


def test_host_sharding_partitions_batch():
    d = dict(seed=5, batch_size=8, seq_len=16)
    hosts = [
        SyntheticPipeline(CFG, DataConfig(host_index=i, host_count=4, **d))
        for i in range(4)
    ]
    batches = [h.next_batch()["tokens"] for h in hosts]
    assert all(b.shape == (2, 16) for b in batches)
    # hosts generate distinct shards
    assert not np.array_equal(batches[0], batches[1])


def test_bad_host_split_rejected():
    with pytest.raises(ValueError):
        SyntheticPipeline(CFG, DataConfig(batch_size=5, host_count=4))


def test_labels_are_shifted_tokens():
    p = SyntheticPipeline(CFG, DataConfig(seed=0, batch_size=2, seq_len=16))
    b = p.next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_motifs_make_data_learnable():
    p = SyntheticPipeline(
        CFG, DataConfig(seed=0, batch_size=64, seq_len=64, motif_prob=1.0)
    )
    b = p.next_batch()
    # with motif_prob=1 every row contains an immediately-repeated span, so
    # label[t] == label[t - motif_len] somewhere measurably above chance
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    hits = (toks[:, 8:] == toks[:, :-8]).mean()
    assert hits > 0.1


def test_frontend_layouts():
    mg = get_config("musicgen_large").reduced()
    p = SyntheticPipeline(mg, DataConfig(batch_size=2, seq_len=16))
    b = p.next_batch()
    assert b["embeds"].shape == (2, 16, mg.d_model)
    assert b["labels"].shape == (2, 16)

    pg = get_config("paligemma_3b").reduced()
    p = SyntheticPipeline(pg, DataConfig(batch_size=2, seq_len=16))
    b = p.next_batch()
    assert b["embeds"].shape == (2, pg.num_prefix, pg.d_model)
    assert b["tokens"].shape == (2, 16 - pg.num_prefix)
    assert b["loss_mask"][:, : pg.num_prefix].sum() == 0
