"""Search algorithms: all must find the optimum of a separable bowl, respect
budgets, never re-evaluate configs, and prune invalid variants."""
import math

import pytest

pytest.importorskip("hypothesis")  # property tests need it; collection must not die
from hypothesis import given, settings, strategies as st

from repro.core import ALGORITHMS, ParamSpace, PowerOfTwoParam, EnumParam, make_search
from repro.core.search.base import INVALID, Trial


def bowl_space():
    return ParamSpace(
        [
            PowerOfTwoParam("bm", 8, 256),
            PowerOfTwoParam("bn", 8, 256),
            EnumParam("order", ["good", "bad"]),
        ]
    )


def bowl_objective(counter=None):
    def f(cfg):
        val = (
            abs(math.log2(cfg["bm"]) - 5) ** 2
            + abs(math.log2(cfg["bn"]) - 4) ** 2
            + (0.0 if cfg["order"] == "good" else 0.7)
            + 1.0
        )
        if counter is not None:
            counter.append(cfg)
        return Trial(config=cfg, objective=val, ok=True)

    return f


OPTIMUM = {"bm": 32, "bn": 16, "order": "good"}


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_finds_optimum(name):
    s = make_search(name, budget=80, seed=3)
    res = s.run(bowl_space(), bowl_objective())
    assert res.best is not None
    assert res.best_config == OPTIMUM, (name, res.best_config)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_budget_respected(name):
    calls = []
    s = make_search(name, budget=13, seed=0)
    res = s.run(bowl_space(), bowl_objective(calls))
    assert res.evaluations <= 13
    assert len(calls) <= 13


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_no_duplicate_evaluations(name):
    calls = []
    s = make_search(name, budget=60, seed=1)
    s.run(bowl_space(), bowl_objective(calls))
    keys = [ParamSpace.config_key(c) for c in calls]
    assert len(keys) == len(set(keys)), f"{name} re-evaluated configs"


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_invalid_variants_pruned(name):
    """Variants that fail (compile error / correctness) must never win."""

    def f(cfg):
        if cfg["order"] == "good":  # the 'best' region is broken
            return Trial(config=cfg, objective=INVALID, ok=False)
        return Trial(config=cfg, objective=1.0 + cfg["bm"] / 1e4, ok=True)

    s = make_search(name, budget=60, seed=0)
    res = s.run(bowl_space(), f)
    assert res.best is not None
    assert res.best.ok
    assert res.best_config["order"] == "bad"


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_coordinate_beats_or_matches_random_on_bowl(seed):
    sp = bowl_space()
    rnd = make_search("random", budget=24, seed=seed).run(sp, bowl_objective())
    coord = make_search("coordinate", budget=24, seed=seed).run(sp, bowl_objective())
    assert coord.best_objective <= rnd.best_objective + 0.71  # within one shelf
