"""kernels/ops.py deployment dispatch: mode switch, DB-driven configs, and
kernel-vs-reference equivalence through the public entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Record, TuningDatabase, make_key, set_default_db
from repro.core.platform import detect_platform
from repro.kernels import ops, ref


@pytest.fixture(autouse=True)
def fresh_db(tmp_path):
    db = TuningDatabase(str(tmp_path / "db.json"))
    set_default_db(db)
    yield db
    ops.set_kernel_mode(False)


def test_reference_mode_is_default():
    assert not ops.kernels_enabled()
    x = jnp.ones((8, 16))
    w = jnp.ones((16, 4))
    np.testing.assert_allclose(ops.matmul(x, w), ref.matmul(x, w))


def test_kernel_mode_matches_reference(rs):
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    ops.set_kernel_mode(True)
    out = ops.matmul(x, w)
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

    xr = jnp.asarray(rs.randn(32, 64), jnp.float32)
    wr = jnp.asarray(rs.randn(64), jnp.float32)
    np.testing.assert_allclose(
        ops.rmsnorm(xr, wr), ref.rmsnorm(xr, wr), rtol=1e-5, atol=1e-5
    )

    logits = jnp.asarray(rs.randn(32, 256) * 2, jnp.float32)
    labels = jnp.asarray(rs.randint(0, 256, 32), jnp.int32)
    np.testing.assert_allclose(
        ops.softmax_xent(logits, labels), ref.softmax_xent(logits, labels),
        rtol=1e-4, atol=1e-4,
    )

    q = jnp.asarray(rs.randn(1, 4, 128, 32) * 0.3, jnp.float32)
    k = jnp.asarray(rs.randn(1, 2, 128, 32) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(1, 2, 128, 32), jnp.float32)
    np.testing.assert_allclose(
        ops.flash_attention(q, k, v, causal=True),
        ref.attention(q, k, v, causal=True),
        rtol=2e-5, atol=2e-5,
    )


def test_db_record_drives_kernel_config(fresh_db, rs):
    """A stored tuning record must be the config the wrapper uses."""
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    key = make_key(
        "matmul", detect_platform().name,
        [tuple(x.shape), tuple(w.shape)], str(x.dtype),
    )
    stored = {"bm": 8, "bn": 128, "bk": 128}
    fresh_db.put(Record(key, stored, 1e-6, "wallclock", 1, 0.0))
    from repro.core import tune_or_lookup
    from repro.kernels.matmul import matmul as matmul_tunable

    assert tune_or_lookup(matmul_tunable, (x, w), db=fresh_db) == stored
    ops.set_kernel_mode(True)
    out = ops.matmul(x, w)  # runs the stored config
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)


def test_explicit_config_override(rs):
    x = jnp.asarray(rs.randn(40, 70), jnp.float32)
    w = jnp.asarray(rs.randn(70, 30), jnp.float32)
    ops.set_kernel_mode(True)
    out = ops.matmul(x, w, config={"bm": 8, "bn": 128, "bk": 128})
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
