"""Deployment dispatch through the runtime API (scoped mode/db, DB-driven
configs, kernel-vs-reference equivalence) + one legacy global-mode shim test.

Every test pins its mode/db with `repro.runtime(...)` scopes, so this file
is environment-agnostic: it passes identically with and without
``REPRO_USE_PALLAS=1`` (the CI dispatch-parity leg runs it with the env var
set).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import Record, TuningDatabase, make_key, set_default_db
from repro.core.platform import detect_platform
from repro.kernels import ops, ref  # ops: legacy-shim test only


@pytest.fixture(autouse=True)
def fresh_global_state(tmp_path):
    """Isolate the two process-global knobs these tests may touch: the
    default database, and the default runtime's mode (the legacy-shim test
    flips it via set_kernel_mode) — restored so no state leaks across tests
    or modules, whatever the REPRO_USE_PALLAS environment."""
    db = TuningDatabase(str(tmp_path / "db.json"))
    set_default_db(db)
    prev_mode = repro.current_runtime().mode     # the root runtime: no scope active
    yield db
    repro.current_runtime().mode = prev_mode


def test_reference_mode_dispatches_reference():
    x = jnp.ones((8, 16))
    w = jnp.ones((16, 4))
    with repro.runtime(mode="reference"):
        assert not repro.current_runtime().kernel_mode_active
        np.testing.assert_allclose(repro.dispatch("matmul", x, w), ref.matmul(x, w))


def test_auto_mode_reads_env(monkeypatch):
    with repro.runtime(mode="auto"):
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
        assert not repro.current_runtime().kernel_mode_active
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        assert repro.current_runtime().kernel_mode_active


def test_kernel_mode_matches_reference(rs):
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)):
        out = repro.dispatch("matmul", x, w)
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

        xr = jnp.asarray(rs.randn(32, 64), jnp.float32)
        wr = jnp.asarray(rs.randn(64), jnp.float32)
        np.testing.assert_allclose(
            repro.dispatch("rmsnorm", xr, wr), ref.rmsnorm(xr, wr), rtol=1e-5, atol=1e-5
        )

        logits = jnp.asarray(rs.randn(32, 256) * 2, jnp.float32)
        labels = jnp.asarray(rs.randint(0, 256, 32), jnp.int32)
        np.testing.assert_allclose(
            repro.dispatch("softmax_xent", logits, labels), ref.softmax_xent(logits, labels),
            rtol=1e-4, atol=1e-4,
        )

        q = jnp.asarray(rs.randn(1, 4, 128, 32) * 0.3, jnp.float32)
        k = jnp.asarray(rs.randn(1, 2, 128, 32) * 0.3, jnp.float32)
        v = jnp.asarray(rs.randn(1, 2, 128, 32), jnp.float32)
        np.testing.assert_allclose(
            repro.dispatch("flash_attention", q, k, v, causal=True),
            ref.attention(q, k, v, causal=True),
            rtol=2e-5, atol=2e-5,
        )


def test_db_record_drives_kernel_config(rs):
    """A stored tuning record must be the config dispatch binds (tier exact)."""
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    db = TuningDatabase(None)
    key = make_key(
        "matmul", detect_platform().name,
        [tuple(x.shape), tuple(w.shape)], str(x.dtype),
    )
    stored = {"bm": 8, "bn": 128, "bk": 128}
    db.put(Record(key, stored, 1e-6, "wallclock", 1, 0.0))

    from repro.kernels.matmul import matmul as matmul_tunable

    with repro.runtime(mode="kernel", db=db) as rt:
        assert rt.resolve(matmul_tunable, (x, w)).config == stored
        out = repro.dispatch("matmul", x, w)  # runs the stored config
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
    tiers = rt.telemetry.snapshot()["tiers"]
    assert tiers.get("exact", 0) >= 1


def test_explicit_config_override(rs):
    x = jnp.asarray(rs.randn(40, 70), jnp.float32)
    w = jnp.asarray(rs.randn(70, 30), jnp.float32)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)) as rt:
        out = repro.dispatch("matmul", x, w, config={"bm": 8, "bn": 128, "bk": 128})
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
    assert rt.telemetry.snapshot()["tiers"] == {"override": 1}


def test_legacy_global_mode_shims(rs):
    """Back-compat: the old process-global API still flips dispatch — and
    every shim (mode flips, reads, and the ops.<kernel> wrappers) now emits
    a DeprecationWarning as the last step of the PR-3 deprecation cycle."""
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    with pytest.warns(DeprecationWarning, match="set_kernel_mode"):
        ops.set_kernel_mode(True)
    with pytest.warns(DeprecationWarning, match="kernels_enabled"):
        assert ops.kernels_enabled()
    with pytest.warns(DeprecationWarning, match="ops.matmul is deprecated"):
        np.testing.assert_allclose(
            ops.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
        )
    with pytest.warns(DeprecationWarning):
        ops.set_kernel_mode(False)
        assert not ops.kernels_enabled()
        np.testing.assert_allclose(ops.matmul(x, w), ref.matmul(x, w))


def test_generated_shim_for_model_tunable_warns():
    """__getattr__-generated shims (model-level tunables) warn too."""
    import repro.models.tunables  # noqa: F401 — registers attn_chunks

    with pytest.warns(DeprecationWarning, match="attn_chunks"):
        fn = ops.attn_chunks
        args, kwargs = repro.core.get_tunable("attn_chunks").dispatch.example()
        with repro.runtime(mode="reference"):
            fn(*args, **kwargs)
