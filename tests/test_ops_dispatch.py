"""Deployment dispatch through the runtime API (scoped mode/db, DB-driven
configs, kernel-vs-reference equivalence).

The legacy global-mode shims (``ops.set_kernel_mode`` / ``ops.<kernel>``)
completed their deprecation cycle and are gone — ``repro.kernels.ops`` is a
migration-guide module only, which `test_ops_module_is_shimless` pins down.

Every test pins its mode/db with `repro.runtime(...)` scopes, so this file
is environment-agnostic: it passes identically with and without
``REPRO_USE_PALLAS=1`` (the CI dispatch-parity leg runs it with the env var
set).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import Record, TuningDatabase, make_key, set_default_db
from repro.core.platform import detect_platform
from repro.kernels import ref


@pytest.fixture(autouse=True)
def fresh_global_state(tmp_path):
    """Isolate the process-global default database so no state leaks across
    tests or modules, whatever the REPRO_USE_PALLAS environment."""
    db = TuningDatabase(str(tmp_path / "db.json"))
    set_default_db(db)
    yield db


def test_reference_mode_dispatches_reference():
    x = jnp.ones((8, 16))
    w = jnp.ones((16, 4))
    with repro.runtime(mode="reference"):
        assert not repro.current_runtime().kernel_mode_active
        np.testing.assert_allclose(repro.dispatch("matmul", x, w), ref.matmul(x, w))


def test_auto_mode_reads_env(monkeypatch):
    with repro.runtime(mode="auto"):
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
        assert not repro.current_runtime().kernel_mode_active
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        assert repro.current_runtime().kernel_mode_active


def test_kernel_mode_matches_reference(rs):
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)):
        out = repro.dispatch("matmul", x, w)
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

        xr = jnp.asarray(rs.randn(32, 64), jnp.float32)
        wr = jnp.asarray(rs.randn(64), jnp.float32)
        np.testing.assert_allclose(
            repro.dispatch("rmsnorm", xr, wr), ref.rmsnorm(xr, wr), rtol=1e-5, atol=1e-5
        )

        logits = jnp.asarray(rs.randn(32, 256) * 2, jnp.float32)
        labels = jnp.asarray(rs.randint(0, 256, 32), jnp.int32)
        np.testing.assert_allclose(
            repro.dispatch("softmax_xent", logits, labels), ref.softmax_xent(logits, labels),
            rtol=1e-4, atol=1e-4,
        )

        q = jnp.asarray(rs.randn(1, 4, 128, 32) * 0.3, jnp.float32)
        k = jnp.asarray(rs.randn(1, 2, 128, 32) * 0.3, jnp.float32)
        v = jnp.asarray(rs.randn(1, 2, 128, 32), jnp.float32)
        np.testing.assert_allclose(
            repro.dispatch("flash_attention", q, k, v, causal=True),
            ref.attention(q, k, v, causal=True),
            rtol=2e-5, atol=2e-5,
        )


def test_db_record_drives_kernel_config(rs):
    """A stored tuning record must be the config dispatch binds (tier exact)."""
    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    db = TuningDatabase(None)
    key = make_key(
        "matmul", detect_platform().name,
        [tuple(x.shape), tuple(w.shape)], str(x.dtype),
    )
    stored = {"bm": 8, "bn": 128, "bk": 128}
    db.put(Record(key, stored, 1e-6, "wallclock", 1, 0.0))

    from repro.kernels.matmul import matmul as matmul_tunable

    with repro.runtime(mode="kernel", db=db) as rt:
        assert rt.resolve(matmul_tunable, (x, w)).config == stored
        out = repro.dispatch("matmul", x, w)  # runs the stored config
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
    tiers = rt.telemetry.snapshot()["tiers"]
    assert tiers.get("exact", 0) >= 1


def test_explicit_config_override(rs):
    x = jnp.asarray(rs.randn(40, 70), jnp.float32)
    w = jnp.asarray(rs.randn(70, 30), jnp.float32)
    with repro.runtime(mode="kernel", db=TuningDatabase(None)) as rt:
        out = repro.dispatch("matmul", x, w, config={"bm": 8, "bn": 128, "bk": 128})
        np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
    assert rt.telemetry.snapshot()["tiers"] == {"override": 1}


def test_ops_module_is_shimless():
    """The deprecation cycle is over: importing repro.kernels.ops still
    populates the registry (one-stop import) but exposes NO runtime shims —
    reaching for the removed global-mode API is an AttributeError, not a
    silently-deprecated call."""
    from repro.kernels import ops

    for gone in ("set_kernel_mode", "kernels_enabled", "matmul",
                 "flash_attention", "rmsnorm", "softmax_xent", "attn_chunks"):
        assert not hasattr(ops, gone), gone
    # the registry side effect is intact: all kernel tunables registered
    names = set(repro.core.registered())
    assert {"matmul", "flash_attention", "rmsnorm", "softmax_xent",
            "flash_attention_bwd", "rmsnorm_bwd", "softmax_xent_bwd"} <= names
