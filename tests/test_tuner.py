"""End-to-end tuner tests: the paper's §2 loop on a real (tiny) tunable."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Constraint,
    EnumParam,
    ParamSpace,
    PowerOfTwoParam,
    Record,
    TuningDatabase,
    WallClockEvaluator,
    autotune,
    correctness_gate,
    make_key,
    shape_bucket,
    tunable,
    tune_or_lookup,
)
from repro.core.search import ExhaustiveSearch


def make_toy_tunable(name="toy_sum"):
    space = ParamSpace([PowerOfTwoParam("chunk", 8, 64), EnumParam("mode", ["a", "b"])])

    def ref(x):
        return jnp.sum(x * x)

    @tunable(name, space=space, reference=ref)
    def toy(x, *, chunk, mode):
        if mode == "b":  # wrong math: must be pruned by the gate
            return jnp.sum(x)
        n = x.shape[0]
        pad = (-n) % chunk
        xp = jnp.pad(x, (0, pad))
        return jnp.sum((xp * xp).reshape(-1, chunk).sum(1))

    return toy


def test_autotune_rejects_incorrect_variants(tmp_path):
    toy = make_toy_tunable("toy1")
    db = TuningDatabase(str(tmp_path / "db.json"))
    x = jnp.asarray(np.random.RandomState(0).randn(100), jnp.float32)
    res = autotune(
        toy, (x,), search=ExhaustiveSearch(budget=100),
        evaluator=WallClockEvaluator(repeats=1, warmup=0), db=db,
    )
    assert res.best_config["mode"] == "a"  # 'b' variants fail the gate
    trials_b = [t for t in res.search.trials if t.config["mode"] == "b"]
    assert trials_b and all(not t.ok for t in trials_b)


def test_tune_or_lookup_roundtrip(tmp_path):
    toy = make_toy_tunable("toy2")
    db = TuningDatabase(str(tmp_path / "db.json"))
    x = jnp.asarray(np.random.RandomState(0).randn(64), jnp.float32)
    res = autotune(
        toy, (x,), search=ExhaustiveSearch(budget=100),
        evaluator=WallClockEvaluator(repeats=1, warmup=0), db=db,
    )
    # DB hit returns the stored winner without tuning
    cfg = tune_or_lookup(toy, (x,), db=db, allow_tune=False)
    assert cfg == res.best_config
    # same shape bucket (65 -> 128 vs 64) is a different key
    y = jnp.asarray(np.random.RandomState(0).randn(65), jnp.float32)
    cfg2 = tune_or_lookup(toy, (y,), db=db, allow_tune=False)
    assert cfg2 == toy.default_config(y)  # miss -> heuristic default


def test_database_persistence_and_better_record_wins(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDatabase(path)
    key = make_key("k", "cpu-host", [(64, 64)], "float32")
    db.put(Record(key, {"a": 1}, 2.0, "wallclock", 5, 0.0))
    db.put(Record(key, {"a": 2}, 5.0, "wallclock", 5, 1.0))  # worse: ignored
    db2 = TuningDatabase(path)
    assert db2.lookup(key).config == {"a": 1}
    db.put(Record(key, {"a": 3}, 1.0, "wallclock", 5, 2.0))  # better: replaces
    assert TuningDatabase(path).lookup(key).config == {"a": 3}


def test_platform_key_isolation(tmp_path):
    db = TuningDatabase(str(tmp_path / "db.json"))
    k_cpu = make_key("k", "cpu-host", [(64,)], "f32")
    k_tpu = make_key("k", "tpu-v5e", [(64,)], "f32")
    db.put(Record(k_cpu, {"a": 1}, 1.0, "wallclock", 1, 0.0))
    assert db.lookup(k_tpu) is None
    assert db.platforms() == {"cpu-host": 1}


def test_shape_bucketing():
    assert shape_bucket((5,)) == (5,)           # small dims exact
    assert shape_bucket((100,)) == (128,)
    assert shape_bucket((128,)) == (128,)
    assert shape_bucket((129, 1000)) == (256, 1024)


def test_correctness_gate():
    a = jnp.ones((4, 4))
    assert correctness_gate(a, a + 1e-7)
    assert not correctness_gate(a, a + 1.0)
    assert not correctness_gate(a, jnp.ones((4, 5)))
    assert not correctness_gate(jnp.full((2,), jnp.nan), jnp.ones((2,)))


def test_variant_invalid_config_raises():
    toy = make_toy_tunable("toy3")
    with pytest.raises(ValueError):
        toy.variant(chunk=7, mode="a")
