"""Chaos suite: seeded fault plans against the real serving / training /
campaign stacks, asserting the fault-isolation contracts end to end.

The acceptance gate for the dispatch guard: a kernel-mode engine with
injected kernel faults on every tunable the model dispatches (matmul,
rmsnorm, flash_attention) serves a request batch with outputs IDENTICAL to
a fault-free reference engine — the guard absorbs each fault at trace time,
quarantines the bucket, and bakes the reference implementation into the
compiled program, so degradation is invisible except in telemetry.

Everything here is deterministic: fault plans are seeded, traffic is
seeded, and every drill asserts exactly which faults fired.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.campaign import CampaignManifest, plan_jobs, run_campaign
from repro.campaign.scheduler import build_manifest
from repro.configs import get_config
from repro.core import Record, TunedRuntime, TuningDatabase
from repro.core.evaluate import Evaluator, Measurement
from repro.data.pipeline import DataConfig
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.obs.export import format_snapshot
from repro.optim import adamw
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.testing import FaultPlan, FaultRule
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import Trainer, TrainerConfig

RUN = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16)
MAX_SEQ = 64
# (prompt_len, max_new, prompt_seed) — a small mixed batch
SCHEDULE = ((3, 6, 0), (9, 5, 1), (12, 4, 2))
# every tunable the reduced qwen2 serving path dispatches in kernel mode
SERVING_TUNABLES = ("matmul", "rmsnorm", "flash_attention")


def _prompt(cfg, length, seed):
    rs = np.random.RandomState(10_000 + 17 * length + seed)
    return rs.randint(0, cfg.vocab_size, length).astype(np.int32)


def _serve_schedule(cfg, eng):
    for length, max_new, seed in SCHEDULE:
        assert eng.submit(Request(prompt=_prompt(cfg, length, seed),
                                  max_new_tokens=max_new))
    done = eng.serve()
    assert len(done) == len(SCHEDULE), "a request was lost to a fault"
    return [r.output for r in done]


@pytest.fixture(scope="module")
def served_ref():
    """Model + the fault-free reference baseline for SCHEDULE."""
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    ref_eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=3, max_seq=MAX_SEQ),
        runtime=TunedRuntime(mode="reference", name="chaos-ref"),
    )
    return cfg, params, _serve_schedule(cfg, ref_eng)


# ---------------------------------------------------------------------------
# The serving gate: guarded dispatch under kernel faults
# ---------------------------------------------------------------------------


def test_guarded_engine_with_faulted_kernels_matches_reference(
    served_ref, tmp_path
):
    cfg, params, ref_out = served_ref
    rt = TunedRuntime(
        db=TuningDatabase(None), mode="kernel", guard=True, name="chaos-kern"
    )
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=3, max_seq=MAX_SEQ), runtime=rt,
    )
    plan = FaultPlan(
        [FaultRule(site=f"dispatch.kernel:{k}") for k in SERVING_TUNABLES],
        seed=1, name="serving-chaos",
    )
    col = obs.collect(name="chaos-serve")
    with col, plan:
        out = _serve_schedule(cfg, eng)

    # The contract: byte-for-byte the reference engine's tokens, no request
    # dropped, no exception surfaced to the caller — only telemetry knows.
    for got, want in zip(out, ref_out):
        np.testing.assert_array_equal(got, want)

    # Every serving tunable faulted at least once and was quarantined.
    assert {s.split(":")[1] for s, _, _ in plan.fired} == set(SERVING_TUNABLES)
    snap = rt.telemetry.snapshot()
    assert snap["tiers"].get("reference", 0) >= len(SERVING_TUNABLES)
    assert len(rt.health) >= len(SERVING_TUNABLES)
    quarantine_warns = [
        e for e in col.events("warning") if e["name"] == "dispatch.quarantine"
    ]
    assert quarantine_warns, "quarantine must be visible in the event log"
    assert all("InjectedFault" in e["error"] for e in quarantine_warns)

    # Satellite: the quarantine counter surfaces through every obs exporter.
    osnap = col.snapshot()
    assert "dispatch.quarantine" in osnap["counters"]
    assert "dispatch.quarantine" in format_snapshot(osnap)
    prom = str(tmp_path / "chaos.prom")
    col.write_prom(prom)
    with open(prom) as f:
        assert "dispatch_quarantine" in f.read()


def test_unguarded_fault_degrades_engine_not_requests(served_ref):
    """A fault the dispatch guard cannot absorb (guard=False: the operator
    opted out) escapes into the engine, which flips onto its reference
    fallback jits and still completes every request bit-identically."""
    cfg, params, ref_out = served_ref
    rt = TunedRuntime(
        db=TuningDatabase(None), mode="kernel", guard=False,
        name="chaos-unguarded",
    )
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=3, max_seq=MAX_SEQ), runtime=rt,
    )
    plan = FaultPlan([FaultRule(site="dispatch.kernel:*")], name="unguarded")
    col = obs.collect(name="chaos-degrade")
    with col, plan:
        out = _serve_schedule(cfg, eng)
    for got, want in zip(out, ref_out):
        np.testing.assert_array_equal(got, want)

    assert eng.degraded
    assert eng.stats["degraded_calls"] > 0
    assert any(e["name"] == "serve.degraded" for e in col.events("warning"))
    # sticky until an operator re-arms it
    eng.reset_degraded()
    assert not eng.degraded


def test_submit_sheds_with_structured_response_at_max_queue(served_ref):
    cfg, params, _ = served_ref
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=1, max_seq=MAX_SEQ, max_queue=1),
    )
    first = Request(prompt=_prompt(cfg, 3, 0), max_new_tokens=2)
    extra = Request(prompt=_prompt(cfg, 3, 1), max_new_tokens=2)
    col = obs.collect(name="chaos-shed")
    with col:
        assert eng.submit(first) is True
        assert eng.submit(extra) is False
    assert extra.shed and "queue_full" in extra.shed_reason
    assert not first.shed
    assert eng.stats["requests_shed"] == 1
    assert "serve.shed" in col.snapshot()["counters"]
    # the shed is backpressure, not corruption: the queued request serves
    (done,) = eng.serve()
    assert done is first and len(done.output) == 2


# ---------------------------------------------------------------------------
# Training: injected step faults recover to the fault-free trajectory
# ---------------------------------------------------------------------------

CFG_TRAIN = get_config("qwen2_0_5b").reduced()
DATA = DataConfig(seed=0, batch_size=8, seq_len=32)
OPT = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, grad_clip=1.0)


def _make_trainer(tmp_path, steps):
    run = dataclasses.replace(RUN, microbatches=1)
    return Trainer(
        CFG_TRAIN, run, make_host_mesh(), Layout(), DATA, OPT,
        TrainerConfig(
            total_steps=steps, checkpoint_every=5,
            checkpoint_dir=str(tmp_path / "ckpt"), async_checkpoint=False,
        ),
    )


def test_injected_step_faults_recover_to_same_loss(tmp_path):
    steps = 10
    clean = _make_trainer(tmp_path / "clean", steps)
    clean_final = None
    for _ in range(steps):
        clean_final = clean.run_one_step()["loss"]

    chaotic = _make_trainer(tmp_path / "chaos", steps)
    plan = FaultPlan(
        [FaultRule(site="train.step:7", times=1, message="injected node loss")]
    )
    with plan:
        metrics = chaotic.train()
    assert plan.count("train.step:7") == 1, "the drill must actually fire"
    assert chaotic.step == steps
    # restore-and-replay reconverges on the uninterrupted trajectory
    assert abs(metrics["loss"] - clean_final) < 1e-5, (
        metrics["loss"], clean_final,
    )


# ---------------------------------------------------------------------------
# Checkpointer: async write failures surface on the training thread
# ---------------------------------------------------------------------------


def test_async_checkpoint_write_failure_surfaces_and_never_commits(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=5)
    tree = {"w": np.arange(8, dtype=np.float32)}
    # the write runs on the background thread: install(), don't scope
    plan = FaultPlan([
        FaultRule(site="checkpoint.write:2", message="disk full"),
        FaultRule(site="checkpoint.write:4", message="disk full again"),
    ])
    plan.install()
    try:
        ckpt.save_async(1, tree)
        ckpt.wait()                                   # step 1: fine
        ckpt.save_async(2, tree)
        with pytest.raises(RuntimeError, match="async checkpoint failed"):
            ckpt.wait()                               # surfaced, not swallowed
        assert ckpt.all_steps() == [1], "a failed write must never commit"
        # the NEXT save_async also surfaces a pending failure (it waits first)
        ckpt.save_async(4, tree)
        with pytest.raises(RuntimeError, match="async checkpoint failed"):
            ckpt.save_async(5, tree)
        assert plan.count("checkpoint.write:*") == 2
        # and the error is cleared once raised: the pipeline keeps going
        ckpt.save_async(6, tree)
        ckpt.wait()
        assert ckpt.all_steps() == [1, 6]
    finally:
        plan.uninstall()


# ---------------------------------------------------------------------------
# Campaign: retries, poison quarantine, timeouts, interrupt flush
# ---------------------------------------------------------------------------

_ARCHES = ["qwen2_0_5b"]
_PLAN_KW = dict(
    train_shapes=("train_4k",), serving=(2, 32), reduced=True,
    max_tokens=64, max_seq=32,
)


class SurrogateEvaluator(Evaluator):
    """Config-only objective: campaign mechanics without timing noise."""

    name = "surrogate"

    def evaluate(self, fn, args, reference=None):
        import math

        config = getattr(fn, "keywords", {})
        score = 0.05
        for v in config.values():
            if isinstance(v, (int, float)) and v > 0:
                score += abs(math.log2(v) - math.log2(64))
        return Measurement(score, True)


class InterruptingEvaluator(SurrogateEvaluator):
    """Delivers SIGINT (as KeyboardInterrupt) after N evaluations."""

    def __init__(self, after: int):
        self.after = after
        self.calls = 0

    def evaluate(self, fn, args, reference=None):
        self.calls += 1
        if self.calls > self.after:
            raise KeyboardInterrupt("operator ctrl-C")
        return super().evaluate(fn, args, reference)


def _mini_manifest(tmp_path, name, kernels=("rmsnorm",), budget=20):
    jobs = plan_jobs(_ARCHES, kernels=kernels, **_PLAN_KW)
    m = build_manifest(jobs, total_budget=10_000, path=str(tmp_path / name))
    for j in m.jobs:
        j.budget = budget
    m.save()
    return m


def test_job_retry_then_succeed_banks_attempts(tmp_path):
    m = _mini_manifest(tmp_path, "m.json")
    db = TuningDatabase(str(tmp_path / "db.json"))
    with FaultPlan([FaultRule(site="campaign.job:*", times=1)]) as plan:
        run_campaign(m, db, evaluator=SurrogateEvaluator(), max_jobs=1,
                     max_attempts=3)
    assert plan.count("campaign.job:*") == 1
    done = [j for j in m.jobs if j.status == "done"]
    assert len(done) == 1 and done[0].attempts == 2 and done[0].error == ""
    # persisted: a resume sees the banked attempt count
    m2 = CampaignManifest.load(str(tmp_path / "m.json"))
    assert [j.attempts for j in m2.jobs if j.status == "done"] == [2]


def test_job_exhausting_attempts_is_poisoned_and_resume_skips_it(tmp_path):
    m = _mini_manifest(tmp_path, "m.json")
    db = TuningDatabase(str(tmp_path / "db.json"))
    n_jobs = len(m.jobs)
    col = obs.collect(name="chaos-campaign")
    with col, FaultPlan([FaultRule(site="campaign.job:*")]) as plan:
        summary = run_campaign(m, db, evaluator=SurrogateEvaluator(),
                               max_jobs=1, max_attempts=2)
    assert plan.count("campaign.job:*") == 2          # both attempts failed
    assert summary["poisoned"] == 1
    poisoned = [j for j in m.jobs if j.status == "poisoned"]
    assert len(poisoned) == 1
    assert poisoned[0].attempts == 2
    assert "InjectedFault" in poisoned[0].error
    assert any(e["name"] == "campaign.job_poisoned"
               for e in col.events("warning"))

    # fault cleared, campaign resumed: the poison pill is never re-run
    m2 = CampaignManifest.load(str(tmp_path / "m.json"))
    assert m2.counts()["poisoned"] == 1
    summary = run_campaign(m2, TuningDatabase(str(tmp_path / "db.json")),
                           evaluator=SurrogateEvaluator())
    assert summary["done"] == n_jobs - 1
    assert summary["poisoned"] == 1


def test_job_timeout_bounds_a_stuck_job(tmp_path):
    m = _mini_manifest(tmp_path, "m.json")
    db = TuningDatabase(str(tmp_path / "db.json"))
    # first attempt of the first job hangs (well past the timeout); with a
    # job_timeout the attempt body runs on a worker thread, so the plan must
    # be installed process-globally, not contextvar-scoped
    plan = FaultPlan(
        [FaultRule(site="campaign.job:*", kind="latency", delay_s=1.5, times=1)]
    )
    plan.install()
    try:
        run_campaign(m, db, evaluator=SurrogateEvaluator(), max_jobs=1,
                     job_timeout=0.2, max_attempts=1)
    finally:
        plan.uninstall()
    stuck = [j for j in m.jobs if j.status == "poisoned"]
    assert len(stuck) == 1
    assert "exceeded --job-timeout" in stuck[0].error


def test_keyboard_interrupt_flushes_manifest_and_telemetry(tmp_path):
    m = _mini_manifest(tmp_path, "m.json")
    db = TuningDatabase(str(tmp_path / "db.json"))
    with pytest.raises(KeyboardInterrupt):
        run_campaign(m, db, evaluator=InterruptingEvaluator(after=3))

    # the manifest on disk reflects the interrupt exactly: nothing done,
    # the in-flight job still pending with its attempt banked, telemetry
    # and the interrupted marker flushed for the post-mortem.
    m2 = CampaignManifest.load(str(tmp_path / "m.json"))
    assert m2.counts()["done"] == 0
    inflight = [j for j in m2.jobs if j.attempts > 0]
    assert len(inflight) == 1 and inflight[0].status == "pending"
    assert m2.meta.get("interrupted")       # stamped (interrupt timestamp)
    assert "telemetry" in m2.meta

    # resume runs to completion, re-running the interrupted job
    summary = run_campaign(m2, TuningDatabase(str(tmp_path / "db.json")),
                           evaluator=SurrogateEvaluator())
    assert summary["done"] == len(m2.jobs) and summary["poisoned"] == 0
    assert [j for j in m2.jobs if j.attempts == 2]    # the replayed one
