"""Dispatch-runtime tests: registry parity, scoped contexts, policies.

Registry parity is the zero-boilerplate guarantee: for EVERY tunable that
declares a dispatch example, the auto-generated entry point must match the
reference implementation in both modes (kernel mode runs the Pallas kernels
in interpret mode on CPU) — a new kernel gets this coverage by adding one
``DispatchSpec(example=...)`` field, with no test edits.

Everything here pins mode/db via `repro.runtime(...)` scopes, so the file
is environment-agnostic (the CI dispatch-parity leg re-runs it with
``REPRO_USE_PALLAS=1``).
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (
    CoverSet,
    ExactHit,
    Heuristic,
    Record,
    Reference,
    TunedRuntime,
    TuningDatabase,
    make_key,
    registered,
)
from repro.core.platform import detect_platform
from repro.core.runtime import dispatch, entry_point
from repro.core.tuner import _args_key, promoted_dtype

# Populate the registry (kernels + model-level tunables) for parametrize.
import repro.kernels  # noqa: F401
import repro.models.tunables  # noqa: F401

DISPATCHABLE = sorted(
    name
    for name, t in registered().items()
    if t.dispatch is not None and t.dispatch.example is not None
)


def _fresh(mode):
    """A pinned scope: given mode, empty in-memory db (no env leakage)."""
    return repro.runtime(mode=mode, db=TuningDatabase(None))


# ---------------------------------------------------------------------------
# Registry parity: auto-generated dispatch ≡ reference, both modes
# ---------------------------------------------------------------------------


def test_registry_covers_all_pallas_kernels():
    # The Pallas kernels (forward AND backward plane) + the model-level
    # chunked attention must all be deployable through the registry with
    # example args.
    assert {"matmul", "flash_attention", "rmsnorm", "softmax_xent",
            "attn_chunks", "flash_attention_bwd", "rmsnorm_bwd",
            "softmax_xent_bwd"} <= set(DISPATCHABLE)


def _assert_trees_close(out, expected, rtol=0.0, atol=0.0):
    """Leaf-wise allclose: backward tunables return tuples of gradients
    with heterogeneous shapes, so a bare np.asarray comparison cannot work."""
    import jax

    o_leaves = jax.tree_util.tree_leaves(out)
    e_leaves = jax.tree_util.tree_leaves(expected)
    assert len(o_leaves) == len(e_leaves)
    for o, e in zip(o_leaves, e_leaves):
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(e, np.float32),
            rtol=rtol, atol=atol,
        )


@pytest.mark.parametrize("name", DISPATCHABLE)
def test_parity_reference_mode(name):
    t = registered()[name]
    args, kwargs = t.dispatch.example()
    expected = t.dispatch.reference_for(t)(*args, **kwargs)
    with _fresh("reference") as rt:
        out = dispatch(name, *args, **kwargs)
    _assert_trees_close(out, expected)
    assert rt.telemetry.snapshot()["tiers"] == {"reference": 1}


@pytest.mark.parametrize("name", DISPATCHABLE)
def test_parity_kernel_mode(name):
    t = registered()[name]
    args, kwargs = t.dispatch.example()
    expected = t.dispatch.reference_for(t)(*args, **kwargs)
    with _fresh("kernel"):
        out = dispatch(name, *args, **kwargs)
    _assert_trees_close(out, expected, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", DISPATCHABLE)
def test_parity_entry_point_matches_dispatch(name):
    t = registered()[name]
    args, kwargs = t.dispatch.example()
    fn = entry_point(name)
    with _fresh("kernel"):
        _assert_trees_close(
            fn(*args, **kwargs), dispatch(name, *args, **kwargs)
        )


# ---------------------------------------------------------------------------
# Scoped contexts: nesting, inheritance, thread isolation
# ---------------------------------------------------------------------------


def _matmul_args():
    x = jnp.ones((64, 128), jnp.float32)
    w = jnp.ones((128, 64), jnp.float32)
    return x, w


def _matmul_db(bm):
    db = TuningDatabase(None)
    key = make_key(
        "matmul", detect_platform().name, [(64, 128), (128, 64)], "float32"
    )
    db.put(Record(key, {"bm": bm, "bn": 128, "bk": 128}, 1e-6, "wallclock", 1, 0.0))
    return db


def test_nested_runtime_scoping():
    from repro.kernels.matmul import matmul as matmul_tunable

    x, w = _matmul_args()
    outer_db, inner_db = _matmul_db(bm=8), _matmul_db(bm=64)
    root = repro.current_runtime()
    with repro.runtime(db=outer_db, mode="kernel") as outer:
        assert repro.current_runtime() is outer
        assert outer.resolve(matmul_tunable, (x, w)).config["bm"] == 8
        with repro.runtime(db=inner_db) as inner:
            assert repro.current_runtime() is inner
            assert inner.mode == "kernel"          # inherited from outer
            assert inner.resolve(matmul_tunable, (x, w)).config["bm"] == 64
        # inner popped: outer's db (and its resolution cache) are back
        assert repro.current_runtime() is outer
        assert outer.resolve(matmul_tunable, (x, w)).config["bm"] == 8
    assert repro.current_runtime() is root


def test_nested_override_mode_keeps_db():
    with repro.runtime(db=_matmul_db(bm=8), mode="kernel") as outer:
        with repro.runtime(mode="reference") as inner:
            assert inner.db is outer.db
            assert not inner.kernel_mode_active
        assert outer.kernel_mode_active


def test_thread_isolation_fresh_thread_sees_no_scope():
    seen = {}

    def worker():
        seen["rt"] = repro.current_runtime()

    with repro.runtime(mode="kernel") as rt:
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert repro.current_runtime() is rt
    # A fresh thread starts at the process default, not inside our scope.
    assert seen["rt"] is not rt


def test_thread_isolation_no_cross_talk():
    barrier = threading.Barrier(2, timeout=10)
    seen = {}

    def worker(tag):
        with repro.runtime(mode="kernel", name=tag) as rt:
            barrier.wait()              # both threads are inside their scope
            seen[tag] = repro.current_runtime() is rt
            barrier.wait()

    ts = [threading.Thread(target=worker, args=(f"t{i}",)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert seen == {"t0": True, "t1": True}


# ---------------------------------------------------------------------------
# Policy pipeline + telemetry + resolution cache
# ---------------------------------------------------------------------------


def test_exact_or_reference_policy(rs):
    """Trimmed pipeline: measured configs or reference — never heuristic."""
    from repro.kernels import ref

    x = jnp.asarray(rs.randn(64, 128), jnp.float32)
    w = jnp.asarray(rs.randn(128, 64), jnp.float32)
    db = TuningDatabase(None)
    with repro.runtime(
        db=db, mode="kernel", policy=(ExactHit(), Reference())
    ) as rt:
        out = dispatch("matmul", x, w)      # no record -> reference executes
        np.testing.assert_allclose(out, ref.matmul(x, w))
        assert rt.telemetry.snapshot()["tiers"] == {"reference": 1}

        key = make_key(
            "matmul", detect_platform().name, [(64, 128), (128, 64)], "float32"
        )
        db.put(Record(key, {"bm": 8, "bn": 128, "bk": 128}, 1e-6, "w", 1, 0.0))
        rt.clear_cache()
        dispatch("matmul", x, w)            # now the record serves it
        assert rt.telemetry.snapshot()["tiers"]["exact"] == 1


def test_telemetry_tier_accounting():
    """exact vs cover vs heuristic per kernel×bucket — the paper's
    sustained-performance accounting."""
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_tunable

    platform = detect_platform().name
    db = TuningDatabase(None)
    w = jnp.ones((32,), jnp.float32)
    x_exact = jnp.ones((64, 32), jnp.float32)
    x_cover = jnp.ones((256, 32), jnp.float32)
    key = make_key("rmsnorm", platform, [(64, 32), (32,)], "float32")
    db.put(Record(key, {"block_rows": 8}, 1e-6, "wallclock", 1, 0.0))
    db.put_cover(
        "rmsnorm", platform,
        [{"config": {"block_rows": 16}, "support": [[[128, 32], [32]]],
          "share": 1.0}],
    )
    with repro.runtime(db=db, mode="kernel") as rt:
        assert rt.resolve(rmsnorm_tunable, (x_exact, w)).tier == "exact"
        assert rt.resolve(rmsnorm_tunable, (x_cover, w)).tier == "cover"
        # empty-db kernel: heuristic tier
        from repro.kernels.matmul import matmul as matmul_tunable

        assert rt.resolve(matmul_tunable, _matmul_args()).tier == "heuristic"
    snap = rt.telemetry.snapshot()
    assert snap["tiers"] == {"exact": 1, "cover": 1, "heuristic": 1}
    assert any(k.startswith("rmsnorm|") for k in snap["by_key"])


def test_resolution_cache_hits_and_invalidation():
    from repro.kernels.matmul import matmul as matmul_tunable

    x, w = _matmul_args()
    db = _matmul_db(bm=8)
    with repro.runtime(db=db, mode="kernel") as rt:
        r1 = rt.resolve(matmul_tunable, (x, w))
        r2 = rt.resolve(matmul_tunable, (x, w))
        assert r1.config == r2.config
        assert rt.cache_size == 1
        snap = rt.telemetry.snapshot()
        assert snap["calls"] == 2 and snap["cache_hits"] == 1

        # A db update is invisible until the cache is cleared (documented).
        key = make_key(
            "matmul", detect_platform().name, [(64, 128), (128, 64)], "float32"
        )
        db.put(Record(key, {"bm": 64, "bn": 128, "bk": 128}, 1e-9, "w", 1, 1.0))
        assert rt.resolve(matmul_tunable, (x, w)).config["bm"] == 8
        rt.clear_cache()
        assert rt.resolve(matmul_tunable, (x, w)).config["bm"] == 64


def test_runtime_rejects_bad_mode():
    with pytest.raises(ValueError):
        TunedRuntime(mode="turbo")


def test_reference_mode_wins_over_explicit_config(rs):
    """config= must not force a kernel in reference mode (the multi-pod
    dry-run escape hatch, same precedence as the old ops.* wrappers)."""
    from repro.kernels import ref

    x = jnp.asarray(rs.randn(16, 32), jnp.float32)
    w = jnp.asarray(rs.randn(32, 8), jnp.float32)
    with repro.runtime(mode="reference") as rt:
        out = dispatch("matmul", x, w, config={"bm": 8, "bn": 128, "bk": 128})
    np.testing.assert_allclose(out, ref.matmul(x, w))
    assert rt.telemetry.snapshot()["tiers"] == {"reference": 1}


def test_default_db_swap_invalidates_cached_resolution():
    """A db=None runtime resolves against default_db() *at call time*:
    set_default_db mid-session must not be shadowed by the cache."""
    from repro.core import set_default_db
    from repro.core.database import default_db
    from repro.kernels.matmul import matmul as matmul_tunable

    x, w = _matmul_args()
    prev = default_db()
    try:
        set_default_db(TuningDatabase(None))
        with repro.runtime(mode="kernel") as rt:
            assert rt.db is None                   # inherited ambient default
            assert rt.resolve(matmul_tunable, (x, w)).tier == "heuristic"
            set_default_db(_matmul_db(bm=8))       # campaign artifact arrives
            res = rt.resolve(matmul_tunable, (x, w))
            assert res.tier == "exact" and res.config["bm"] == 8
    finally:
        set_default_db(prev)


def test_shared_runtime_interleaved_asyncio_tasks():
    """Two tasks on ONE thread entering the same runtime, exits interleaved
    (A enters, B enters, A exits while B is still inside): context-local
    stacks must not cross."""
    import asyncio

    rt = TunedRuntime(mode="kernel", name="shared-async")

    async def task(entered, may_exit):
        with rt:
            assert repro.current_runtime() is rt
            entered.set()
            await may_exit.wait()
            assert repro.current_runtime() is rt

    async def main():
        a_in, a_out = asyncio.Event(), asyncio.Event()
        b_in, b_out = asyncio.Event(), asyncio.Event()
        ta = asyncio.create_task(task(a_in, a_out))
        tb = asyncio.create_task(task(b_in, b_out))
        await a_in.wait()
        await b_in.wait()
        a_out.set()               # A exits first, B still inside its scope
        await ta
        b_out.set()
        await tb

    asyncio.run(main())


def test_warmup_resolves_against_passed_db_without_install():
    """warmup(db, install=False) must consult the passed artifact (old
    tune_or_lookup semantics), not the ambient default database."""
    import jax
    from repro.configs import get_config
    from repro.core.database import default_db
    from repro.distributed.sharding import Layout
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.models.transformer import RunConfig
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(
        cfg, RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16),
        params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=32),     # no pinned runtime
    )
    platform = detect_platform().name
    # decode-pool rmsnorm bucket: x=[max_batch, d_model], w=[d_model]
    key = make_key("rmsnorm", platform,
                   [(2, cfg.d_model), (cfg.d_model,)], "float32")
    art = TuningDatabase(None)
    art.put(Record(key, {"block_rows": 8}, 1e-6, "wallclock", 1, 0.0))

    prev_default = default_db()
    resolved = eng.warmup(db=art, install=False, max_tokens=2048)
    assert default_db() is prev_default            # nothing installed
    assert resolved[key] == {"block_rows": 8}      # artifact WAS consulted


def test_shared_runtime_entered_from_two_threads():
    """One engine-pinned runtime may wrap calls on several serving threads:
    entry tokens are per-thread, so interleaved enter/exit must not blow up."""
    rt = TunedRuntime(mode="kernel", name="shared")
    barrier = threading.Barrier(2, timeout=10)
    errors = []

    def worker():
        try:
            for _ in range(50):
                with rt:
                    barrier.wait()
                    assert repro.current_runtime() is rt
                    barrier.wait()
        except Exception as e:  # noqa: BLE001 - surface any token mismatch
            errors.append(e)
            try:
                barrier.abort()
            except Exception:
                pass

    ts = [threading.Thread(target=worker) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors


# ---------------------------------------------------------------------------
# Warmed engine: per-tier accounting end-to-end (acceptance criterion)
# ---------------------------------------------------------------------------


def test_warmed_serving_engine_reports_tiers():
    """warmup() resolves every slot-pool bucket through the engine's runtime;
    serve-time dispatch runs under the same scope — telemetry shows per-tier
    hit counts for the whole run."""
    import jax
    from repro.configs import get_config
    from repro.distributed.sharding import Layout
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.models.transformer import RunConfig
    from repro.serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    # Pinned mode keeps this env-agnostic (reference path on the CPU host).
    rt = repro.runtime(mode="reference", db=TuningDatabase(None), name="test-engine")
    eng = ServingEngine(
        cfg, RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16),
        params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=64), runtime=rt,
    )
    resolved = eng.warmup(max_tokens=2048)
    assert resolved and all(cfg_ is not None for cfg_ in resolved.values())
    assert rt.cache_size > 0                      # warm resolution cache

    prompt = np.arange(1, 9, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(prompt=prompt, max_new_tokens=3))
    eng.submit(Request(prompt=prompt[:5], max_new_tokens=3))
    done = eng.serve()
    assert len(done) == 2

    snap = rt.telemetry.snapshot()
    # warmup resolutions landed on config tiers (all-heuristic: empty db)...
    assert snap["tiers"].get("heuristic", 0) > 0
    # ...and the serve-time traces dispatched under the engine's scope.
    assert snap["tiers"].get("reference", 0) > 0
    # per-bucket accounting: warmed serving buckets appear as db keys
    assert any(k.startswith("rmsnorm|") for k in snap["by_key"])


# ---------------------------------------------------------------------------
# Bounded resolution cache: LRU capacity + TTL + eviction telemetry
# ---------------------------------------------------------------------------


def _resolve_rows(rt, rows):
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_tunable

    w = jnp.ones((32,), jnp.float32)
    return rt.resolve(rmsnorm_tunable, (jnp.ones((rows, 32), jnp.float32), w))


def test_cache_lru_capacity_bounds_growth():
    """A long-lived server cycling through many buckets must not grow the
    resolution cache without limit (ROADMAP follow-up)."""
    with repro.runtime(mode="kernel", db=TuningDatabase(None),
                       cache_capacity=2) as rt:
        for rows in (16, 64, 256, 1024):       # 4 distinct buckets
            _resolve_rows(rt, rows)
        assert rt.cache_size == 2
        snap = rt.telemetry.snapshot()
        assert snap["cache_evictions"] == 2
        # LRU order: the two most recent buckets are still warm
        _resolve_rows(rt, 1024)
        assert rt.telemetry.snapshot()["cache_hits"] == 1


def test_cache_lru_touch_on_hit():
    with repro.runtime(mode="kernel", db=TuningDatabase(None),
                       cache_capacity=2) as rt:
        _resolve_rows(rt, 16)
        _resolve_rows(rt, 64)
        _resolve_rows(rt, 16)                  # touch: 16 becomes most-recent
        _resolve_rows(rt, 256)                 # evicts 64, not 16
        _resolve_rows(rt, 16)
        snap = rt.telemetry.snapshot()
        assert snap["cache_hits"] == 2         # the touch + the final re-use


def test_cache_ttl_expires_entries(monkeypatch):
    import repro.core.runtime as rtmod

    t = {"now": 1000.0}
    monkeypatch.setattr(rtmod.time, "monotonic", lambda: t["now"])
    with repro.runtime(mode="kernel", db=TuningDatabase(None),
                       cache_ttl=10.0) as rt:
        _resolve_rows(rt, 16)
        t["now"] += 5.0
        _resolve_rows(rt, 16)                  # within TTL: cache hit
        assert rt.telemetry.snapshot()["cache_hits"] == 1
        t["now"] += 11.0
        _resolve_rows(rt, 16)                  # expired: re-resolved
        snap = rt.telemetry.snapshot()
        assert snap["cache_hits"] == 1
        assert snap["cache_evictions"] == 1


def test_cache_params_inherit():
    with repro.runtime(cache_capacity=7, cache_ttl=3.0):
        inner = repro.runtime()
        assert inner.cache_capacity == 7 and inner.cache_ttl == 3.0
        assert repro.runtime(cache_capacity=9).cache_capacity == 9


# ---------------------------------------------------------------------------
# Satellite regressions: key dtype promotion + __call__ validation
# ---------------------------------------------------------------------------


def test_args_key_uses_promoted_dtype():
    from repro.kernels.matmul import matmul as matmul_tunable

    bf = jnp.ones((8, 16), jnp.bfloat16)
    f = jnp.ones((16, 4), jnp.float32)
    k1 = _args_key(matmul_tunable, (bf, f), "p")
    k2 = _args_key(matmul_tunable, (f, bf), "p")
    # dtype field is order-independent and is the promotion, not the last arg
    assert k1.split("|")[3] == k2.split("|")[3] == "float32"
    # int labels never dominate the key (softmax_xent's old bug)
    assert promoted_dtype(["float32", "int32"]) == "float32"
    assert promoted_dtype([]) == "f32"


def test_call_validates_knob_overrides(rs):
    from repro.kernels.matmul import matmul as matmul_tunable

    x = jnp.asarray(rs.randn(16, 32), jnp.float32)
    w = jnp.asarray(rs.randn(32, 8), jnp.float32)
    with pytest.raises(ValueError, match="not in domain"):
        matmul_tunable(x, w, bm=999)
    # valid knob override + non-knob passthrough kwarg both still work
    out = matmul_tunable(x, w, bm=8, interpret=True)
    from repro.kernels import ref

    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)


def test_call_rejects_constraint_violation():
    from repro.core import Constraint, ParamSpace, PowerOfTwoParam, tunable

    space = ParamSpace(
        [PowerOfTwoParam("a", 8, 64), PowerOfTwoParam("b", 8, 64)],
        [Constraint(lambda c: c["a"] <= c["b"], "a must not exceed b")],
    )

    @tunable("toy_constrained_rt", space=space, default={"a": 8, "b": 8})
    def toy(x, *, a, b):
        return x

    with pytest.raises(ValueError, match="a must not exceed b"):
        toy(jnp.ones(4), a=64, b=8)
    assert toy(jnp.ones(4), a=8, b=64) is not None
