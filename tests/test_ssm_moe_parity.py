"""Parity sweeps for the SSM/MoE dispatch plane vs the ref.py oracles.

Forward AND VJP, across the cases the tunables' knobs actually change:
chunk sizes that don't divide the sequence, block_d strips that don't
divide d_inner, grouped expert shapes with ragged capacity/hidden dims,
and capacity-overflow token dropping. Hypothesis-free on purpose (see
test_kernels_bwd.py): this correctness gate must run everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gemm import expert_gemm_pallas
from repro.kernels.ssm_scan import (
    ssm_scan_chunked,
    ssm_scan_pallas,
    ssm_update_pallas,
)


def _scan_args(rs, b=2, s=12, di=8, ds=4):
    """Well-conditioned scan inputs: dt small positive, A negative."""
    r = lambda *sh: rs.randn(*sh)
    xc = jnp.asarray(r(b, s, di) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(r(b, s, di)) * 0.1 + 0.01, jnp.float32)
    B = jnp.asarray(r(b, s, ds) * 0.5, jnp.float32)
    C = jnp.asarray(r(b, s, ds) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(r(di, ds)) - 0.1, jnp.float32)
    h0 = jnp.asarray(r(b, di, ds) * 0.2, jnp.float32)
    return xc, dt, B, C, A, h0


# --------------------------------------------------------------- ssm_scan

@pytest.mark.parametrize("s,chunk", [(12, 4), (13, 4), (16, 16), (7, 32), (24, 8)])
def test_ssm_scan_chunked_matches_sequential_ref(rs, s, chunk):
    """Chunked associative scan == sequential lax.scan oracle for every
    (seq, chunk) alignment, including non-divisible tails and chunk > s."""
    args = _scan_args(rs, s=s)
    y, hN = ssm_scan_chunked(*args, chunk=chunk)
    y_r, hN_r = ref.ssm_scan(*args)
    np.testing.assert_allclose(y, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(hN, hN_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,chunk,block_d", [(12, 4, 8), (13, 8, 4), (9, 4, 4)])
def test_ssm_scan_pallas_matches_ref(rs, s, chunk, block_d):
    """The Pallas kernel (interpret mode) across chunk/block_d schedules,
    including d_inner strips and padded sequence tails."""
    args = _scan_args(rs, s=s, di=8)
    y, hN = ssm_scan_pallas(*args, chunk=chunk, block_d=block_d, interpret=True)
    y_r, hN_r = ref.ssm_scan(*args)
    np.testing.assert_allclose(y, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(hN, hN_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,chunk", [(12, 4), (13, 8)])
def test_ssm_scan_vjp_matches_ref_oracle(rs, s, chunk):
    """VJP of the chunked form == the ref.ssm_scan_bwd oracle: the tuned
    backward plan must be interchangeable with the Reference-tier grads."""
    args = _scan_args(rs, s=s)
    ct_y = jnp.asarray(rs.randn(*args[0].shape), jnp.float32)
    ct_h = jnp.asarray(rs.randn(*args[5].shape), jnp.float32)

    _, vjp = jax.vjp(lambda *a: ssm_scan_chunked(*a, chunk=chunk), *args)
    grads = vjp((ct_y, ct_h))
    grads_r = ref.ssm_scan_bwd(ct_y, ct_h, *args)
    assert len(grads) == len(grads_r) == 6
    for g, g_r in zip(grads, grads_r):
        np.testing.assert_allclose(g, g_r, rtol=2e-4, atol=2e-4)


def test_ssm_scan_identity_padding_invariant(rs):
    """The padded tail must be a no-op: scanning s steps of a longer padded
    buffer whose tail has dt=0 returns the state of step s-1 exactly — the
    prefill-state bug this PR fixes regresses here first."""
    xc, dt, B, C, A, h0 = _scan_args(rs, s=10)
    pad = lambda t: jnp.pad(t, ((0, 0), (0, 6)) + ((0, 0),) * (t.ndim - 2))
    y_pad, h_pad = ssm_scan_chunked(pad(xc), pad(dt), pad(B), pad(C), A, h0,
                                    chunk=4)
    y, hN = ref.ssm_scan(xc, dt, B, C, A, h0)
    np.testing.assert_allclose(y_pad[:, :10], y, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h_pad, hN, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- ssm_update

@pytest.mark.parametrize("b,di,block_b,block_d", [(3, 8, 8, 8), (5, 12, 2, 4)])
def test_ssm_update_pallas_matches_ref(rs, b, di, block_b, block_d):
    xc, dt, B, C, A, h0 = _scan_args(rs, b=b, s=1, di=di)
    xc, dt, B, C = xc[:, 0], dt[:, 0], B[:, 0], C[:, 0]
    y, hn = ssm_update_pallas(xc, dt, B, C, A, h0, block_b=block_b,
                              block_d=block_d, interpret=True)
    y_r, hn_r = ref.ssm_update(xc, dt, B, C, A, h0)
    np.testing.assert_allclose(y, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(hn, hn_r, rtol=2e-5, atol=2e-5)


def test_ssm_update_bwd_tunable_matches_ref_oracle(rs):
    """The blocked ssm_update_bwd variant == ref.ssm_update_bwd across a
    block_d that does not divide d_inner."""
    from repro.kernels.ssm_scan import ssm_update_bwd

    xc, dt, B, C, A, h = _scan_args(rs, b=3, s=1, di=12)
    xc, dt, B, C = xc[:, 0], dt[:, 0], B[:, 0], C[:, 0]
    ct_y = jnp.asarray(rs.randn(3, 12), jnp.float32)
    ct_h = jnp.asarray(rs.randn(3, 12, 4), jnp.float32)
    grads = ssm_update_bwd.fn(ct_y, ct_h, xc, dt, B, C, A, h, block_d=8)
    grads_r = ref.ssm_update_bwd(ct_y, ct_h, xc, dt, B, C, A, h)
    assert len(grads) == len(grads_r) == 6
    for g, g_r in zip(grads, grads_r):
        np.testing.assert_allclose(g, g_r, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ expert_gemm

@pytest.mark.parametrize("e,c,k,n,bc,bn,bk", [
    (2, 12, 16, 8, 8, 8, 8),       # ragged: blocks don't divide c or n
    (4, 7, 5, 9, 16, 16, 16),      # blocks larger than every dim (clamping)
    (1, 32, 8, 16, 8, 8, 8),       # single expert
])
def test_expert_gemm_pallas_matches_ref(rs, e, c, k, n, bc, bn, bk):
    x = jnp.asarray(rs.randn(e, c, k), jnp.float32)
    w = jnp.asarray(rs.randn(e, k, n), jnp.float32)
    out = expert_gemm_pallas(x, w, bc=bc, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(out, ref.expert_gemm(x, w), rtol=2e-5, atol=2e-5)


def test_expert_gemm_vjp_matches_einsum_grads(rs):
    """Dispatch-mode VJP (transposed-operand expert_gemm sites) == plain
    einsum autodiff grads."""
    import repro

    x = jnp.asarray(rs.randn(2, 12, 16), jnp.float32)
    w = jnp.asarray(rs.randn(2, 16, 8), jnp.float32)

    def loss_dispatch(x, w):
        return (repro.dispatch("expert_gemm", x, w) ** 2).sum()

    def loss_ref(x, w):
        return (jnp.einsum("eck,ekn->ecn", x, w) ** 2).sum()

    with repro.runtime(mode="kernel"):
        gx, gw = jax.grad(loss_dispatch, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gx_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gw, gw_r, rtol=2e-4, atol=2e-4)


# ------------------------------------------------- capacity overflow (MoE)

def test_moe_capacity_overflow_drops_exactly_the_late_tokens(rs):
    """With top_k=1 and a capacity below the routed load, the scatter path
    must contribute *zero* for each dropped (over-capacity) token and match
    the dense oracle for every kept one — no silent corruption."""
    from repro.models import moe

    d, ff, e = 8, 16, 2
    b, s, top_k = 2, 8, 1
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    p, _ = moe.moe_init(keys[0], d, ff, e, jnp.float32)
    # route everything to expert 0 so overflow is deterministic
    p["router"] = jnp.concatenate(
        [jnp.full((d, 1), 10.0), jnp.full((d, e - 1), -10.0)], axis=1)
    x = jnp.asarray(np.abs(rs.randn(b, s, d)) + 0.1, jnp.float32)

    cf = 0.5                               # cap = max(1, 0.5*16/2) = 4 slots
    cap = moe.expert_capacity(b * s, e, top_k, cf)
    assert cap < b * s                     # genuinely over-subscribed
    y, _ = moe.moe_apply(p, x, top_k=top_k, capacity_factor=cf,
                         dispatch="scatter")
    y_dense, _ = moe.moe_apply(p, x, top_k=top_k, capacity_factor=cf,
                               dispatch="dense")
    y2, yd2 = y.reshape(-1, d), y_dense.reshape(-1, d)
    # flat order = batch-major: first `cap` tokens kept, rest dropped
    np.testing.assert_allclose(y2[:cap], yd2[:cap], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(y2[cap:], np.zeros_like(y2[cap:]), atol=1e-7)
    assert not np.isnan(np.asarray(y)).any()
