"""Trainer × dispatch runtime: parity, scoping, and local-shape keys.

The sharded pieces run in a subprocess (XLA_FLAGS must fake 8 host devices
before jax imports — same pattern as test_launch's mini dry-run):

* kernel-mode vs reference-mode loss/grad agreement for the full train step
  on a 2×4 host mesh (correctness-gate tolerances) — proves the runtime's
  reference-VJP wrapper trains;
* sharded vs unsharded key resolution: inside the trainer's mesh context
  dispatch must look up the per-device local-shard key, outside it the
  global key — with a record stored under each to prove which one hits.

The in-process tests cover the host-mesh (1-device) path: a pinned runtime
observes every dispatch the trainer makes.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str, timeout: int = 560):
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=dict(_ENV),
        cwd=".",
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("RESULT_JSON=")), None
    )
    assert line, f"stdout={r.stdout[-1500:]} stderr={r.stderr[-2500:]}"
    return json.loads(line.split("=", 1)[1])


_PARITY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import repro
from repro.configs.base import SHAPES, get_config
from repro.core.database import TuningDatabase
from repro.distributed import sharding as shd
from repro.launch import defaults
from repro.launch.mesh import make_mesh_from_spec
from repro.models import lm

cfg = get_config("qwen2_0_5b").reduced()
shape = SHAPES["train_smoke"]
run = defaults.default_run(cfg, shape)
layout = defaults.default_layout(cfg)
mesh = make_mesh_from_spec("2x4")

params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
rs = jax.random.PRNGKey(1)
B, S = shape.global_batch, shape.seq_len
batch = {
    "tokens": jax.random.randint(rs, (B, S), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.fold_in(rs, 1), (B, S), 0, cfg.vocab_size),
}

def loss(p, b):
    return lm.loss_fn(p, b, cfg, run)[0]

out = {}
for mode in ("reference", "kernel"):
    with repro.runtime(mode=mode, db=TuningDatabase(None)), \\
         shd.mesh_context(mesh, layout):
        l, g = jax.jit(jax.value_and_grad(loss))(params, batch)
        jax.block_until_ready(g)
    gflat = jnp.concatenate([x.astype(jnp.float32).ravel()
                             for x in jax.tree_util.tree_leaves(g)])
    out[mode] = {"loss": float(l), "gnorm": float(jnp.linalg.norm(gflat)),
                 "g_head": [float(v) for v in gflat[:64]]}
print("RESULT_JSON=" + json.dumps(out))
"""


def test_trainer_kernel_reference_parity_sharded_mesh():
    out = _run(_PARITY)
    ref, ker = out["reference"], out["kernel"]
    # correctness-gate-style tolerances (f32 model, interpret-mode kernels)
    assert ref["loss"] == pytest.approx(ker["loss"], rel=2e-4, abs=2e-4)
    assert ref["gnorm"] == pytest.approx(ker["gnorm"], rel=5e-4, abs=5e-4)
    np.testing.assert_allclose(
        np.asarray(ref["g_head"]), np.asarray(ker["g_head"]),
        rtol=5e-4, atol=5e-4,
    )


_LOCAL_KEYS = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import repro
from repro.core import Record, TuningDatabase, make_key
from repro.core.platform import detect_platform
from repro.distributed.sharding import (
    Layout, data_parallel_degree, mesh_axis_sizes, mesh_context,
)
from repro.kernels.matmul import matmul as matmul_tunable
from repro.launch.mesh import make_mesh_from_spec

mesh = make_mesh_from_spec("2x4")
layout = Layout()
platform = detect_platform().name
x = jnp.ones((256, 64), jnp.float32)
w = jnp.ones((64, 128), jnp.float32)
# the degree the step's BATCH dim is sharded at (batch 8 over data=2), the
# way the Trainer computes it — NOT derived from the flattened 256 rows
dp = data_parallel_degree(mesh_axis_sizes(mesh), layout, 8)

db = TuningDatabase(None)
local_key = make_key("matmul", platform, [(128, 64), (64, 128)], "float32")
global_key = make_key("matmul", platform, [(256, 64), (64, 128)], "float32")
db.put(Record(local_key, {"bm": 8, "bn": 128, "bk": 128}, 1e-6, "w", 1, 0.0))

out = {"dp": dp}
with repro.runtime(mode="kernel", db=db) as rt:
    with mesh_context(mesh, layout, dp_degree=dp):
        out["sharded_tier"] = rt.resolve(matmul_tunable, (x, w)).tier
    out["unsharded_tier"] = rt.resolve(matmul_tunable, (x, w)).tier
    keys = sorted(rt.telemetry.snapshot()["by_key"])
out["keys"] = keys
out["local_key"] = local_key
out["global_key"] = global_key
print("RESULT_JSON=" + json.dumps(out))
"""


def test_sharded_vs_unsharded_db_key_resolution():
    out = _run(_LOCAL_KEYS)
    assert out["dp"] == 2
    # under the mesh the LOCAL record (256 rows / dp2 = 128) exact-hits;
    # the same call outside the mesh computes the global key and misses
    assert out["sharded_tier"] == "exact"
    assert out["unsharded_tier"] == "heuristic"
    assert set(out["keys"]) == {out["local_key"], out["global_key"]}


def test_local_shape_helpers_pure():
    """The size-map helpers need no live mesh (planning for a pod from a
    dev host) and only divide when every selected axis divides."""
    from repro.distributed.sharding import (
        Layout,
        data_parallel_degree,
        local_shard_shape,
        localize_shapes,
    )

    layout = Layout()                       # data_axes = ("data",)
    sizes = {"pod": 2, "data": 16, "model": 16}
    assert data_parallel_degree(sizes, layout, 256) == 32       # pod × data
    assert data_parallel_degree(sizes, layout, 8) == 2          # pod only
    assert data_parallel_degree(sizes, layout, 7) == 1
    assert local_shard_shape((256, 4096, 64), sizes, layout) == (8, 4096, 64)
    assert local_shard_shape((64,), {"data": 4}, layout) == (16,)
    # outside any mesh context, localize_shapes is the identity
    assert localize_shapes([(256, 64), (64, 128)]) == ((256, 64), (64, 128))


def test_localize_uses_context_degree_not_per_arg_divisibility():
    """Regression: the degree is the context's batch-dim degree, computed
    once — a data axis that divides a *flattened* activation dim (batch·seq)
    but not the batch must NOT localize the key. With batch 8 on a data=16
    axis the batch is replicated (16 ∤ 8 → dp 1), so the 512-row flattened
    activation keys globally even though 16 | 512."""
    from repro.distributed.sharding import (
        Layout,
        data_parallel_degree,
        localize_shapes,
        mesh_context,
    )
    from repro.launch.mesh import make_host_mesh

    layout = Layout()
    dp = data_parallel_degree({"data": 16, "model": 1}, layout, 8)
    assert dp == 1
    mesh = make_host_mesh()
    with mesh_context(mesh, layout, dp_degree=dp):
        assert localize_shapes([(512, 64)], [0]) == ((512, 64),)
    # a context that carries no degree (dry-run lowering) keys globally too
    with mesh_context(mesh, layout):
        assert localize_shapes([(512, 64)], [0]) == ((512, 64),)
    # and with a real degree, only declared batch args divide — args whose
    # leading dim the degree does not divide stay global (replicated rows)
    with mesh_context(mesh, layout, dp_degree=4):
        assert localize_shapes([(512, 64), (7, 3), (64,)], [0, 1]) == (
            (128, 64), (7, 3), (64,),
        )


def test_trainer_dispatches_through_pinned_runtime(tmp_path):
    """Host-mesh trainer: every kernel site the step traces resolves through
    the pinned runtime (telemetry observes it), not ambient state."""
    import jax  # noqa: F401

    import repro
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.core import TuningDatabase
    from repro.data.pipeline import DataConfig
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]
    rt = repro.runtime(mode="reference", db=TuningDatabase(None), name="t")
    tr = Trainer(
        cfg, defaults.default_run(cfg, shape), make_host_mesh(),
        defaults.default_layout(cfg),
        DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
        adamw.AdamWConfig(total_steps=2),
        TrainerConfig(total_steps=2, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "ckpt"),
                      async_checkpoint=False),
        runtime=rt,
    )
    loss = tr.run_one_step()["loss"]
    assert np.isfinite(loss)
    snap = rt.telemetry.snapshot()
    # reference mode: every dispatch lands on the reference tier, and the
    # trainer's matmul/rmsnorm/xent sites all route through this runtime
    assert snap["tiers"].get("reference", 0) > 0
    assert set(snap["tiers"]) == {"reference"}
