"""Trainer × dispatch runtime: parity, scoping, and local-shape keys.

The sharded pieces run in a subprocess (XLA_FLAGS must fake 8 host devices
before jax imports — same pattern as test_launch's mini dry-run):

* kernel-mode vs reference-mode loss/grad agreement for the full train step
  on a 2×4 host mesh (correctness-gate tolerances) — proves the runtime's
  reference-VJP wrapper trains;
* sharded vs unsharded key resolution: inside the trainer's mesh context
  dispatch must look up the per-device local-shard key, outside it the
  global key — with a record stored under each to prove which one hits.

The in-process tests cover the host-mesh (1-device) path: a pinned runtime
observes every dispatch the trainer makes.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str, timeout: int = 560):
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=dict(_ENV),
        cwd=".",
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("RESULT_JSON=")), None
    )
    assert line, f"stdout={r.stdout[-1500:]} stderr={r.stderr[-2500:]}"
    return json.loads(line.split("=", 1)[1])


_PARITY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import repro
from repro.configs.base import SHAPES, get_config
from repro.core.database import TuningDatabase
from repro.distributed import sharding as shd
from repro.launch import defaults
from repro.launch.mesh import make_mesh_from_spec
from repro.models import lm

cfg = get_config("qwen2_0_5b").reduced()
shape = SHAPES["train_smoke"]
run = defaults.default_run(cfg, shape)
layout = defaults.default_layout(cfg)
mesh = make_mesh_from_spec("2x4")

params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
rs = jax.random.PRNGKey(1)
B, S = shape.global_batch, shape.seq_len
batch = {
    "tokens": jax.random.randint(rs, (B, S), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.fold_in(rs, 1), (B, S), 0, cfg.vocab_size),
}

def loss(p, b):
    return lm.loss_fn(p, b, cfg, run)[0]

out = {}
for mode in ("reference", "kernel"):
    with repro.runtime(mode=mode, db=TuningDatabase(None)), \\
         shd.mesh_context(mesh, layout):
        l, g = jax.jit(jax.value_and_grad(loss))(params, batch)
        jax.block_until_ready(g)
    gflat = jnp.concatenate([x.astype(jnp.float32).ravel()
                             for x in jax.tree_util.tree_leaves(g)])
    out[mode] = {"loss": float(l), "gnorm": float(jnp.linalg.norm(gflat)),
                 "g_head": [float(v) for v in gflat[:64]]}
print("RESULT_JSON=" + json.dumps(out))
"""


def test_trainer_kernel_reference_parity_sharded_mesh():
    out = _run(_PARITY)
    ref, ker = out["reference"], out["kernel"]
    # correctness-gate-style tolerances (f32 model, interpret-mode kernels)
    assert ref["loss"] == pytest.approx(ker["loss"], rel=2e-4, abs=2e-4)
    assert ref["gnorm"] == pytest.approx(ker["gnorm"], rel=5e-4, abs=5e-4)
    np.testing.assert_allclose(
        np.asarray(ref["g_head"]), np.asarray(ker["g_head"]),
        rtol=5e-4, atol=5e-4,
    )


_LOCAL_KEYS = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import repro
from repro.core import Record, TuningDatabase, make_key
from repro.core.platform import detect_platform
from repro.distributed.sharding import (
    Layout, data_parallel_degree, mesh_axis_sizes, mesh_context,
)
from repro.kernels.matmul import matmul as matmul_tunable
from repro.launch.mesh import make_mesh_from_spec

mesh = make_mesh_from_spec("2x4")
layout = Layout()
platform = detect_platform().name
x = jnp.ones((256, 64), jnp.float32)
w = jnp.ones((64, 128), jnp.float32)
# the degree the step's BATCH dim is sharded at (batch 8 over data=2), the
# way the Trainer computes it — NOT derived from the flattened 256 rows
dp = data_parallel_degree(mesh_axis_sizes(mesh), layout, 8)

db = TuningDatabase(None)
local_key = make_key("matmul", platform, [(128, 64), (64, 128)], "float32")
global_key = make_key("matmul", platform, [(256, 64), (64, 128)], "float32")
db.put(Record(local_key, {"bm": 8, "bn": 128, "bk": 128}, 1e-6, "w", 1, 0.0))

out = {"dp": dp}
with repro.runtime(mode="kernel", db=db) as rt:
    with mesh_context(mesh, layout, dp_degree=dp):
        out["sharded_tier"] = rt.resolve(matmul_tunable, (x, w)).tier
    out["unsharded_tier"] = rt.resolve(matmul_tunable, (x, w)).tier
    keys = sorted(rt.telemetry.snapshot()["by_key"])
out["keys"] = keys
out["local_key"] = local_key
out["global_key"] = global_key
print("RESULT_JSON=" + json.dumps(out))
"""


def test_sharded_vs_unsharded_db_key_resolution():
    out = _run(_LOCAL_KEYS)
    assert out["dp"] == 2
    # under the mesh the LOCAL record (256 rows / dp2 = 128) exact-hits;
    # the same call outside the mesh computes the global key and misses
    assert out["sharded_tier"] == "exact"
    assert out["unsharded_tier"] == "heuristic"
    assert set(out["keys"]) == {out["local_key"], out["global_key"]}


def test_local_shape_helpers_pure():
    """The size-map helpers need no live mesh (planning for a pod from a
    dev host) and only divide when every selected axis divides."""
    from repro.distributed.sharding import (
        Layout,
        data_parallel_degree,
        local_shard_shape,
        localize_shapes,
    )

    layout = Layout()                       # data_axes = ("data",)
    sizes = {"pod": 2, "data": 16, "model": 16}
    assert data_parallel_degree(sizes, layout, 256) == 32       # pod × data
    assert data_parallel_degree(sizes, layout, 8) == 2          # pod only
    assert data_parallel_degree(sizes, layout, 7) == 1
    assert local_shard_shape((256, 4096, 64), sizes, layout) == (8, 4096, 64)
    assert local_shard_shape((64,), {"data": 4}, layout) == (16,)
    # outside any mesh context, localize_shapes is the identity
    assert localize_shapes([(256, 64), (64, 128)]) == ((256, 64), (64, 128))


def test_localize_uses_context_degree_not_per_arg_divisibility():
    """Regression: the degree is the context's batch-dim degree, computed
    once — a data axis that divides a *flattened* activation dim (batch·seq)
    but not the batch must NOT localize the key. With batch 8 on a data=16
    axis the batch is replicated (16 ∤ 8 → dp 1), so the 512-row flattened
    activation keys globally even though 16 | 512."""
    from repro.distributed.sharding import (
        Layout,
        data_parallel_degree,
        localize_shapes,
        mesh_context,
    )
    from repro.launch.mesh import make_host_mesh

    layout = Layout()
    dp = data_parallel_degree({"data": 16, "model": 1}, layout, 8)
    assert dp == 1
    mesh = make_host_mesh()
    with mesh_context(mesh, layout, dp_degree=dp):
        assert localize_shapes([(512, 64)], [0]) == ((512, 64),)
    # a context that carries no degree (dry-run lowering) keys globally too
    with mesh_context(mesh, layout):
        assert localize_shapes([(512, 64)], [0]) == ((512, 64),)
    # and with a real degree, only declared batch args divide — args whose
    # leading dim the degree does not divide stay global (replicated rows)
    with mesh_context(mesh, layout, dp_degree=4):
        assert localize_shapes([(512, 64), (7, 3), (64,)], [0, 1]) == (
            (128, 64), (7, 3), (64,),
        )


def test_trainer_dispatches_through_pinned_runtime(tmp_path):
    """Host-mesh trainer: every kernel site the step traces resolves through
    the pinned runtime (telemetry observes it), not ambient state."""
    import jax  # noqa: F401

    import repro
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.core import TuningDatabase
    from repro.data.pipeline import DataConfig
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]
    rt = repro.runtime(mode="reference", db=TuningDatabase(None), name="t")
    tr = Trainer(
        cfg, defaults.default_run(cfg, shape), make_host_mesh(),
        defaults.default_layout(cfg),
        DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
        adamw.AdamWConfig(total_steps=2),
        TrainerConfig(total_steps=2, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "ckpt"),
                      async_checkpoint=False),
        runtime=rt,
    )
    loss = tr.run_one_step()["loss"]
    assert np.isfinite(loss)
    snap = rt.telemetry.snapshot()
    # reference mode: every dispatch lands on the reference tier, and the
    # trainer's matmul/rmsnorm/xent sites all route through this runtime
    assert snap["tiers"].get("reference", 0) > 0
    assert set(snap["tiers"]) == {"reference"}


# ---------------------------------------------------------------------------
# Tuned backward plane: per-tunable grad parity, bwd db keys, bwd fallbacks
# ---------------------------------------------------------------------------

_BWD_TUNABLES = ("matmul", "rmsnorm", "softmax_xent", "flash_attention")


@pytest.mark.parametrize("name", _BWD_TUNABLES)
def test_dispatch_grad_matches_reference(name):
    """For every forward tunable with a dispatch-vjp backward plan, the
    gradient of kernel-mode dispatch must match the reference VJP — and the
    backward sites must show up as bwd-phase telemetry rows."""
    import jax
    import jax.numpy as jnp

    import repro
    import repro.kernels  # noqa: F401 — registers the tunables
    from repro.core import TuningDatabase, registered

    t = registered()[name]
    assert t.dispatch.vjp == "dispatch" and t.dispatch.bwd is not None
    args, kwargs = t.dispatch.example()
    ref_fn = t.dispatch.reference_for(t)
    diff = [i for i, a in enumerate(args)
            if jnp.issubdtype(jnp.result_type(a), jnp.inexact)]

    def rebuild(inexact):
        full = list(args)
        for i, v in zip(diff, inexact):
            full[i] = v
        return tuple(full)

    def loss_dispatch(*inexact):
        out = repro.dispatch(name, *rebuild(inexact), **kwargs)
        return sum((jnp.asarray(o, jnp.float32) ** 2).sum()
                   for o in jax.tree_util.tree_leaves(out))

    def loss_ref(*inexact):
        out = ref_fn(*rebuild(inexact), **kwargs)
        return sum((jnp.asarray(o, jnp.float32) ** 2).sum()
                   for o in jax.tree_util.tree_leaves(out))

    inexact = tuple(args[i] for i in diff)
    argnums = tuple(range(len(inexact)))
    with repro.runtime(mode="kernel", db=TuningDatabase(None)) as rt:
        g_kernel = jax.jit(jax.grad(loss_dispatch, argnums=argnums))(*inexact)
    g_ref = jax.grad(loss_ref, argnums=argnums)(*inexact)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   rtol=5e-4, atol=5e-4)
    snap = rt.telemetry.snapshot()
    assert snap["phases"].get("bwd"), snap["phases"]


def test_bwd_db_keys_match_training_planner():
    """bwd db-key stability: the keys backward dispatch computes under a
    sharded mesh context (including the dp_dims transposed-operand
    override) are exactly the keys `plan_training_jobs` emits for the same
    sites — the contract that makes gradient ExactHits possible."""
    import jax.numpy as jnp

    from repro.campaign.planner import plan_training_jobs
    from repro.configs.base import SHAPES, get_config
    from repro.core.platform import detect_platform
    from repro.core.tuner import _args_key
    from repro.distributed.sharding import Layout, mesh_context
    from repro.kernels.matmul import matmul as matmul_tunable
    from repro.kernels.rmsnorm import rmsnorm_bwd as rmsnorm_bwd_tunable
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]            # B=8, S=64
    layout = Layout()
    platform = detect_platform().name
    jobs = plan_training_jobs(cfg, shape, layout=layout, mesh_axes="2x4")
    planned = {j.db_key(platform) for j in jobs}

    d, n = cfg.d_model, cfg.num_heads * cfg.hd
    T_global = 8 * 64                        # flattened rows in the jit trace
    x = jnp.zeros((T_global, d), jnp.float32)
    ct = jnp.zeros((T_global, n), jnp.float32)
    w = jnp.zeros((d, n), jnp.float32)
    with mesh_context(make_host_mesh(), layout, dp_degree=2):
        # dL/dx = ct @ wT: ordinary leading-dim localization
        key_dx = _args_key(matmul_tunable, (ct, w.T), platform)
        # dL/dw = xT @ ct: token dim sits at arg0-dim1/arg1-dim0
        key_dw = _args_key(matmul_tunable, (x.T, ct), platform,
                           dp_dims={0: 1, 1: 0})
        ct_n = jnp.zeros((T_global, d), jnp.float32)
        # the saved inv-rms residual rides along as a keyed operand
        inv_rms = jnp.zeros((T_global,), jnp.float32)
        key_norm = _args_key(
            rmsnorm_bwd_tunable,
            (ct_n, x, jnp.zeros((d,), jnp.float32), inv_rms), platform,
        )
    assert key_dx in planned, key_dx
    assert key_dw in planned, key_dw
    assert key_norm in planned, key_norm
    # a dp_dims-less dw key (leading-dim convention) would NOT be planned:
    # the transposed override is load-bearing
    with mesh_context(make_host_mesh(), layout, dp_degree=2):
        key_dw_wrong = _args_key(matmul_tunable, (x.T, ct), platform)
    assert key_dw_wrong != key_dw


def test_bwd_cover_and_warm_start_fallback(tmp_path):
    """A backward kernel with no exact record still rides the transfer
    machinery: its nearest record warm-starts a re-tune, and a stored cover
    entry serves an unseen bucket at the cover tier (never Reference)."""
    import jax.numpy as jnp

    import repro
    from repro.campaign.transfer import warm_start_configs
    from repro.core import Record, TuningDatabase, make_key
    from repro.core.platform import detect_platform
    from repro.core.runtime import CoverSet, ExactHit, Heuristic
    from repro.kernels.rmsnorm import rmsnorm_bwd as rmsnorm_bwd_tunable

    platform = detect_platform().name
    db = TuningDatabase(str(tmp_path / "db.json"))
    cfg = {"block_rows": 16}
    key = make_key("rmsnorm_bwd", platform,
                   [(64, 32), (64, 32), (32,)], "float32")
    db.put(Record(key, cfg, 1e-6, "wallclock", 1, 0.0))

    # warm start: the neighbouring bucket seeds from the stored record
    seeds = warm_start_configs(
        db, "rmsnorm_bwd", platform,
        [(128, 32), (128, 32), (32,)], "float32",
        space=rmsnorm_bwd_tunable.space,
    )
    assert cfg in seeds

    # cover fallback: an unseen bucket resolves at the cover tier
    db.put_cover("rmsnorm_bwd", platform, [{"config": cfg, "shapes": [(64, 32)]}])
    args = (
        jnp.zeros((256, 32), jnp.float32),
        jnp.zeros((256, 32), jnp.float32),
        jnp.zeros((32,), jnp.float32),
    )
    with repro.runtime(mode="kernel", db=db,
                       policy=(ExactHit(), CoverSet(), Heuristic())) as rt:
        res = rt.resolve(rmsnorm_bwd_tunable, args)
    assert res.tier == "cover"
    assert res.config == cfg


def test_sharded_smoke_step_has_no_remat_warning():
    """Regression for the sharding-annotation pass: the 2×4 sharded smoke
    step (kernel mode, fwd+bwd dispatch) must not trigger XLA's
    'Involuntary full rematerialization' on the attention reshapes."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro
from repro.configs.base import SHAPES, get_config
from repro.core.database import TuningDatabase
from repro.data.pipeline import DataConfig
from repro.launch import defaults
from repro.launch.mesh import make_mesh_from_spec
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig
import json, tempfile

cfg = get_config("qwen2_0_5b").reduced()
shape = SHAPES["train_smoke"]
rt = repro.runtime(mode="kernel", db=TuningDatabase(None))
tr = Trainer(cfg, defaults.default_run(cfg, shape), make_mesh_from_spec("2x4"),
             defaults.default_layout(cfg),
             DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
             adamw.AdamWConfig(total_steps=1),
             TrainerConfig(total_steps=1, checkpoint_every=100,
                           checkpoint_dir=tempfile.mkdtemp(),
                           async_checkpoint=False),
             runtime=rt)
m = tr.run_one_step()
print("RESULT_JSON=" + json.dumps({"loss": float(m["loss"])}))
"""
    env = dict(_ENV)
    env["TF_CPP_MIN_LOG_LEVEL"] = "0"        # surface XLA's SPMD messages
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=560, env=env, cwd=".",
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("RESULT_JSON=")), None
    )
    assert line, f"stdout={r.stdout[-1500:]} stderr={r.stderr[-2500:]}"
    out = json.loads(line.split("=", 1)[1])
    assert np.isfinite(out["loss"])
    assert "full rematerialization" not in r.stderr, r.stderr[-3000:]
