"""Platform fingerprinting + the override escape hatch.

The dispatch runtime and campaign tools namespace databases under the
*detected* platform; these tests pin the override precedence (explicit arg >
set_platform_override > $REPRO_PLATFORM > fingerprint) and that a runtime
can pin a foreign namespace without touching process state.
"""
import jax.numpy as jnp
import pytest

import repro
from repro.core import TuningDatabase
from repro.core.platform import (
    CPU_HOST,
    PROFILES,
    detect_platform,
    platform_override,
    set_platform_override,
)


@pytest.fixture(autouse=True)
def clean_override(monkeypatch):
    monkeypatch.delenv("REPRO_PLATFORM", raising=False)
    set_platform_override(None)
    yield
    set_platform_override(None)


def test_fingerprint_on_this_host_is_cpu():
    assert detect_platform().name == "cpu-host"
    assert detect_platform() is CPU_HOST


def test_known_override_selects_profile():
    set_platform_override("tpu-v4")
    assert platform_override() == "tpu-v4"
    assert detect_platform() is PROFILES["tpu-v4"]


def test_unknown_override_clones_fingerprint():
    """A new namespace (e.g. an unreleased TPU generation) isolates records
    while keeping sensible roofline peaks from the fingerprinted profile."""
    set_platform_override("tpu-v6e-preview")
    prof = detect_platform()
    assert prof.name == "tpu-v6e-preview"
    assert prof.peak_flops_bf16 == CPU_HOST.peak_flops_bf16
    assert "tpu-v6e-preview" not in PROFILES   # no registry pollution


def test_env_override_and_precedence(monkeypatch):
    monkeypatch.setenv("REPRO_PLATFORM", "tpu-v5e")
    assert detect_platform().name == "tpu-v5e"
    set_platform_override("tpu-v4")            # explicit call wins over env
    assert detect_platform().name == "tpu-v4"
    assert detect_platform(override="cpu-host").name == "cpu-host"


def test_override_changes_runtime_db_namespace():
    """Dispatch keys follow the override — records stored under the
    overridden namespace hit; the fingerprinted namespace does not leak."""
    from repro.kernels.matmul import matmul as matmul_tunable

    x = jnp.ones((16, 32), jnp.float32)
    w = jnp.ones((32, 8), jnp.float32)
    set_platform_override("tpu-v6e-preview")
    with repro.runtime(mode="kernel", db=TuningDatabase(None)) as rt:
        rt.resolve(matmul_tunable, (x, w))
    keys = list(rt.telemetry.snapshot()["by_key"])
    assert keys and all("|tpu-v6e-preview|" in k for k in keys)


def test_runtime_platform_param_pins_namespace():
    """A per-runtime platform pin (inspecting a foreign artifact from a dev
    host) needs no process-global state."""
    from repro.core import Record, make_key
    from repro.kernels.matmul import matmul as matmul_tunable

    x = jnp.ones((16, 32), jnp.float32)
    w = jnp.ones((32, 8), jnp.float32)
    db = TuningDatabase(None)
    key = make_key("matmul", "tpu-v5e", [(16, 32), (32, 8)], "float32")
    db.put(Record(key, {"bm": 8, "bn": 128, "bk": 128}, 1e-6, "w", 1, 0.0))

    with repro.runtime(mode="kernel", db=db, platform="tpu-v5e") as rt:
        res = rt.resolve(matmul_tunable, (x, w))
    assert res.tier == "exact"
    # the same db under the detected (cpu-host) namespace misses
    with repro.runtime(mode="kernel", db=db) as rt2:
        assert rt2.resolve(matmul_tunable, (x, w)).tier == "heuristic"
    # nested runtimes inherit the pinned platform
    with repro.runtime(platform="tpu-v5e"):
        assert repro.runtime().platform == "tpu-v5e"
