"""Production-mesh sharding validation for ALL 10 archs at FULL size.

Spec construction needs mesh *geometry*, not real devices — a tiled device
array gives us the exact 16×16 production mesh shape on one CPU. For every
arch this checks: every parameter of the full-size model gets a legal
PartitionSpec (divisibility + no axis reuse), head-aware mode never splits
a head/kv-head/expert unit, and the big models' per-chip parameter bytes
fit v5e HBM with FSDP on.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.sharding import param_shardings
from repro.launch.defaults import default_layout
from repro.models import lm


def production_mesh_shape(shape=(16, 16), axes=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = production_mesh_shape()
SIZES = dict(zip(MESH.axis_names, MESH.devices.shape))


def _axis_size(part):
    n = 1
    for a in part if isinstance(part, tuple) else (part,):
        n *= SIZES[a]
    return n


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("head_aware", [False, True])
def test_full_size_param_shardings_legal(arch, head_aware):
    import dataclasses

    cfg = get_config(arch)
    layout = dataclasses.replace(default_layout(cfg), head_aware=head_aware)
    specs, axes = lm.abstract_params(cfg)
    shardings = param_shardings(axes, specs, MESH, layout)

    leaves_s = jax.tree_util.tree_leaves(specs)
    leaves_sh = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    )
    assert len(leaves_s) == len(leaves_sh) > 0
    for spec, sh in zip(leaves_s, leaves_sh):
        used = []
        for i, part in enumerate(sh.spec):
            if part is None:
                continue
            size = _axis_size(part)
            assert spec.shape[i] % size == 0, (arch, spec.shape, sh.spec)
            used.extend(part if isinstance(part, tuple) else (part,))
        assert len(used) == len(set(used)), (arch, sh.spec)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_head_aware_never_splits_units(arch):
    cfg = get_config(arch)
    layout = default_layout(cfg)
    layout = type(layout)(**{**layout.__dict__, "head_aware": True})
    specs, axes = lm.abstract_params(cfg)
    shardings = param_shardings(axes, specs, MESH, layout)

    def walk(ax_tree, sh_tree, sp_tree):
        if isinstance(ax_tree, dict):
            for k in ax_tree:
                walk(ax_tree[k], sh_tree[k], sp_tree[k])
        elif isinstance(ax_tree, (list, tuple)) and not all(
            isinstance(s, str) for s in ax_tree
        ):
            for a, s, p in zip(ax_tree, sh_tree, sp_tree):
                walk(a, s, p)
        else:
            for i, name in enumerate(ax_tree):
                part = sh_tree.spec[i] if i < len(sh_tree.spec) else None
                if part is None:
                    continue
                count = layout.count_of(name)
                if count is not None:
                    assert count % _axis_size(part) == 0, (arch, name, count, part)

    walk(axes, shardings, specs)


@pytest.mark.parametrize(
    "arch", ["gemma3_27b", "mixtral_8x7b", "arctic_480b", "jamba_1_5_large"]
)
def test_big_model_param_bytes_fit_hbm_with_fsdp(arch):
    """Per-chip bf16 param bytes under the default (FSDP) layout ≤ 16 GiB.

    (Optimizer states can exceed HBM for the two ~0.5T models on one pod —
    recorded honestly in EXPERIMENTS.md §Dry-run; this test pins the params
    themselves.)
    """
    cfg = get_config(arch)
    layout = default_layout(cfg)
    assert layout.fsdp
    specs, axes = lm.abstract_params(cfg)
    shardings = param_shardings(axes, specs, MESH, layout)
    leaves_s = jax.tree_util.tree_leaves(specs)
    leaves_sh = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    )
    per_chip = 0
    for spec, sh in zip(leaves_s, leaves_sh):
        n = math.prod(spec.shape)
        shard = 1
        for part in sh.spec:
            if part is not None:
                shard *= _axis_size(part)
        per_chip += (n // shard) * 2  # bf16
    assert per_chip <= 16 * 1024**3, f"{arch}: {per_chip/2**30:.1f} GiB/chip"
