"""Drift detection: attribution unit tests plus the end-to-end protocol —
tune a small roster, replay with an artificially slowed kernel, and the
report must flag exactly the regressed site."""
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.database import Record, TuningDatabase, make_key
from repro.obs.drift import (
    DriftEntry,
    detect_drift,
    drift_report,
    format_drift,
    measure_sites,
)


def _db_with(tmp_path=None, *entries):
    db = TuningDatabase(str(tmp_path / "db.json") if tmp_path else None)
    for key, config, objective in entries:
        db.put(Record(key, config, objective, "wallclock", 4, 0.0),
               save=tmp_path is not None)
    return db


K_MM = make_key("matmul", "cpu-host", [(64, 32), (32, 16)], "float32")
K_RN = make_key("rmsnorm", "cpu-host", [(64, 32), (32,)], "float32")


# ---------------------------------------------------------------------------
# unit: attribution against synthetic live timings
# ---------------------------------------------------------------------------

def test_detect_drift_flags_exactly_the_slowed_site():
    db = _db_with(None,
                  (K_MM, {"bm": 8, "bn": 16, "bk": 32}, 1e-4),
                  (K_RN, {"block_rows": 16}, 2e-4))
    live = {K_MM: 1.1e-4,          # holds its promise
            K_RN: 8e-4}            # 4x slower than tuned
    entries = detect_drift(db, live, threshold=1.5)
    assert [e.regressed for e in entries] == [True, False]  # ranked worst-first
    worst = entries[0]
    assert worst.key == K_RN and worst.kernel == "rmsnorm"
    assert worst.slowdown == pytest.approx(4.0)
    assert worst.pct_of_tuned_best == pytest.approx(25.0)   # 100*tuned/live
    assert worst.roofline_s > 0
    assert worst.pct_of_roofline == pytest.approx(
        100.0 * worst.roofline_s / worst.live_s)
    ok = entries[1]
    assert ok.key == K_MM and not ok.regressed
    assert {k for k in worst.to_json()} >= {
        "key", "kernel", "tuned_s", "live_s", "slowdown",
        "pct_of_tuned_best", "pct_of_roofline", "regressed"}


def test_detect_drift_missing_live_and_failed_replay():
    db = _db_with(None, (K_MM, {"bm": 8, "bn": 16, "bk": 32}, 1e-4))
    assert detect_drift(db, {}, threshold=1.5) == []        # no live timing
    entries = detect_drift(db, {K_MM: math.inf}, threshold=1.5)
    assert entries[0].regressed and entries[0].pct_of_tuned_best == 0.0


def test_format_drift_report():
    db = _db_with(None,
                  (K_MM, {"bm": 8, "bn": 16, "bk": 32}, 1e-4),
                  (K_RN, {"block_rows": 16}, 2e-4))
    entries = detect_drift(db, {K_MM: 1e-4, K_RN: 9e-4}, threshold=1.5)
    text = format_drift(entries, threshold=1.5)
    assert "REGRESSED" in text
    assert f"campaign re-tune candidate: {K_RN}" in text
    assert K_MM in text and "1 site(s) regressed" in text
    assert "no measured sites" in format_drift([], 1.5)
    healthy = format_drift(detect_drift(db, {K_MM: 1e-4}, threshold=1.5), 1.5)
    assert "sustained" in healthy


# ---------------------------------------------------------------------------
# e2e: tune a roster, slow one kernel, replay, flag it
# ---------------------------------------------------------------------------

@pytest.fixture
def tuned_db(tmp_path):
    """A real two-site tuned database (matmul + rmsnorm, tiny shapes)."""
    from repro.core.evaluate import WallClockEvaluator
    from repro.core.search import CoordinateDescent
    from repro.core.tuner import autotune
    from repro.kernels.matmul import matmul as matmul_tunable
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_tunable

    rs = np.random.RandomState(0)
    db = TuningDatabase(str(tmp_path / "tuned.json"))
    ev = WallClockEvaluator(repeats=2, warmup=1)
    autotune(matmul_tunable,
             (jnp.asarray(rs.randn(64, 32), jnp.float32),
              jnp.asarray(rs.randn(32, 16), jnp.float32)),
             search=CoordinateDescent(budget=4), evaluator=ev, db=db)
    autotune(rmsnorm_tunable,
             (jnp.asarray(rs.randn(64, 32), jnp.float32),
              jnp.asarray(rs.randn(32), jnp.float32)),
             search=CoordinateDescent(budget=4), evaluator=ev, db=db)
    assert len(db) == 2
    return db


def _slow_rmsnorm(monkeypatch, factor=40):
    """Chain `factor` dependent rmsnorm calls — shape-preserving, not
    DCE-able, so the replayed variant is genuinely ~factor× slower."""
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_tunable

    orig = rmsnorm_tunable.fn

    def chained(x, w, **cfg):
        out = orig(x, w, **cfg)
        for _ in range(factor - 1):
            out = orig(out, w, **cfg)
        return out

    monkeypatch.setattr(rmsnorm_tunable, "fn", chained)


def test_replay_flags_exactly_the_slowed_kernel(tuned_db, monkeypatch):
    _slow_rmsnorm(monkeypatch)
    # threshold 3: far above wall-clock noise, far below the 40x slowdown
    entries = drift_report(tuned_db, threshold=3.0)
    assert len(entries) == 2
    flagged = [e for e in entries if e.regressed]
    assert [e.kernel for e in flagged] == ["rmsnorm"]
    assert entries[0].kernel == "rmsnorm"                  # ranked first
    assert entries[0].slowdown > 3.0
    assert entries[0].pct_of_tuned_best < 35.0
    assert "campaign re-tune candidate" in format_drift(entries, 3.0)


def test_measure_sites_skips_unregistered_and_filters_keys(tuned_db):
    stray = make_key("not_a_kernel", "cpu-host", [(8, 8)], "float32")
    tuned_db.put(Record(stray, {}, 1e-5, "wallclock", 1, 0.0), save=False)
    live = measure_sites(tuned_db)
    assert stray not in live                               # unregistered: skipped
    assert len(live) == 2
    only = measure_sites(tuned_db, keys=[next(iter(live))])
    assert len(only) == 1


# ---------------------------------------------------------------------------
# CLI: `repro.obs report --drift` and `campaign drift`
# ---------------------------------------------------------------------------

def test_obs_cli_drift_with_live_timings(tmp_path, capsys):
    from repro.obs.cli import main

    db_path = str(tmp_path / "db.json")
    _db_with(tmp_path,
             (K_MM, {"bm": 8, "bn": 16, "bk": 32}, 1e-4),
             (K_RN, {"block_rows": 16}, 2e-4))
    live_path = str(tmp_path / "live.json")
    with open(live_path, "w") as f:
        json.dump({K_MM: 1e-4, K_RN: 1e-3}, f)
    out_path = str(tmp_path / "drift.json")
    rc = main(["report", "--drift", "--db", db_path, "--live", live_path,
               "--json-out", out_path])
    assert rc == 0
    out = capsys.readouterr().out
    assert "REGRESSED" in out and K_RN in out
    report = json.load(open(out_path))
    assert report["threshold"] == 1.5
    flagged = [e for e in report["entries"] if e["regressed"]]
    assert [e["kernel"] for e in flagged] == ["rmsnorm"]
    # --fail-on-drift turns the flag into a nonzero exit
    assert main(["report", "--drift", "--db", db_path, "--live", live_path,
                 "--fail-on-drift"]) == 1


def test_campaign_cli_drift_replay(tuned_db, tmp_path, monkeypatch, capsys):
    from repro.campaign.cli import main

    _slow_rmsnorm(monkeypatch)
    out_path = str(tmp_path / "drift.json")
    rc = main(["drift", "--db", tuned_db.path, "--threshold", "3",
               "--json-out", out_path])
    assert rc == 0
    out = capsys.readouterr().out
    assert "campaign drift report" in out and "REGRESSED" in out
    entries = json.load(open(out_path))
    assert [e["kernel"] for e in entries if e["regressed"]] == ["rmsnorm"]
    assert main(["drift", "--db", tuned_db.path, "--threshold", "3",
                 "--fail-on-drift"]) == 1
