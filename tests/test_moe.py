"""MoE routing invariants under right-padded (bucketed) inputs.

Regression coverage for the pad-routing bug: ``moe_apply`` used to route
padding tokens — they consumed expert capacity ahead of later rows' real
tokens (batched prefill) and skewed the load-balancing aux statistics,
and ``transformer.layer_apply`` never forwarded ``true_len`` at all.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

D, FF, E = 8, 16, 2


def _params(seed=0):
    p, _ = moe.moe_init(jax.random.PRNGKey(seed), D, FF, E, jnp.float32)
    return p


def _one_expert_params(seed=0):
    """Router biased so every all-positive token picks expert 0."""
    p = _params(seed)
    bias = jnp.concatenate(
        [jnp.full((D, 1), 10.0), jnp.full((D, E - 1), -10.0)], axis=1
    )
    p["router"] = bias
    return p


def _positive_x(seed, b, s):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, D))
    return jnp.abs(x) + 0.1  # positive entries => positive router logit dot


def test_pad_tokens_do_not_consume_capacity():
    """Batched bucketed prefill: row 0 is short (its tail is padding), row 1
    is full. Every token routes to expert 0; the real tokens exactly fit
    capacity — but only if row 0's pads are excluded from the cumsum.
    Pre-fix, the pads occupy slots ahead of row 1's real tokens and drop
    them to zero.
    """
    p = _one_expert_params()
    b, s = 2, 8
    true_len = jnp.array([2, 8], jnp.int32)
    x = _positive_x(1, b, s)
    # n*k = 16 -> cap = int(1.25 * 16 / 2) = 10 >= the 10 real tokens.
    kw = dict(top_k=1, capacity_factor=1.25, true_len=true_len)
    y_scatter, aux_s = moe.moe_apply(p, x, dispatch="scatter", **kw)
    y_dense, aux_d = moe.moe_apply(p, x, dispatch="dense", **kw)
    mask = (jnp.arange(s)[None, :] < true_len[:, None])[..., None]
    np.testing.assert_allclose(
        y_scatter * mask, y_dense * mask, rtol=1e-5, atol=1e-5
    )
    # No real token may be silently dropped (the pre-fix failure mode zeroes
    # the tail of row 1).
    real_norms = jnp.abs(y_scatter * mask).sum(-1)[1]
    assert bool(jnp.all(real_norms > 0)), real_norms
    np.testing.assert_allclose(aux_s, aux_d, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dispatch", ["scatter", "dense"])
def test_real_prefix_output_and_aux_pad_invariant(dispatch):
    """Bucketed prefill: output for the real prefix and the aux loss must be
    independent of how much padding the bucket added. Pre-fix the aux
    statistics (me/ce) averaged over pad tokens too.
    """
    p = _params()
    s_real = 6
    x_real = _positive_x(2, 1, s_real)
    got = []
    for pad in (2, 10):
        x_pad = jnp.pad(
            x_real, ((0, 0), (0, pad), (0, 0)), constant_values=0.9
        )
        y, aux = moe.moe_apply(
            p, x_pad, top_k=2, capacity_factor=4.0, dispatch=dispatch,
            true_len=jnp.int32(s_real),
        )
        got.append((y[:, :s_real], aux))
    np.testing.assert_allclose(got[0][0], got[1][0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got[0][1], got[1][1], rtol=1e-6, atol=1e-7)


def test_no_mask_matches_full_length_mask():
    """true_len=None must behave exactly like true_len == s (back-compat:
    the training path has no padding)."""
    p = _params()
    x = _positive_x(3, 2, 8)
    kw = dict(top_k=2, capacity_factor=1.25, dispatch="scatter")
    y0, aux0 = moe.moe_apply(p, x, **kw)
    y1, aux1 = moe.moe_apply(p, x, true_len=jnp.int32(8), **kw)
    np.testing.assert_allclose(y0, y1, rtol=0, atol=0)
    np.testing.assert_allclose(aux0, aux1, rtol=0, atol=0)


def test_scatter_matches_dense_with_mask():
    """Masked scatter dispatch must agree with the dense oracle on real
    tokens (ample capacity), for top_k in {1, 2}."""
    p = _params(seed=4)
    b, s = 2, 12
    true_len = jnp.array([5, 9], jnp.int32)
    x = _positive_x(5, b, s)
    for top_k in (1, 2):
        kw = dict(top_k=top_k, capacity_factor=8.0, true_len=true_len)
        y_s, aux_s = moe.moe_apply(p, x, dispatch="scatter", **kw)
        y_d, aux_d = moe.moe_apply(p, x, dispatch="dense", **kw)
        mask = (jnp.arange(s)[None, :] < true_len[:, None])[..., None]
        np.testing.assert_allclose(
            y_s * mask, y_d * mask, rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(aux_s, aux_d, rtol=1e-6, atol=1e-6)


def test_layer_apply_threads_true_len(monkeypatch):
    """transformer.layer_apply must forward true_len into moe_apply —
    the wiring half of the pad-routing fix."""
    from repro.configs import get_config
    from repro.models import transformer

    cfg = get_config("mixtral_8x7b").reduced()
    spec = next(
        s for seg in cfg.segments() for s in seg.pattern if "moe" in s.ffn
    )
    rng = jax.random.PRNGKey(0)
    p, _ = transformer.layer_init(rng, cfg, spec)
    seen = {}
    real_apply = moe.moe_apply

    def spy(params, xx, **kw):
        seen.update(kw)
        return real_apply(params, xx, **kw)

    monkeypatch.setattr(transformer.moe_mod, "moe_apply", spy)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), cfg.jdtype)
    transformer.layer_apply(
        p, x, spec, cfg, transformer.RunConfig(), "prefill",
        true_len=jnp.int32(5),
    )
    assert "true_len" in seen and seen["true_len"] is not None, seen
