"""Backward-kernel allclose sweeps against the ref.py VJP oracles.

Separate from test_kernels.py on purpose: that module needs hypothesis for
its property sweeps and skips wholesale without it — the backward plane's
correctness must not ride on an optional dependency.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.attention import flash_attention_bwd_pallas
from repro.kernels.rmsnorm import rmsnorm_bwd_pallas
from repro.kernels.xent import softmax_xent_bwd_pallas


def _rand(rs, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rs.randn(*shape) * scale, dtype)


@pytest.mark.parametrize("rows,d,br", [(64, 128, 16), (37, 64, 8)])
def test_rmsnorm_bwd(rs, rows, d, br):
    """Fused (dx, dw) kernel vs the VJP oracle, incl. the row-padding path."""
    x, w = _rand(rs, (rows, d)), _rand(rs, (d,))
    ct = _rand(rs, (rows, d))
    _, invrms = ref.rmsnorm_res(x, w)
    dx, dw = rmsnorm_bwd_pallas(ct, x, w, invrms, block_rows=br, interpret=True)
    dx_r, dw_r = ref.rmsnorm_bwd(ct, x, w)
    np.testing.assert_allclose(dx, dx_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dw, dw_r, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("rows,v,br,bv", [(64, 512, 16, 128), (23, 300, 8, 128)])
def test_xent_bwd(rs, rows, v, br, bv):
    """Vocab-streamed d_logits vs the VJP oracle (padding on both axes)."""
    logits = _rand(rs, (rows, v), scale=2.0)
    labels = jnp.asarray(rs.randint(0, v, rows), jnp.int32)
    ct = _rand(rs, (rows,))
    _, lse = ref.softmax_xent_res(logits, labels)
    dl = softmax_xent_bwd_pallas(ct, logits, labels, lse, block_rows=br,
                                 block_v=bv, interpret=True)
    np.testing.assert_allclose(dl, ref.softmax_xent_bwd(ct, logits, labels),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_bwd(rs, causal, window):
    """(dq, dk, dv) vs the VJP oracle across masking modes, with GQA."""
    b, h, kv, s, d = 2, 4, 2, 128, 16
    q = _rand(rs, (b, h, s, d), scale=0.3)
    k = _rand(rs, (b, kv, s, d), scale=0.3)
    v = _rand(rs, (b, kv, s, d))
    ct = _rand(rs, (b, h, s, d))
    o, lse = ref.attention_res(q, k, v, causal=causal, window=window)
    dq, dk, dv = flash_attention_bwd_pallas(
        ct, q, k, v, o, lse, block_q=64, block_k=64, causal=causal,
        window=window, interpret=True,
    )
    dq_r, dk_r, dv_r = ref.attention_bwd(ct, q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(dq, dq_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dk, dk_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dv, dv_r, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block_q,block_k", [(32, 128), (128, 32), (64, 64)])
def test_flash_attention_bwd_blocks(rs, block_q, block_k):
    """Gradients are block-schedule invariant (the tunable's contract)."""
    b, h, kv, s, d = 1, 4, 2, 128, 16
    q = _rand(rs, (b, h, s, d), scale=0.3)
    k = _rand(rs, (b, kv, s, d), scale=0.3)
    v = _rand(rs, (b, kv, s, d))
    ct = _rand(rs, (b, h, s, d))
    o, lse = ref.attention_res(q, k, v, causal=True)
    grads = flash_attention_bwd_pallas(
        ct, q, k, v, o, lse, block_q=block_q, block_k=block_k, causal=True,
        interpret=True,
    )
    want = ref.attention_bwd(ct, q, k, v, causal=True)
    for g, w_ in zip(grads, want):
        np.testing.assert_allclose(g, w_, rtol=2e-4, atol=2e-4)
