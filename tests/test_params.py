"""Unit + property tests for the tunable parameter-space layer."""
import random

import pytest

pytest.importorskip("hypothesis")  # property tests need it; collection must not die
from hypothesis import given, settings, strategies as st

from repro.core import (
    BoolParam,
    Constraint,
    EnumParam,
    IntParam,
    ParamSpace,
    PowerOfTwoParam,
)


def space_small():
    return ParamSpace(
        [
            PowerOfTwoParam("bm", 8, 64),
            EnumParam("order", ["mn", "nm"]),
            BoolParam("flag"),
        ],
        [Constraint(lambda c: not (c["bm"] == 64 and c["flag"]), "64+flag invalid")],
    )


def test_pow2_domain():
    p = PowerOfTwoParam("x", 8, 64)
    assert p.choices == (8, 16, 32, 64)
    p = PowerOfTwoParam("x", 5, 33)
    assert p.choices == (8, 16, 32)


def test_pow2_bad_range():
    with pytest.raises(ValueError):
        PowerOfTwoParam("x", 0, 8)
    with pytest.raises(ValueError):
        PowerOfTwoParam("x", 65, 64)


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        ParamSpace([IntParam("a", [1]), IntParam("a", [2])])


def test_enumerate_respects_constraints():
    sp = space_small()
    cfgs = list(sp.enumerate())
    assert len(cfgs) == 4 * 2 * 2 - 2  # minus the two 64+flag combos
    assert all(sp.is_valid(c) for c in cfgs)
    assert all(not (c["bm"] == 64 and c["flag"]) for c in cfgs)


def test_why_invalid():
    sp = space_small()
    assert sp.why_invalid({"bm": 64, "order": "mn", "flag": True}) == "64+flag invalid"
    assert sp.why_invalid({"bm": 3, "order": "mn", "flag": False}) is not None
    assert sp.why_invalid({"bm": 8, "order": "mn", "flag": False}) is None


def test_neighbors_one_step():
    sp = space_small()
    cfg = {"bm": 16, "order": "mn", "flag": False}
    nbrs = sp.neighbors(cfg)
    assert all(sp.is_valid(n) for n in nbrs)
    for n in nbrs:
        diffs = [k for k in cfg if n[k] != cfg[k]]
        assert len(diffs) == 1


@given(seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_sample_always_valid(seed):
    sp = space_small()
    cfg = sp.sample(random.Random(seed))
    assert sp.is_valid(cfg)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_crossover_valid(seed):
    sp = space_small()
    rng = random.Random(seed)
    a, b = sp.sample(rng), sp.sample(rng)
    child = sp.crossover(a, b, rng)
    assert sp.is_valid(child)
    for k in child:
        assert child[k] in (a[k], b[k])


def test_empty_space_raises():
    sp = ParamSpace(
        [IntParam("a", [1, 2])], [Constraint(lambda c: False, "nothing valid")]
    )
    with pytest.raises(RuntimeError):
        sp.default()


def test_config_key_stable():
    k1 = ParamSpace.config_key({"b": 2, "a": 1})
    k2 = ParamSpace.config_key({"a": 1, "b": 2})
    assert k1 == k2
