"""Sharding-solver properties: divisibility is never violated, no mesh axis
is used twice in one tensor, head-aware mode never splits a head, and the
cache solver shards what it can.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; collection must not die
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import Layout, batch_spec, cache_shardings, spec_for_dims
from repro.launch.mesh import make_host_mesh


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    # abstract mesh over the single CPU device repeated is not allowed;
    # use jax.sharding.Mesh with a numpy device array of the right shape.
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = fake_mesh()
L = Layout(counts=(("heads", 6), ("kv_heads", 2), ("experts", 4)))


def _check_spec(spec, dims, shape, mesh):
    used = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in parts:
            size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
            used.append(a)
        assert shape[i] % size == 0, (dims, shape, spec)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


@given(
    dims=st.lists(
        st.sampled_from(["vocab", "ff", "heads", "kv_heads", "experts", "d_model", "other"]),
        min_size=1, max_size=3, unique=True,
    ),
    sizes=st.lists(st.sampled_from([1, 2, 3, 8, 16, 64, 256]), min_size=3, max_size=3),
    fsdp=st.booleans(),
)
@settings(max_examples=60, deadline=None)
def test_spec_never_violates_divisibility(dims, sizes, fsdp):
    shape = tuple(sizes[: len(dims)])
    layout = Layout(fsdp=fsdp, counts=L.counts)
    spec = spec_for_dims(dims, shape, MESH, layout)
    _check_spec(spec, dims, shape, MESH)


def test_ff_prefers_model_axis():
    spec = spec_for_dims(("d_model", "ff"), (64, 128), MESH, Layout())
    assert spec == P(None, "model")


def test_vocab_beats_ff():
    spec = spec_for_dims(("vocab", "ff"), (256, 128), MESH, Layout())
    assert spec == P("model")


def test_head_aware_blocks_mid_head_split():
    # fused heads dim 6*16=96 divides the 2-way axis, but 6 heads would
    # split 3-heads-per-device... fine; use a 4-way tensor axis instead
    mesh = fake_mesh((2, 4))
    layout = Layout(counts=(("heads", 6),), head_aware=True)
    spec = spec_for_dims(("d_model", "heads"), (64, 96), mesh, layout)
    assert spec == P()  # 6 % 4 != 0 -> refuse
    naive = Layout(counts=(("heads", 6),), head_aware=False)
    spec2 = spec_for_dims(("d_model", "heads"), (64, 96), mesh, naive)
    assert spec2 == P(None, "model")  # the baseline pathology


def test_fsdp_shards_d_model_over_data():
    layout = Layout(fsdp=True)
    spec = spec_for_dims(("d_model", "ff"), (64, 128), MESH, layout)
    assert spec == P("data", "model")


def test_batch_spec_divisibility():
    assert batch_spec(MESH, Layout(), 8) == P("data")
    assert batch_spec(MESH, Layout(), 3) == P()   # 3 % 4 != 0


def test_cache_shardings_full_and_b1():
    mesh = fake_mesh((4, 2))
    layout = Layout()
    # attn cache [layers, batch, len, kv, hd]
    spec = jax.ShapeDtypeStruct((8, 16, 1024, 2, 64), np.float32)
    sh = cache_shardings({"k": spec}, mesh, layout)["k"].spec
    assert sh[1] == "data"
    assert "model" in sh  # largest divisible dim got the tensor axis
    # B=1 long-context: batch unshardable -> sequence-parallel cache
    spec1 = jax.ShapeDtypeStruct((8, 1, 4096, 2, 64), np.float32)
    sh1 = cache_shardings({"k": spec1}, mesh, layout)["k"].spec
    flat = [a for p in sh1 if p for a in (p if isinstance(p, tuple) else (p,))]
    assert "data" in flat and "model" in flat


def test_host_mesh_spec_degenerates():
    mesh = make_host_mesh()
    spec = spec_for_dims(("d_model", "ff"), (64, 128), mesh, Layout())
    _check_spec(spec, ("d_model", "ff"), (64, 128), mesh)
