"""Launch layer: build_cell/lower_cell must lower + compile every step kind
on a multi-device mesh (8 fake host devices, subprocess), and the dry-run
record machinery must produce roofline-ready numbers.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import SHAPES, get_config
from repro.configs.base import cell_is_runnable, input_specs


def test_cell_runnability_rule():
    ok, _ = cell_is_runnable(get_config("xlstm-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, why = cell_is_runnable(get_config("qwen2.5-3b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = cell_is_runnable(get_config("jamba-1.5-large-398b"), SHAPES["long_500k"])
    assert ok


def test_input_specs_layouts():
    cfg = get_config("musicgen-large")
    s = input_specs(cfg, SHAPES["train_4k"])
    assert s["embeds"].shape == (256, 4096, cfg.d_model)
    cfg = get_config("paligemma-3b")
    s = input_specs(cfg, SHAPES["prefill_32k"])
    assert s["embeds"].shape[1] == cfg.num_prefix
    assert s["tokens"].shape[1] == 32768 - cfg.num_prefix
    s = input_specs(cfg, SHAPES["decode_32k"])
    assert s["tokens"].shape == (128, 1)


_MINI_DRYRUN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from repro.configs.base import ShapeSpec, get_config
    from repro.core.evaluate import collective_stats, roofline_from_compiled
    from repro.distributed.sharding import Layout
    from repro.launch import steps
    from repro.models.transformer import RunConfig

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen2-0.5b").reduced()
    layout = Layout(counts=(("heads", cfg.num_heads), ("kv_heads", cfg.num_kv_heads)),
                    head_aware=True)
    results = {}
    cells = [
        ShapeSpec("t", 64, 8, "train"),
        ShapeSpec("p", 64, 8, "prefill"),
        ShapeSpec("d", 64, 8, "decode"),
    ]
    for shape in cells:
        run = RunConfig(remat="none", q_chunk=32, k_chunk=64, loss_chunk=32,
                        microbatches=2 if shape.kind == "train" else 1)
        cell = steps.build_cell(cfg, shape, mesh, layout, run)
        compiled = steps.lower_cell(cell, mesh).compile()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        terms = roofline_from_compiled(compiled, chips=8, hlo_text=hlo)
        mem = compiled.memory_analysis()
        results[shape.kind] = {
            "collective_bytes": coll["total_bytes"],
            "n_collectives": coll["count"],
            "arg_bytes": int(mem.argument_size_in_bytes),
        }
    print("MINI_DRYRUN_JSON=" + json.dumps(results))
    """
)


def test_mini_dryrun_all_step_kinds():
    r = subprocess.run(
        [sys.executable, "-c", _MINI_DRYRUN],
        capture_output=True, text=True, timeout=560,
        # JAX_PLATFORMS=cpu: without it jax probes the bundled libtpu on this
        # image and hangs for minutes before falling back to CPU
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=".",
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("MINI_DRYRUN_JSON=")),
        None,
    )
    assert line, f"stdout={r.stdout[-1500:]} stderr={r.stderr[-2500:]}"
    results = json.loads(line.split("=", 1)[1])
    assert set(results) == {"train", "prefill", "decode"}
    # a sharded train step must contain collectives (grad all-reduce at least)
    assert results["train"]["n_collectives"] > 0
    assert results["train"]["collective_bytes"] > 0
    for kind in results:
        assert results[kind]["arg_bytes"] > 0
