"""SSM mixer invariants: chunked-parallel forms ≡ sequential decode, state
handoff across prefill→decode, chunk-size invariance (the tunable must not
change math).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; collection must not die
from hypothesis import given, settings, strategies as st

from repro.models import ssm

B, S, D, H = 2, 24, 32, 4


def _x(seed=1, s=S):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, s, D)) * 0.5


def test_mamba_parallel_equals_sequential(rs):
    p, _ = ssm.mamba_init(jax.random.PRNGKey(0), D, jnp.float32)
    x = _x()
    y_par, st_par = ssm.mamba_forward(p, x, return_state=True)
    state = {"h": jnp.zeros((B, 2 * D, 16)), "conv": jnp.zeros((B, 3, 2 * D))}
    ys = []
    for t in range(S):
        yt, state = ssm.mamba_decode(p, x[:, t : t + 1], state)
        ys.append(yt)
    np.testing.assert_allclose(y_par, jnp.concatenate(ys, 1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(st_par["h"], state["h"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(st_par["conv"], state["conv"], rtol=1e-5, atol=1e-6)


@given(chunk=st.sampled_from([1, 3, 8, 24, 32]))
@settings(max_examples=5, deadline=None)
def test_mamba_chunk_invariance(chunk):
    """The scan chunk schedule is a pure performance parameter — math must
    not move. Pinned through the scan_fn hook (mamba_forward's old inert
    chunk arg is removed; the schedule belongs to the ssm_scan tunable)."""
    import functools

    from repro.kernels.ssm_scan import ssm_scan_chunked

    p, _ = ssm.mamba_init(jax.random.PRNGKey(0), D, jnp.float32)
    x = _x()
    base = ssm.mamba_forward(p, x)
    out = ssm.mamba_forward(
        p, x, scan_fn=functools.partial(ssm_scan_chunked, chunk=chunk))
    np.testing.assert_allclose(out, base, rtol=1e-4, atol=1e-5)


def test_mlstm_parallel_equals_sequential():
    p, _ = ssm.mlstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    y_par, st_par = ssm.mlstm_forward(p, x, n_heads=H, chunk=8, return_state=True)
    hd = 2 * D // H
    state = {
        "C": jnp.zeros((B, H, hd, hd)),
        "n": jnp.zeros((B, H, hd)),
        "m": jnp.zeros((B, H)),
    }
    ys = []
    for t in range(S):
        yt, state = ssm.mlstm_decode(p, x[:, t : t + 1], state, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(y_par, jnp.concatenate(ys, 1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_par["C"], state["C"], rtol=1e-4, atol=1e-4)


@given(chunk=st.sampled_from([2, 6, 12, 24]))
@settings(max_examples=4, deadline=None)
def test_mlstm_chunk_invariance(chunk):
    p, _ = ssm.mlstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    base = ssm.mlstm_forward(p, x, n_heads=H, chunk=S)
    out = ssm.mlstm_forward(p, x, n_heads=H, chunk=chunk)
    np.testing.assert_allclose(out, base, rtol=1e-4, atol=1e-4)


def test_slstm_parallel_equals_sequential():
    p, _ = ssm.slstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    y_par, st_par = ssm.slstm_forward(p, x, n_heads=H, return_state=True)
    state = {k: jnp.zeros((B, D)) for k in ("c", "n", "h", "m")}
    ys = []
    for t in range(S):
        yt, state = ssm.slstm_decode(p, x[:, t : t + 1], state, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(y_par, jnp.concatenate(ys, 1), rtol=1e-4, atol=1e-5)
    for k in state:
        np.testing.assert_allclose(st_par[k], state[k], rtol=1e-4, atol=1e-5)


def test_slstm_unroll_invariance():
    p, _ = ssm.slstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    base = ssm.slstm_forward(p, x, n_heads=H, unroll=1)
    out = ssm.slstm_forward(p, x, n_heads=H, unroll=4)
    np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-6)


def test_no_nans_with_extreme_gates():
    """Exp gating must stay stabilized for large inputs (long sequences)."""
    p, _ = ssm.mlstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x() * 20.0
    out = ssm.mlstm_forward(p, x, n_heads=H, chunk=8)
    assert bool(jnp.all(jnp.isfinite(out)))
    p2, _ = ssm.slstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    out2 = ssm.slstm_forward(p2, x, n_heads=H)
    assert bool(jnp.all(jnp.isfinite(out2)))
