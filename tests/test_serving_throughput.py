"""Scheduling economics of the slot pool, on a deterministic fake clock.

The engine's virtual clock IS the fake clock: 1 tick = one pool decode
step, so `stats["decode_steps"]` and per-request `latency_steps` are exact
integers — no wall-time flakiness. A counting wall clock is injected where
wall latency attribution itself is under test.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.serving.engine import (
    EngineConfig, LockStepEngine, Request, ServingEngine,
)

RUN = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16)


class CountingClock:
    """Deterministic wall clock: each reading is 1.0 later than the last."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1.0
        return self.t


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, spec):
    """spec: list of (max_new, arrival). Prompts all length 9, deterministic."""
    rs = np.random.RandomState(4)
    out = []
    for max_new, arrival in spec:
        out.append(Request(
            prompt=rs.randint(0, cfg.vocab_size, 9).astype(np.int32),
            max_new_tokens=max_new, arrival_time=arrival,
        ))
    return out


def test_inflight_admission_reduces_decode_steps(model):
    """Skewed workload: one long request + many short ones. Lock-step decodes
    the short ones at the long one's cadence batch after batch; the slot pool
    retires them mid-flight and strictly saves pool decode steps."""
    cfg, params = model
    spec = [(24, 0.0), (4, 0.0), (4, 0.0), (4, 0.0), (4, 0.0), (4, 0.0)]

    lock = LockStepEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=64),
    )
    for r in _reqs(cfg, spec):
        lock.submit(r)
    lock_done = lock.serve()
    lock_steps = lock.stats["decode_steps"]

    cont = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=64),
    )
    for r in _reqs(cfg, spec):
        cont.submit(r)
    cont_done = cont.serve()
    cont_steps = cont.stats["decode_steps"]

    assert len(lock_done) == len(cont_done) == len(spec)
    # identical tokens out of both engines (greedy, same prompts)
    for a, b in zip(lock_done, cont_done):
        np.testing.assert_array_equal(a.output, b.output)
    assert cont_steps < lock_steps, (cont_steps, lock_steps)
    # exact accounting: lock-step pays max(new) per batch of 2:
    #   [24,4] -> 24, [4,4] -> 4, [4,4] -> 4 = 32; the pool finishes when the
    #   long request does (24 tokens = 23 decode ticks after its prefill)
    assert lock_steps == 32
    assert cont_steps == 23
    # the saving is idle-slot work the pool reassigned mid-flight
    assert cont.stats["tokens_out"] == sum(n for n, _ in spec)


def test_decode_steps_equal_on_uniform_workload(model):
    """No skew, full batches: the slot pool cannot do better than lock-step
    (both decode max_new-1 ticks per wave) — guard against miscounting."""
    cfg, params = model
    spec = [(6, 0.0)] * 4
    lock = LockStepEngine(cfg, RUN, params, make_host_mesh(), Layout(),
                          EngineConfig(max_batch=4, max_seq=64))
    cont = ServingEngine(cfg, RUN, params, make_host_mesh(), Layout(),
                         EngineConfig(max_batch=4, max_seq=64))
    for r in _reqs(cfg, spec):
        lock.submit(r)
    for r in _reqs(cfg, spec):
        cont.submit(r)
    lock.serve()
    cont.serve()
    # lock-step runs one extra step (it decodes after the last kept token)
    assert cont.stats["decode_steps"] == 5
    assert lock.stats["decode_steps"] == 6


def test_latency_attributed_from_admission(model):
    """Regression: a request admitted late (queued behind a long occupant)
    is charged from ITS admission, not the batch/engine start."""
    cfg, params = model
    clock = CountingClock()
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=1, max_seq=64), clock=clock,
    )
    a, b = _reqs(cfg, [(10, 0.0), (10, 0.0)])
    eng.submit(a)
    eng.submit(b)
    da, db = eng.serve()
    # same work -> same tick latency, though b finished twice as late
    assert da.latency_steps == db.latency_steps == 9
    assert db.finished_step == 2 * da.finished_step == 18
    assert db.admitted_step == 9
    assert db.queue_steps == 9
    # wall clock: one admission reading + one finish reading per request on
    # the counting clock -> identical attributed latency for identical work
    assert da.latency_s == db.latency_s
    # steps-based p50 would have been 13.5 under whole-batch attribution
    assert da.latency_steps + db.latency_steps == 18


def test_late_arrival_not_charged_for_queue_wait(model):
    """A request that ARRIVES late must not be charged for ticks before its
    arrival either; queue_steps counts arrival -> admission only."""
    cfg, params = model
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=64),
    )
    spec = [(12, 0.0), (4, 5.0)]
    a, b = _reqs(cfg, spec)
    eng.submit(a)
    eng.submit(b)
    da, db = eng.serve()
    assert db.admitted_step == 5           # a free slot was waiting
    assert db.queue_steps == 0
    assert db.latency_steps == 3           # its own 4 tokens, nothing else
    assert da.latency_steps == 11


def test_idle_engine_jumps_to_next_arrival(model):
    """No busy-spinning: with nothing in flight the clock jumps straight to
    the next arrival instead of burning decode steps."""
    cfg, params = model
    eng = ServingEngine(
        cfg, RUN, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=64),
    )
    (r,) = _reqs(cfg, [(4, 100.0)])
    eng.submit(r)
    (done,) = eng.serve()
    assert done.admitted_step == 100
    assert eng.stats["decode_steps"] == 3  # only its own ticks
