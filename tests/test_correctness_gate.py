"""correctness_gate edge cases: NaN-in-reference, zero-size leaves,
mismatched tree structure, and dtype-aware (bf16) tolerance scaling."""
import jax.numpy as jnp
import numpy as np

from repro.core.evaluate import correctness_gate, tolerance_for


def test_nan_in_reference_matching_positions_pass():
    ref = np.array([1.0, np.nan, 3.0], np.float32)
    out = np.array([1.0, np.nan, 3.0], np.float32)
    assert correctness_gate(out, ref)


def test_nan_in_output_where_reference_finite_fails():
    ref = np.array([1.0, 2.0, 3.0], np.float32)
    out = np.array([1.0, np.nan, 3.0], np.float32)
    assert not correctness_gate(out, ref)


def test_nan_positions_must_align():
    ref = np.array([np.nan, 2.0], np.float32)
    out = np.array([2.0, np.nan], np.float32)
    assert not correctness_gate(out, ref)


def test_all_nan_reference_does_not_blow_up_scale():
    ref = np.full((4,), np.nan, np.float32)
    assert correctness_gate(np.full((4,), np.nan, np.float32), ref)
    assert not correctness_gate(np.zeros((4,), np.float32), ref)


def test_zero_size_leaves_pass():
    ref = {"a": np.zeros((0, 8), np.float32), "b": np.ones((2,), np.float32)}
    out = {"a": np.zeros((0, 8), np.float32), "b": np.ones((2,), np.float32)}
    assert correctness_gate(out, ref)


def test_zero_size_vs_nonzero_shape_fails():
    assert not correctness_gate(np.zeros((0,), np.float32), np.zeros((1,), np.float32))


def test_mismatched_tree_structure_same_leaf_count_fails():
    x = np.ones((2,), np.float32)
    y = np.zeros((2,), np.float32)
    assert not correctness_gate({"a": x, "b": y}, [x, y])
    assert not correctness_gate((x, (y,)), ((x,), y))
    # same structure still passes
    assert correctness_gate({"a": x, "b": y}, {"a": x, "b": y})


def test_bf16_tolerance_scales_with_dtype():
    ref = jnp.ones((8,), jnp.float32)
    drift = 5e-3  # within bf16 tolerance, far outside f32 tolerance
    out_bf16 = (jnp.ones((8,)) + drift).astype(jnp.bfloat16)
    out_f32 = jnp.ones((8,), jnp.float32) + drift
    assert correctness_gate(out_bf16, ref)       # coarser dtype decides
    assert not correctness_gate(out_f32, ref)    # f32 variant held to f32 tol
    # explicit tolerances override the dtype rule
    assert correctness_gate(out_f32, ref, rtol=1e-2, atol=1e-2)
    assert not correctness_gate(out_bf16, ref, rtol=1e-6, atol=1e-6)


def test_bf16_tolerance_applies_before_f32_upcast():
    """The upcast-to-f32 used for comparison must not reset the tolerance."""
    rt, at = tolerance_for(jnp.bfloat16)
    assert rt >= 1e-2
    ref = jnp.asarray(np.linspace(0.5, 2.0, 16), jnp.bfloat16)
    out = ref + ref * 1e-2                      # 1% off: bf16-ok, f32-not
    assert correctness_gate(out, ref)


def test_tolerance_scale_uses_finite_reference_magnitude():
    ref = np.array([np.inf, 100.0, -100.0], np.float32)
    out = np.array([np.inf, 100.0, -100.0], np.float32)
    assert correctness_gate(out, ref)
    # the finite magnitude (100) scales atol; a 2e-3 absolute error passes f32
    out2 = np.array([np.inf, 100.0005, -100.0], np.float32)
    assert correctness_gate(out2, ref)
