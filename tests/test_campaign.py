"""Campaign subsystem: plan determinism, dedup/priority/budget, resume after
interrupt, transfer warm-start evaluation savings, export → zero-tune serve."""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (
    CampaignManifest,
    TuningJob,
    allocate_budget,
    cluster_winners,
    dedupe_jobs,
    export_campaign_db,
    plan_jobs,
    prioritize_jobs,
    run_campaign,
    warm_start_configs,
)
from repro.campaign.scheduler import build_manifest
from repro.core import Record, TuningDatabase, make_key, set_default_db, tune_or_lookup
from repro.core.evaluate import Evaluator, Measurement
from repro.core.platform import detect_platform

ARCHES = ["qwen2_0_5b", "minitron_4b", "qwen2_5_3b"]
PLAN_KW = dict(
    train_shapes=("train_4k",),
    serving=(2, 32),
    reduced=True,
    max_tokens=64,
    max_seq=32,
)


class SurrogateEvaluator(Evaluator):
    """Deterministic config-only objective (no compilation, no timing).

    Score = sum over numeric knobs of |log2(v) - log2(64)| — a separable
    bowl whose optimum is each domain's value nearest 64. Lets campaign
    mechanics (scheduling, resume, transfer) be asserted exactly.
    """

    name = "surrogate"

    def evaluate(self, fn, args, reference=None):
        config = getattr(fn, "keywords", {})
        score = 0.05
        for v in config.values():
            if isinstance(v, (int, float)) and v > 0:
                score += abs(math.log2(v) - math.log2(64))
        return Measurement(score, True)


MATMUL_OPT = {"bm": 64, "bn": 128, "bk": 128}     # surrogate optimum in MATMUL_SPACE


# ------------------------------------------------------------------ planning


def test_plan_is_deterministic():
    a = plan_jobs(ARCHES, **PLAN_KW)
    b = plan_jobs(ARCHES, **PLAN_KW)
    assert a == b
    assert len(a) > 20


def test_plan_covers_arches_and_serving_buckets():
    from repro.configs import get_config

    jobs = plan_jobs(ARCHES, **PLAN_KW)
    scens = {s for j in jobs for s in j.scenarios}
    for arch in ARCHES:
        cfg_name = get_config(arch).name
        assert any(s.startswith(f"{cfg_name}/train_4k") for s in scens), arch
    # serving buckets present for every servable arch: (2, 32) -> b in {1,2}
    assert any("serve_prefill_b1s16" in s for s in scens)
    assert any("serve_decode_b2s32" in s for s in scens)


def test_dedupe_merges_weights_and_scenarios():
    jobs = plan_jobs(ARCHES, **PLAN_KW)
    platform = detect_platform().name
    unique = dedupe_jobs(jobs, platform)
    keys = [j.db_key(platform) for j in unique]
    assert len(keys) == len(set(keys)) < len(jobs)
    assert abs(sum(j.weight for j in unique) - sum(j.weight for j in jobs)) < 1e-6
    merged = max(unique, key=lambda j: len(j.scenarios))
    assert len(merged.scenarios) > 1


def test_prioritize_and_allocate_budget():
    jobs = prioritize_jobs(dedupe_jobs(plan_jobs(ARCHES, **PLAN_KW), "cpu-host"))
    assert all(j.priority > 0 for j in jobs)
    assert jobs == sorted(jobs, key=lambda j: -j.priority)
    funded = allocate_budget(jobs, total_budget=100, min_budget=6, max_budget=30)
    spent = sum(j.budget for j in funded)
    assert 0 < spent <= 100
    assert all(j.budget == 0 or 6 <= j.budget <= 30 for j in funded)
    # higher priority never gets less budget than a lower-priority job
    budgets = [j.budget for j in funded if j.budget > 0]
    assert budgets == sorted(budgets, reverse=True)


# ------------------------------------------------------------------- running


def _mini_manifest(tmp_path, name, budget_per_job=50, kernels=("matmul",)):
    jobs = plan_jobs(ARCHES, kernels=kernels, **PLAN_KW)
    m = build_manifest(jobs, total_budget=10_000, path=str(tmp_path / name))
    for j in m.jobs:
        j.budget = budget_per_job
    m.save()
    return m


def test_run_resumes_from_manifest(tmp_path):
    m = _mini_manifest(tmp_path, "m.json", kernels=("rmsnorm",))
    db = TuningDatabase(str(tmp_path / "db.json"))
    n_jobs = len([j for j in m.jobs if j.budget > 0])
    assert n_jobs >= 2
    run_campaign(m, db, evaluator=SurrogateEvaluator(), max_jobs=1)

    # interrupt here: a fresh process sees one banked job, the rest pending
    m2 = CampaignManifest.load(str(tmp_path / "m.json"))
    assert m2.counts()["done"] == 1
    assert m2.counts()["pending"] == n_jobs - 1
    done = [j for j in m2.jobs if j.status == "done"][0]
    assert done.evaluations > 0 and done.best_objective > 0

    summary = run_campaign(m2, TuningDatabase(str(tmp_path / "db.json")),
                           evaluator=SurrogateEvaluator())
    assert summary["done"] == n_jobs and summary["failed"] == 0
    # resumed run did not redo the first job (its state came from the manifest)
    assert [j.evaluations for j in m2.jobs if j.status == "done"]


def test_warm_start_reduces_evaluations_vs_cold(tmp_path):
    """Transfer seeding must save search budget on the matmul kernel."""
    platform = detect_platform().name

    # cold control: transfer disabled entirely (an empty db would still
    # self-seed job 2 from job 1's fresh record — that cascade is the
    # feature, so the control must switch it off)
    cold_m = _mini_manifest(tmp_path, "cold.json")
    cold_db = TuningDatabase(str(tmp_path / "cold_db.json"))
    run_campaign(cold_m, cold_db, evaluator=SurrogateEvaluator(), max_jobs=2,
                 warm_start=False)

    warm_m = _mini_manifest(tmp_path, "warm.json")
    warm_db = TuningDatabase(str(tmp_path / "warm_db.json"))
    # a sibling-bucket record at the surrogate optimum = the transfer source
    warm_db.put(Record(
        make_key("matmul", platform, [(8192, 64), (64, 128)], "float32"),
        dict(MATMUL_OPT), 0.05, "surrogate", 20, 0.0,
    ))
    run_campaign(warm_m, warm_db, evaluator=SurrogateEvaluator(), max_jobs=2)

    cold_jobs = {j.db_key(platform): j for j in cold_m.jobs if j.status == "done"}
    warm_jobs = {j.db_key(platform): j for j in warm_m.jobs if j.status == "done"}
    assert set(cold_jobs) == set(warm_jobs)
    for key, warm in warm_jobs.items():
        cold = cold_jobs[key]
        assert warm.seeded and not cold.seeded
        assert warm.best_objective <= cold.best_objective + 1e-9
        assert warm.evaluations < cold.evaluations, key
    assert (sum(j.evaluations for j in warm_jobs.values())
            < sum(j.evaluations for j in cold_jobs.values()))


def test_warm_start_configs_ranking(tmp_path):
    db = TuningDatabase(None)
    db.put(Record(make_key("k", "cpu-host", [(64,)], "f32"), {"a": 1}, 1.0, "w", 1, 0.0))
    db.put(Record(make_key("k", "cpu-host", [(4096,)], "f32"), {"a": 2}, 1.0, "w", 1, 0.0))
    db.put(Record(make_key("k", "tpu-v5e", [(128,)], "f32"), {"a": 3}, 1.0, "w", 1, 0.0))
    db.put(Record(make_key("other", "cpu-host", [(128,)], "f32"), {"a": 4}, 1.0, "w", 1, 0.0))
    seeds = warm_start_configs(db, "k", "cpu-host", [(128,)], "f32")
    # nearest same-platform bucket first, then the far one, then the sibling
    assert seeds == [{"a": 1}, {"a": 2}, {"a": 3}]
    # exact-key records are a db hit, not a transfer
    seeds = warm_start_configs(db, "k", "cpu-host", [(64,)], "f32")
    assert {"a": 1} not in seeds


# ---------------------------------------------------------- export + serving


def test_cluster_winners_few_fit_most():
    recs = []
    for i, shape in enumerate([(64,), (128,), (256,), (512,)]):
        recs.append(Record(make_key("k", "p", [shape], "f32"),
                           {"a": 1}, 1.0, "w", 1, float(i)))
    recs.append(Record(make_key("k", "p", [(4096,)], "f32"),
                       {"a": 9}, 1.0, "w", 1, 9.0))
    entries = cluster_winners(recs, max_size=4)
    assert entries[0]["config"] == {"a": 1}
    assert entries[0]["share"] == pytest.approx(0.8)
    assert len(entries[0]["support"]) == 4
    assert entries[1]["config"] == {"a": 9}


def test_export_drives_dispatch_with_zero_tuning(tmp_path):
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_tunable

    m = _mini_manifest(tmp_path, "m.json", kernels=("rmsnorm",))
    db = TuningDatabase(str(tmp_path / "db.json"))
    run_campaign(m, db, evaluator=SurrogateEvaluator())
    platform = detect_platform().name
    exported = export_campaign_db(db, str(tmp_path / "artifact.json"), platform)
    assert len(exported) > 0 and exported.lookup_cover("rmsnorm", platform)

    # a fresh deployment: generic code + the exported artifact, no tuning
    serve_db = TuningDatabase(str(tmp_path / "artifact.json"))
    tuned = [j for j in m.jobs if j.status == "done" and j.kernel == "rmsnorm"][0]
    x = jnp.ones(tuned.arg_shapes[0], jnp.float32)
    w = jnp.ones(tuned.arg_shapes[1], jnp.float32)
    cfg = tune_or_lookup(rmsnorm_tunable, (x, w), db=serve_db, allow_tune=False)
    assert cfg == serve_db.lookup(tuned.db_key(platform)).config

    # unseen bucket: the cover set answers (surrogate optimum 64), not the
    # heuristic (1024 for this width) — measured fallback, still zero tuning
    x2 = jnp.ones((2**17, 64), jnp.float32)
    cfg2 = tune_or_lookup(rmsnorm_tunable, (x2, w), db=serve_db, allow_tune=False)
    assert cfg2 == {"block_rows": 64}
    assert rmsnorm_tunable.default_config(x2, w) == {"block_rows": 1024}

    # and runtime dispatch consumes the same artifact end-to-end
    import repro

    with repro.runtime(mode="kernel", db=serve_db):
        out = repro.dispatch("rmsnorm", x, w)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(jnp.ones_like(x)),  # rmsnorm of ones with unit weight
        rtol=1e-5, atol=1e-5,
    )


def test_serving_engine_warmup(tmp_path):
    import jax

    from repro.configs import get_config
    from repro.distributed.sharding import Layout
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.models.transformer import RunConfig
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(
        cfg, RunConfig(remat="none"), params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=32),
    )
    assert eng.serving_buckets() == [(1, 16), (1, 32), (2, 16), (2, 32)]

    platform = detect_platform().name
    db = TuningDatabase(str(tmp_path / "db.json"))
    stored = {"block_rows": 16}
    # slot-pool bucket: admission prefill is batch-1, rows = seq bucket
    key = make_key("rmsnorm", platform, [(32, cfg.d_model), (cfg.d_model,)],
                   "float32")
    db.put(Record(key, stored, 1e-6, "wallclock", 1, 0.0))
    try:
        resolved = eng.warmup(db)
        assert len(resolved) > 0
        assert resolved[key] == stored            # exact record wins
        # the warmed db must be what ops dispatch will actually read
        from repro.core.database import default_db
        assert default_db() is db
        from repro.core.annotate import get_tunable
        for k, config in resolved.items():
            kernel = k.split("|")[0]
            assert get_tunable(kernel).space.is_valid(config), (k, config)
    finally:
        set_default_db(TuningDatabase(None))


def test_plan_training_jobs_local_shapes():
    """Sharding-aware training jobs key on per-device local shard shapes:
    batch-leading dims divided by the data-parallel degree of the Layout ×
    mesh, token rows scaled to match — what dispatch under a mesh_context
    actually looks up."""
    from repro.campaign import plan_training_jobs
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import Layout

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]            # B=8, S=64
    layout = Layout(counts=(("heads", cfg.num_heads),
                            ("kv_heads", cfg.num_kv_heads)))
    jobs = plan_training_jobs(cfg, shape, layout=layout, mesh_axes="2x4")
    by_kernel = {}
    for j in jobs:
        by_kernel.setdefault(j.kernel, []).append(j)
    # dp=2 (data axis): 8/2=4 local batch; T = 4*64 = 256 token rows
    attn = by_kernel["flash_attention"][0]
    assert attn.arg_shapes[0] == (4, cfg.num_heads, 64, cfg.hd)
    assert attn.key_extra == "cTruew0"
    norm = by_kernel["rmsnorm"][0]
    assert norm.arg_shapes[0] == (256, cfg.d_model)
    # smoke run: loss_chunk=32 -> xent rows = 4 * 32 = 128
    xent = by_kernel["softmax_xent"][0]
    assert xent.arg_shapes == ((128, cfg.vocab_size), (128,))
    assert xent.arg_dtypes[-1] == "int32"
    # the dispatch-site matmuls are all present: q, k/v, o, ffn up+down, unembed
    mm_shapes = {j.arg_shapes for j in by_kernel["matmul"]}
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.num_heads, cfg.num_kv_heads
    assert ((256, d), (d, H * hd)) in mm_shapes
    assert ((256, d), (d, KV * hd)) in mm_shapes
    assert ((256, H * hd), (H * hd, d)) in mm_shapes
    assert ((256, d), (d, cfg.d_ff)) in mm_shapes
    assert ((256, cfg.d_ff), (cfg.d_ff, d)) in mm_shapes
    assert ((128, d), (d, cfg.vocab_size)) in mm_shapes
    assert all("@dp2" in s for j in jobs for s in j.scenarios)


def test_plan_training_jobs_no_mesh_is_unsharded():
    from repro.campaign import plan_training_jobs
    from repro.configs import SHAPES, get_config

    cfg = get_config("qwen2_0_5b").reduced()
    jobs = plan_training_jobs(cfg, SHAPES["train_smoke"])
    attn = [j for j in jobs if j.kernel == "flash_attention"][0]
    assert attn.arg_shapes[0][0] == 8                # global batch, dp=1
    assert all("@dp1" in s for s in attn.scenarios)


def test_plan_training_jobs_per_window_attention():
    """SWA archs dispatch flash attention with per-window key_extra; the
    planner must emit one job per distinct window in the layer pattern."""
    from repro.campaign import plan_training_jobs
    from repro.configs import SHAPES, get_config

    cfg = get_config("gemma3_27b").reduced()         # local:global pattern
    jobs = plan_training_jobs(cfg, SHAPES["train_smoke"])
    extras = {j.key_extra for j in jobs if j.kernel == "flash_attention"}
    windows = {
        spec.window for seg in cfg.segments() for spec in seg.pattern
        if spec.mixer == "attn"
    }
    assert extras == {f"cTruew{w}" for w in windows}
    assert len(extras) >= 2


def test_plan_jobs_train_mesh_switches_planner():
    jobs = plan_jobs(["qwen2_0_5b"], train_shapes=("train_smoke",),
                     serving=None, reduced=True, train_mesh="2x4")
    assert jobs and all("@dp2" in s for j in jobs for s in j.scenarios)


def test_summarize_telemetry_rollup():
    from repro.campaign import summarize_telemetry

    snap = {
        "calls": 10, "cache_hits": 4, "cache_hit_rate": 0.4,
        "cache_evictions": 1,
        "tiers": {"exact": 6, "heuristic": 2, "reference": 2},
        "by_key": {
            "matmul|p|8x8/8x8|f32": {"exact": 6},
            "rmsnorm|p|8x8/8|f32": {"heuristic": 2},
            "softmax_xent|*": {"reference": 2},
        },
    }
    s = summarize_telemetry(snap)
    assert s["tier_rates"]["exact"] == 0.6
    assert s["kernels"]["matmul"]["exact_share"] == 1.0
    assert s["kernels"]["rmsnorm"]["exact_share"] == 0.0
    assert s["kernels"]["matmul"]["measured_share"] == 1.0
    assert s["cache_evictions"] == 1


def test_cli_plan_and_status(tmp_path, capsys):
    from repro.campaign.cli import main

    manifest_path = str(tmp_path / "c.json")
    rc = main([
        "plan", "--reduced", "--arches", ",".join(ARCHES),
        "--budget", "60", "--max-tokens", "64", "--max-seq", "32",
        "--serving", "2x32", "--out", manifest_path,
        "--db", str(tmp_path / "db.json"),
    ])
    assert rc == 0
    m = CampaignManifest.load(manifest_path)
    assert len(m.jobs) > 10
    assert any(j.budget > 0 for j in m.jobs)
    rc = main(["status", "--manifest", manifest_path])
    assert rc == 0
    out = capsys.readouterr().out
    assert "pending" in out


def test_serving_planner_trace_faithful_warmup_exact_only(tmp_path):
    """Serving planner parity (trace-faithful roster): with one record per
    planned serving job, (a) warmup resolves every bucket ExactHit-only and
    (b) a kernel-mode engine actually serving a request — admission prefill
    + pool decode — dispatches ONLY keys the planner emitted, so nothing
    falls through to Reference under an ExactHit-or-bust policy. Catches
    any drift between `plan_serving_jobs` and the engine's dispatch sites
    (the o-proj/unembed gemms were missing from the roster once)."""
    import jax
    import numpy as np

    import repro
    from repro.campaign.planner import plan_serving_jobs
    from repro.configs import get_config
    from repro.core.annotate import get_tunable
    from repro.core.runtime import ExactHit, Reference
    from repro.distributed.sharding import Layout
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.models.transformer import RunConfig
    from repro.serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_config("qwen2_0_5b").reduced()
    platform = detect_platform().name
    max_batch, max_seq = 2, 32
    jobs = plan_serving_jobs(cfg, max_batch, max_seq)
    db = TuningDatabase(str(tmp_path / "db.json"))
    planned = set()
    for job in jobs:
        key = job.db_key(platform)
        planned.add(key)
        if not db.lookup(key):
            cfg_default = get_tunable(job.kernel).space.default()
            db.put(Record(key, cfg_default, 1e-6, "wallclock", 1, 0.0),
                   save=False)

    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    rt = repro.runtime(mode="kernel", db=db,
                       policy=(ExactHit(), Reference()), name="serve-parity")
    eng = ServingEngine(
        cfg, RunConfig(remat="none"), params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=max_batch, max_seq=max_seq),
        runtime=rt,
    )

    # (a) warmup: every planned bucket resolves at the exact tier
    resolved = eng.warmup()
    assert resolved and all(c is not None for c in resolved.values())
    snap = rt.telemetry.snapshot()
    assert set(snap["tiers"]) == {"exact"}, snap["tiers"]

    # (b) live serving: prefill one prompt and decode a few tokens — every
    # dispatch must still be an ExactHit on a planned key
    rt.telemetry.reset()
    eng.submit(Request(prompt=np.arange(5, dtype=np.int32) % cfg.vocab_size,
                       max_new_tokens=3))
    eng.serve()
    snap = rt.telemetry.snapshot()
    assert snap["tiers"].get("exact", 0) > 0
    assert set(snap["tiers"]) == {"exact"}, snap["tiers"]
    dispatched = set(snap["by_key"])
    assert dispatched <= planned, dispatched - planned


def test_plan_training_jobs_backward_roster():
    """The training planner derives the backward plane alongside the
    forward sites: transposed-operand matmul jobs for every gemm (dL/dx and
    dL/dw, token dim localized), and the *_bwd tunable jobs with
    output-shaped cotangent operands — all at per-device local shapes."""
    from repro.campaign import plan_training_jobs
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import Layout

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]            # B=8, S=64 → dp=2, T=256 local
    layout = Layout(counts=(("heads", cfg.num_heads),
                            ("kv_heads", cfg.num_kv_heads)))
    jobs = plan_training_jobs(cfg, shape, layout=layout, mesh_axes="2x4")
    by_kernel = {}
    for j in jobs:
        by_kernel.setdefault(j.kernel, []).append(j)
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.num_heads, cfg.num_kv_heads
    mm = {j.arg_shapes for j in by_kernel["matmul"]}
    # q proj fwd + its two transposed gradients
    assert ((256, d), (d, H * hd)) in mm
    assert ((256, H * hd), (H * hd, d)) in mm            # dL/dx (ct @ wT)
    assert ((d, 256), (256, H * hd)) in mm               # dL/dw (xT @ ct)
    # unembed gradients at loss-chunk rows (loss_chunk=32 → 128 local rows)
    assert ((128, cfg.vocab_size), (cfg.vocab_size, d)) in mm
    assert ((d, 128), (128, cfg.vocab_size)) in mm
    # fused bwd tunables: cotangent-led shapes + the forward's saved
    # residuals as trailing keyed operands (residual contract)
    norm_bwd = {j.arg_shapes for j in by_kernel["rmsnorm_bwd"]}
    assert ((256, d), (256, d), (d,), (256,)) in norm_bwd  # + inv-rms rows
    xent_bwd = [j for j in by_kernel["softmax_xent_bwd"]][0]
    assert xent_bwd.arg_shapes == (
        (128,), (128, cfg.vocab_size), (128,), (128,))     # + lse rows
    assert xent_bwd.arg_dtypes == ("float32", "float32", "int32", "float32")
    attn_bwd = [j for j in by_kernel["flash_attention_bwd"]][0]
    assert attn_bwd.arg_shapes[0] == (4, H, 64, hd)      # ct is q-shaped
    assert attn_bwd.arg_shapes[4] == (4, H, 64, hd)      # o residual
    assert attn_bwd.arg_shapes[5] == (4, H, 64)          # lse residual
    assert attn_bwd.arg_dtypes[5] == "float32"
    assert attn_bwd.key_extra == "cTruew0"
    # per-window parity: every flash fwd job has a matching bwd job
    fwd_extras = {j.key_extra for j in by_kernel["flash_attention"]}
    bwd_extras = {j.key_extra for j in by_kernel["flash_attention_bwd"]}
    assert fwd_extras == bwd_extras


def test_plan_training_jobs_ssm_roster():
    """Hybrid-SSM archs get selective-scan rows at local shard shapes: the
    four mamba projection gemm families (dt/out in fp32) plus the ssm_scan
    and ssm_scan_bwd sites whose batch dim is the per-device shard."""
    from repro.campaign import plan_training_jobs
    from repro.campaign.planner import _mamba_dims
    from repro.configs import SHAPES, get_config

    cfg = get_config("jamba_1_5_large").reduced()
    jobs = plan_training_jobs(cfg, SHAPES["train_smoke"], mesh_axes="2x4")
    by_kernel = {}
    for j in jobs:
        by_kernel.setdefault(j.kernel, []).append(j)
    assert "ssm_scan" in by_kernel and "ssm_scan_bwd" in by_kernel
    di, ds, dtr = _mamba_dims(cfg)
    d = cfg.d_model
    scan = by_kernel["ssm_scan"][0]
    b_loc, s = scan.arg_shapes[0][0], scan.arg_shapes[0][1]
    dp = int(scan.scenarios[0].rsplit("@dp", 1)[1])
    assert b_loc * dp <= SHAPES["train_smoke"].global_batch
    assert scan.arg_shapes == (
        (b_loc, s, di), (b_loc, s, di), (b_loc, s, ds), (b_loc, s, ds),
        (di, ds), (b_loc, di, ds),
    )
    assert scan.arg_dtypes[1:] == ("float32",) * 5
    # bwd: two output-shaped cotangents lead, then the forward args
    bwd = by_kernel["ssm_scan_bwd"][0]
    assert bwd.arg_shapes == ((b_loc, s, di), (b_loc, di, ds)) + scan.arg_shapes
    assert bwd.arg_dtypes[:2] == ("float32", "float32")
    # projection gemms: in/x in model dtype, dt/out in fp32 (matching the
    # model's fp32 dt_proj/out_proj dispatches)
    f = str(cfg.jdtype)
    mm = {(j.arg_shapes, j.arg_dtypes) for j in by_kernel["matmul"]}
    T = [j for j in by_kernel["rmsnorm"]][0].arg_shapes[0][0]
    assert (((T, d), (d, 2 * di)), (f, f)) in mm
    assert (((T, di), (di, dtr + 2 * ds)), (f, f)) in mm
    assert (((T, dtr), (dtr, di)), ("float32", "float32")) in mm
    assert (((T, di), (di, d)), ("float32", "float32")) in mm
    # dL/dw transposes exist for the fp32 sites too
    assert (((dtr, T), (T, di)), ("float32", "float32")) in mm


def test_plan_training_jobs_moe_roster():
    """MoE archs get grouped expert-gemm rows keyed on (experts × capacity ×
    hidden), capacity from capacity_factor at the global traced token count,
    with both transposed-operand gradient rows per site."""
    from repro.campaign import plan_training_jobs
    from repro.configs import SHAPES, get_config
    from repro.models.moe import expert_capacity

    cfg = get_config("mixtral_8x7b").reduced()
    shape = SHAPES["train_smoke"]
    jobs = plan_training_jobs(cfg, shape, mesh_axes="2x4")
    eg = [j for j in jobs if j.kernel == "expert_gemm"]
    assert eg, "MoE roster must include expert_gemm jobs"
    e, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    # capacity from the *global* per-microbatch token count (scatter traces
    # the unsharded shape; expert_gemm args are not batch-sharded)
    caps = {j.arg_shapes[0][1] for j in eg if j.arg_shapes[0][2] == d}
    assert len(caps) == 1
    cap = caps.pop()
    shapes = {j.arg_shapes for j in eg}
    # up-gemm fwd + dL/dx + dL/dw
    assert ((e, cap, d), (e, d, ff)) in shapes
    assert ((e, cap, ff), (e, ff, d)) in shapes
    assert ((e, d, cap), (e, cap, ff)) in shapes
    # down-gemm dL/dw
    assert ((e, ff, cap), (e, cap, d)) in shapes
    # consistency with the model's own capacity formula for SOME microbatch
    # split of the global batch
    possible = {
        min(4096, expert_capacity(
            (shape.global_batch // mb) * shape.seq_len, e,
            cfg.experts_per_token, cfg.capacity_factor))
        for mb in (1, 2, 4, 8)
    }
    assert cap in possible


def test_plan_serving_jobs_ssm_and_moe_buckets():
    """Serving rosters cover the SSM decode-state site (ssm_update at the
    slot width, weighted by tokens generated) and per-bucket expert-gemm
    rows; prefill buckets get batch-1 ssm_scan rows."""
    from repro.campaign import plan_serving_jobs
    from repro.campaign.planner import _mamba_dims
    from repro.configs import get_config
    from repro.models.moe import expert_capacity

    cfg = get_config("jamba_1_5_large").reduced()
    jobs = plan_serving_jobs(cfg, max_batch=4, max_seq=64)
    di, ds, _ = _mamba_dims(cfg)
    ups = [j for j in jobs if j.kernel == "ssm_update"]
    assert ups, "decode roster must include the fused state-update site"
    for j in ups:
        assert j.arg_shapes == (
            (4, di), (4, di), (4, ds), (4, ds), (di, ds), (4, di, ds))
        assert all("serve_decode" in s for s in j.scenarios)
        assert j.weight >= 1.0
    scans = [j for j in jobs if j.kernel == "ssm_scan"]
    assert scans and all(j.arg_shapes[0][0] == 1 for j in scans)
    assert all("serve_prefill" in s for j in scans for s in j.scenarios)
    # expert rows exist for both prefill (cap from s) and decode (cap from B)
    eg_pre = [j for j in jobs if j.kernel == "expert_gemm"
              and any("serve_prefill" in s for s in j.scenarios)]
    eg_dec = [j for j in jobs if j.kernel == "expert_gemm"
              and any("serve_decode" in s for s in j.scenarios)]
    assert eg_pre and eg_dec
    e = cfg.num_experts
    cap_dec = expert_capacity(4, e, cfg.experts_per_token, cfg.capacity_factor)
    assert any(j.arg_shapes[0][1] == cap_dec for j in eg_dec)


def test_campaign_run_rejects_pre_bwd_training_manifest(tmp_path, capsys):
    """Implicit resume on a manifest planned before the tuned backward plane
    (training @dp rows, no *_bwd jobs) must fail with a re-plan instruction;
    --allow-missing-bwd overrides; forward-only serving manifests pass."""
    from repro.campaign import cli, plan_training_jobs, plan_serving_jobs
    from repro.campaign.scheduler import (
        build_manifest, manifest_missing_bwd, CampaignManifest,
    )
    from repro.configs import SHAPES, get_config

    cfg = get_config("qwen2_0_5b").reduced()
    fwd_only = tuple(k for k in
                     ("matmul", "rmsnorm", "flash_attention", "softmax_xent")
                     )
    stale_jobs = plan_training_jobs(
        cfg, SHAPES["train_smoke"], mesh_axes="2x4", kernels=fwd_only)
    assert stale_jobs and not any(j.kernel.endswith("_bwd") for j in stale_jobs)
    stale_path = str(tmp_path / "stale.json")
    m = build_manifest(stale_jobs, 8, path=stale_path)
    # simulate the pre-backward-plane era: no meta stamp at all
    m.meta.pop("bwd_roster", None)
    m.save()
    assert manifest_missing_bwd(CampaignManifest.load(stale_path))
    rc = cli.main(["run", "--manifest", stale_path,
                   "--db", str(tmp_path / "db.json")])
    assert rc == 2
    assert "re-plan" in capsys.readouterr().err
    # fresh plan with the full kernel roster is accepted by the guard
    fresh = build_manifest(
        plan_training_jobs(cfg, SHAPES["train_smoke"], mesh_axes="2x4"),
        8, path=str(tmp_path / "fresh.json"))
    assert not manifest_missing_bwd(fresh)
    assert fresh.meta["bwd_roster"] is True
    # serving manifests are forward-only by design: never flagged
    serve = build_manifest(
        plan_serving_jobs(cfg, 2, 32), 8, path=str(tmp_path / "serve.json"))
    assert not manifest_missing_bwd(serve)
    # the escape hatch: --allow-missing-bwd proceeds (0 budget -> no work)
    rc = cli.main(["run", "--manifest", stale_path, "--allow-missing-bwd",
                   "--db", str(tmp_path / "db.json"), "--max-jobs", "0"])
    assert rc == 0
