"""repro.analysis: dispatch-completeness lint + pragma grammar, registry
contract verification, db/manifest checks, and the CLI exit-code gates."""
import json

import pytest

from repro.analysis import Report, run_checks
from repro.analysis.lint import default_models_dir, lint_paths, lint_source
from repro.core.database import make_key

# ---------------------------------------------------------------------------
# Pass 1: lint + pragma grammar
# ---------------------------------------------------------------------------

RAW = """
import jax
import jax.numpy as jnp

def f(x, w):
    return jnp.einsum("ij,jk->ik", x, w)
"""

RAW_ALLOWED_SAME_LINE = """
import jax.numpy as jnp

def f(x, w):
    return jnp.einsum("ij,jk->ik", x, w)  # repro: allow-raw(tiny gate matmul)
"""

RAW_ALLOWED_STATEMENT = """
import jax
import jax.numpy as jnp

# repro: allow-raw(whole function is the tunable reference body)
def f(x, w):
    y = x @ w
    z = jax.nn.softmax(y)
    return jax.lax.scan(lambda c, t: (c + t, c), 0.0, z)
"""

CLEAN = """
import jax.numpy as jnp
from repro.core.runtime import dispatch

def f(x, w):
    return dispatch("matmul", x, w) + jnp.sum(x)
"""


def _lint_str(src):
    report = Report()
    lint_source(src, "synthetic.py", report)
    return report


def test_lint_flags_raw_einsum_and_gate_bites():
    report = _lint_str(RAW)
    assert len(report.errors()) == 1
    assert "einsum" in report.errors()[0].message
    assert report.exit_code() == 1          # the CI gate fails on this


def test_lint_same_line_pragma_downgrades_to_info():
    report = _lint_str(RAW_ALLOWED_SAME_LINE)
    assert report.errors() == []
    infos = report.by_severity("info")
    assert len(infos) == 1 and "tiny gate matmul" in infos[0].message
    assert report.exit_code(strict=True) == 0


def test_lint_statement_pragma_covers_whole_def():
    """One own-line pragma above a def covers every raw site inside it —
    the @, the softmax, and the scan."""
    report = _lint_str(RAW_ALLOWED_STATEMENT)
    assert report.errors() == []
    assert len(report.by_severity("info")) == 3


def test_lint_pragma_does_not_leak_past_the_statement():
    src = RAW_ALLOWED_SAME_LINE + "\n\ndef g(a, b):\n    return a @ b\n"
    report = _lint_str(src)
    assert len(report.errors()) == 1        # g's @ is not covered


def test_lint_clean_file_has_no_findings():
    report = _lint_str(CLEAN)
    assert report.findings == []


def test_lint_directory_walk_and_seeded_violation(tmp_path):
    """End-to-end gate proof: a seeded synthetic violation in a fresh tree
    makes `check --strict` (and plain `check`) exit non-zero."""
    (tmp_path / "bad.py").write_text(RAW)
    (tmp_path / "good.py").write_text(CLEAN)
    report = run_checks(models_dir=str(tmp_path), passes=["lint"])
    assert report.exit_code() == 1
    assert report.stats["lint_files"] == 2
    (tmp_path / "bad.py").write_text(RAW_ALLOWED_SAME_LINE)
    report = run_checks(models_dir=str(tmp_path), passes=["lint"])
    assert report.exit_code(strict=True) == 0


def test_repo_models_lint_clean_strict():
    """Satellite acceptance: the shipped model layer carries a pragma with a
    reason at every intentional raw site — zero errors, zero warnings."""
    report = lint_paths([default_models_dir()])
    assert report.errors() == []
    assert report.exit_code(strict=True) == 0
    # the known-intentional sites are documented, not silenced
    assert report.stats.get("lint_allowed", 0) >= 20


# ---------------------------------------------------------------------------
# Pass 2 + 3 on the repo itself
# ---------------------------------------------------------------------------


def test_repo_full_check_strict_is_clean():
    report = run_checks()
    assert report.errors() == []
    assert report.warnings() == []
    assert report.exit_code(strict=True) == 0
    # legality stats carried for both TPU fingerprints
    assert report.stats["legality"]["ssm_scan@tpu-v5e"]["illegal"] == 28


def test_contracts_flag_missing_reference_oracle():
    from repro.analysis.contracts import check_contracts
    from repro.core.annotate import _REGISTRY, Tunable
    from repro.core.params import ParamSpace, PowerOfTwoParam

    fake = Tunable(
        "zz_fake_no_oracle", lambda x: x,
        space=ParamSpace([PowerOfTwoParam("a", 8, 16)]), reference=None,
    )
    _REGISTRY[fake.name] = fake
    try:
        report = check_contracts()
        locs = [f.location for f in report.errors()]
        assert fake.name in locs
    finally:
        del _REGISTRY[fake.name]


def test_contracts_verify_bwd_dispatch_targets():
    report = Report()
    from repro.analysis.contracts import check_contracts

    check_contracts(report)
    assert report.errors() == []
    # every dispatch-vjp tunable was actually inspected
    assert report.stats["contracts"]["dispatch_vjp"] >= 6


# ---------------------------------------------------------------------------
# db / manifest checks (the `campaign check` body)
# ---------------------------------------------------------------------------


def _write_db(path, records, schema=2):
    path.write_text(json.dumps({"schema": schema, "records": records}))


def test_db_check_flags_stale_int_dtype_key(tmp_path):
    from repro.analysis.db_check import check_db

    stale = make_key("softmax_xent", "tpu-v5e", ((2048, 65536), (2048,)), "int32")
    good = make_key("softmax_xent", "tpu-v5e", ((2048, 65536), (2048,)), "float32")
    db = tmp_path / "db.json"
    _write_db(db, {stale: {"objective": 1.0}, good: {"objective": 1.0}})
    report = check_db(str(db))
    errs = [f for f in report.errors() if f.location == stale]
    assert len(errs) == 1 and "stale integer-dtype key" in errs[0].message
    assert not [f for f in report.errors() if f.location == good]


def test_db_check_flags_unknown_platform_and_schema(tmp_path):
    from repro.analysis.db_check import check_db

    key = make_key("matmul", "rocm-mi300", ((512, 512), (512, 512)), "float32")
    db = tmp_path / "db.json"
    _write_db(db, {key: {"objective": 1.0}}, schema=1)
    report = check_db(str(db))
    msgs = " | ".join(f.message for f in report.warnings())
    assert "schema 1" in msgs
    assert "rocm-mi300" in msgs


def test_db_check_flags_invalid_stored_config(tmp_path):
    from repro.analysis.db_check import check_db

    key = make_key("matmul", "tpu-v5e", ((512, 512), (512, 512)), "float32")
    db = tmp_path / "db.json"
    _write_db(db, {key: {"objective": 1.0, "config": {"bogus_knob": 3}}})
    report = check_db(str(db))
    assert any("no longer valid" in f.message for f in report.warnings())


def test_db_check_flags_pre_residual_bwd_key(tmp_path):
    """A *_bwd record keyed before the residual contract (fewer operands
    than the tunable's current example call) is warm-start-only: flagged."""
    from repro.analysis.db_check import check_db

    q, kv = (2, 4, 128, 16), (2, 2, 128, 16)
    stale = make_key(
        "flash_attention_bwd", "tpu-v5e", (q, q, kv, kv), "float32", "cTruew0"
    )
    good = make_key(
        "flash_attention_bwd", "tpu-v5e",
        (q, q, kv, kv, q, (2, 4, 128)), "float32", "cTruew0",
    )
    db = tmp_path / "db.json"
    _write_db(db, {stale: {"objective": 1.0}, good: {"objective": 1.0}})
    report = check_db(str(db))
    flagged = [f for f in report.warnings() if f.location == stale]
    assert len(flagged) == 1 and "pre-residual" in flagged[0].message
    assert not [f for f in report.warnings() if f.location == good]


def _capacity_manifest(tmp_path, capacity=1024, scenarios=("mixtral/train_4k@dp16",)):
    from repro.campaign.planner import TuningJob
    from repro.campaign.scheduler import CampaignManifest

    job = TuningJob(
        kernel="expert_gemm",
        arg_shapes=((4, capacity, 512), (4, 512, 256)),
        arg_dtypes=("float32", "float32"),
        scenarios=scenarios,
    )
    path = str(tmp_path / "manifest.json")
    CampaignManifest(path=path, platform="tpu-v5e", jobs=[job]).save()
    return path


def test_db_check_flags_expert_capacity_drift_and_missing_bwd(tmp_path):
    from repro.analysis.db_check import check_db

    drifted = make_key(
        "expert_gemm", "tpu-v5e", ((4, 2048, 512), (4, 512, 256)), "float32"
    )
    matching = make_key(
        "expert_gemm", "tpu-v5e", ((4, 1024, 512), (4, 512, 256)), "float32"
    )
    db = tmp_path / "db.json"
    _write_db(db, {drifted: {"objective": 1.0}, matching: {"objective": 1.0}})
    manifest = _capacity_manifest(tmp_path, capacity=1024)
    report = check_db(str(db), manifest_path=manifest)
    # capacity drift: warn on the 2048-capacity record only
    drift = [f for f in report.warnings() if f.location == drifted]
    assert len(drift) == 1 and "capacity bucket 2048" in drift[0].message
    assert not [f for f in report.warnings() if f.location == matching]
    # @dp training manifest without a backward roster is the pre-bwd hazard
    assert any("backward roster" in f.message for f in report.errors())
    # ... and the drift also landed in the obs event buffer via warn_once
    from repro.obs.collect import warn_once

    assert not warn_once("analysis.expert_gemm_capacity", key=drifted)


def test_db_check_clean_without_manifest_is_info_only(tmp_path):
    from repro.analysis.db_check import check_db

    key = make_key("matmul", "tpu-v5e", ((512, 512), (512, 512)), "float32")
    db = tmp_path / "db.json"
    _write_db(db, {key: {"objective": 1.0}})
    report = check_db(str(db))
    assert report.errors() == [] and report.warnings() == []
    assert any("skipped" in f.message for f in report.by_severity("info"))


# ---------------------------------------------------------------------------
# CLIs: python -m repro.analysis check / python -m repro.campaign check
# ---------------------------------------------------------------------------


def test_analysis_cli_strict_clean_on_repo(capsys):
    from repro.analysis.cli import main

    rc = main(["check", "--strict", "--passes", "lint,contracts"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 error(s), 0 warning(s)" in out


def test_analysis_cli_fails_on_seeded_violation(tmp_path, capsys):
    from repro.analysis.cli import main

    (tmp_path / "bad.py").write_text(RAW)
    rc = main(["check", "--models-dir", str(tmp_path), "--passes", "lint"])
    assert rc == 1
    assert "not routed through a registry tunable" in capsys.readouterr().out


def test_analysis_cli_json_output(tmp_path, capsys):
    from repro.analysis.cli import main

    (tmp_path / "bad.py").write_text(RAW)
    rc = main(["check", "--models-dir", str(tmp_path), "--passes", "lint",
               "--json"])
    assert rc == 1
    blob = json.loads(capsys.readouterr().out)
    assert blob["counts"]["error"] == 1
    assert blob["findings"][0]["pass_name"] == "lint"


def test_campaign_check_cli(tmp_path, capsys):
    from repro.campaign.cli import main as campaign_main

    stale = make_key("softmax_xent", "tpu-v5e", ((2048, 65536), (2048,)), "int32")
    db = tmp_path / "db.json"
    _write_db(db, {stale: {"objective": 1.0}})
    manifest = _capacity_manifest(
        tmp_path, capacity=1024, scenarios=("mixtral/train_4k",)
    )
    rc = campaign_main(["check", "--db", str(db), "--manifest", manifest])
    out = capsys.readouterr().out
    assert rc == 1
    assert "stale integer-dtype key" in out

    clean = tmp_path / "clean.json"
    _write_db(clean, {})
    rc = campaign_main(["check", "--db", str(clean), "--manifest", manifest])
    assert rc == 0


def test_campaign_status_prints_pruned_counts(tmp_path, capsys):
    from repro.campaign.cli import main as campaign_main
    from repro.campaign.planner import TuningJob
    from repro.campaign.scheduler import build_manifest
    from repro.core.platform import PROFILES
    from repro.core.runtime import ensure_registered

    ensure_registered()
    job = TuningJob(
        kernel="ssm_scan",
        arg_shapes=((2, 64, 256), (2, 64, 256), (2, 64, 16), (2, 64, 16),
                    (256, 16), (2, 256, 16)),
        arg_dtypes=("float32",) * 6,
        scenarios=("jamba/train_4k",),
    )
    path = str(tmp_path / "m.json")
    build_manifest([job], 24, path=path, platform="tpu-v5e",
                   profile=PROFILES["tpu-v5e"])
    rc = campaign_main(["status", "--manifest", path])
    out = capsys.readouterr().out
    assert rc == 0
    assert '"configs_pruned": 28' in out
    assert "pruned 28 of 49 configs (21 legal) on tpu-v5e" in out


# ---------------------------------------------------------------------------
# Report mechanics
# ---------------------------------------------------------------------------


def test_report_exit_code_strictness():
    r = Report()
    r.add("db", "warn", "k", "drift")
    assert r.exit_code() == 0
    assert r.exit_code(strict=True) == 1
    r.add("lint", "error", "f.py:1", "raw")
    assert r.exit_code() == 1


def test_report_rejects_bad_severity():
    r = Report()
    with pytest.raises(ValueError):
        r.add("lint", "fatal", "x", "y")
