"""End-to-end acceptance: campaign-tuned sharded training hits only tuned
records — across the forward AND backward dispatch planes.

One subprocess (8 fake host devices, 2×4 mesh) runs the whole pipeline the
PR is about:

  1. ``plan_training_jobs`` derives the smoke train step's kernel jobs at
     per-device local shard shapes from the arch config × production Layout
     — including the backward roster (transposed-operand matmul gradients,
     ``*_bwd`` tunables);
  2. ``campaign run`` executes them (tiny budget) into a database;
  3. a Trainer dispatches two steps under ``repro.runtime(db=..,
     mode="kernel")``;
  4. the runtime's exported telemetry must show **ExactHit resolutions for
     every kernel×bucket in the step — no TuneNow/Heuristic/CoverSet
     fallbacks and zero Reference-tier resolutions — under BOTH the ``fwd``
     and ``bwd`` phases** — and cache hits on the repeated step.

If the planner's site roster ever drifts from the model's dispatch sites
(forward or gradient), step 4 fails with the offending keys.

Parametrized over one arch per dispatch plane: dense attention
(qwen2_0_5b), hybrid SSM + MoE (jamba_1_5_large — ssm_scan fwd+bwd rows),
and pure MoE (mixtral_8x7b — grouped expert_gemm fwd + transposed-operand
gradients).
"""
import json
import subprocess
import sys

import pytest

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}

_E2E = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import repro
from repro.configs.base import SHAPES, get_config
from repro.core.database import TuningDatabase
from repro.core.evaluate import WallClockEvaluator
from repro.core.search import RandomSearch
from repro.campaign.planner import plan_training_jobs
from repro.campaign.runner import run_campaign
from repro.campaign.scheduler import build_manifest
from repro.data.pipeline import DataConfig
from repro.launch import defaults
from repro.launch.mesh import make_mesh_from_spec
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

tmp = tempfile.mkdtemp()
cfg = get_config("__ARCH__").reduced()
shape = SHAPES["train_smoke"]
run = defaults.default_run(cfg, shape)
layout = defaults.default_layout(cfg)
mesh = make_mesh_from_spec("2x4")

# 1. plan: local-shape jobs for this arch x Layout x mesh
jobs = plan_training_jobs(cfg, shape, layout=layout, mesh_axes="2x4", run=run)
manifest = build_manifest(jobs, total_budget=3 * len(jobs),
                          path=os.path.join(tmp, "campaign.json"),
                          min_budget=2, max_budget=3)

# 2. run: populate the database (tiny searches keep CI fast; any valid
# record exact-hits regardless of how good it is)
db = TuningDatabase(os.path.join(tmp, "tuning.json"))
summary = run_campaign(
    manifest, db,
    evaluator=WallClockEvaluator(repeats=1, warmup=0),
    search_factory=lambda j: RandomSearch(budget=2),
)

# 3. train two steps under the campaign database, kernel mode
rt = repro.runtime(db=db, mode="kernel", name="train-e2e")
trainer = Trainer(
    cfg, run, mesh, layout,
    DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
    adamw.AdamWConfig(total_steps=2),
    TrainerConfig(total_steps=2, checkpoint_every=100,
                  checkpoint_dir=os.path.join(tmp, "ckpt"),
                  async_checkpoint=False),
    runtime=rt,
)
losses = [float(trainer.run_one_step()["loss"]) for _ in range(2)]

# 4. export the telemetry the assertions run on
print("RESULT_JSON=" + json.dumps({
    "campaign": summary,
    "planned_keys": sorted(j.db_key(manifest.platform) for j in manifest.jobs),
    "losses": losses,
    "telemetry": rt.telemetry.snapshot(),
}))
"""


# per-arch kernel families the step must resolve (fwd plane assertion);
# matmul gradients reuse the matmul tunable so they never appear separately
_ARCH_KERNELS = {
    "qwen2_0_5b": {"flash_attention", "flash_attention_bwd"},
    "jamba_1_5_large": {"flash_attention", "flash_attention_bwd",
                        "ssm_scan", "ssm_scan_bwd", "expert_gemm"},
    "mixtral_8x7b": {"flash_attention", "flash_attention_bwd", "expert_gemm"},
}


@pytest.mark.parametrize("arch", sorted(_ARCH_KERNELS))
def test_campaign_tuned_training_is_all_exact_hits(arch):
    r = subprocess.run(
        [sys.executable, "-c", _E2E.replace("__ARCH__", arch)],
        capture_output=True, text=True, timeout=560, env=dict(_ENV), cwd=".",
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("RESULT_JSON=")), None
    )
    assert line, f"stdout={r.stdout[-1500:]} stderr={r.stderr[-2500:]}"
    out = json.loads(line.split("=", 1)[1])

    # the campaign ran every planned job
    assert out["campaign"]["failed"] == 0, out["campaign"]
    assert out["campaign"]["done"] == out["campaign"]["jobs"]

    snap = out["telemetry"]
    # every kernel×bucket the step dispatched resolved at the exact tier —
    # no TuneNow, no CoverSet, no Heuristic, no Reference fallback
    offending = {
        key: tiers for key, tiers in snap["by_key"].items()
        if set(tiers) - {"exact"}
    }
    assert not offending, f"non-exact resolutions: {offending}"
    assert snap["tiers"].get("exact", 0) > 0
    assert set(snap["tiers"]) == {"exact"}

    # the tightened gate: BOTH dispatch phases present, each 100% ExactHit —
    # the backward plane runs on tuned records, not reference recomputes
    phases = snap["phases"]
    assert set(phases) == {"fwd", "bwd"}, phases
    for phase in ("fwd", "bwd"):
        assert set(phases[phase]) == {"exact"}, (phase, phases[phase])
        assert phases[phase]["exact"] > 0, (phase, phases[phase])
    bwd_offending = {
        key: tiers
        for key, tiers in snap["by_key_phase"]["bwd"].items()
        if set(tiers) - {"exact"}
    }
    assert not bwd_offending, f"non-exact gradient resolutions: {bwd_offending}"

    # the dispatched buckets are a subset of what the campaign planned
    planned = set(out["planned_keys"])
    assert set(snap["by_key"]) <= planned

    # kernel coverage: every tunable family the step can exercise, forward
    # and backward (matmul gradients reuse the matmul tunable)
    kernels = {k.split("|")[0] for k in snap["by_key"]}
    assert {"matmul", "rmsnorm", "softmax_xent",
            "rmsnorm_bwd", "softmax_xent_bwd"} | _ARCH_KERNELS[arch] <= kernels
    bwd_kernels = {k.split("|")[0] for k in snap["by_key_phase"]["bwd"]}
    assert "matmul" in bwd_kernels          # transposed-operand gradient gemms
    if "ssm_scan" in _ARCH_KERNELS[arch]:
        assert "ssm_scan_bwd" in bwd_kernels
    if "expert_gemm" in _ARCH_KERNELS[arch]:
        assert "expert_gemm" in bwd_kernels  # transposed grouped-gemm grads

    # second step re-used the warm resolution cache
    assert snap["cache_hits"] > 0

    import numpy as np

    assert np.isfinite(out["losses"]).all()
