"""Checkpointer: atomicity, integrity, async, GC, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer, _COMMIT_MARK


def tree(seed=0):
    rs = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rs.randn(4, 8), jnp.float32),
        "nested": {"b": jnp.asarray(rs.randn(3), jnp.bfloat16),
                   "step": jnp.asarray(7, jnp.int32)},
    }


def assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), a, b
    )


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = tree()
    ck.save(3, t)
    assert ck.all_steps() == [3]
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
    )
    out = ck.restore(3, target)
    assert_tree_equal(t, out)


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save_async(1, t)
    ck.wait()
    assert ck.latest_step() == 1


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree())
    # simulate crash mid-save: directory without the commit mark
    broken = tmp_path / "step_000000009"
    shutil.copytree(tmp_path / "step_000000005", broken)
    os.unlink(broken / _COMMIT_MARK)
    assert ck.all_steps() == [5]
    assert ck.latest_step() == 5


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    path = ck.save(2, t)
    # flip bytes in a leaf file
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr_view = arr.view(np.uint8).copy()
    arr_view[-1] ^= 0xFF
    np.save(leaf, arr_view.view(arr.dtype).reshape(arr.shape))
    target = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(ValueError, match="crc|corrupt"):
        ck.restore(2, target)


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    assert ck.all_steps() == [3, 4]


def test_tree_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    bad_target = {"a": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore(1, bad_target)


def test_elastic_restore_reshards(tmp_path):
    """Restore applies whatever shardings the *current* mesh wants."""
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(1, t)
    mesh = make_host_mesh()
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), t)
    target = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = ck.restore(1, target, shardings=sh)
    assert_tree_equal(t, out)
    for leaf in jax.tree_util.tree_leaves(out):
        assert isinstance(leaf.sharding, NamedSharding)
