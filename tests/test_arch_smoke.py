"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
family-preserving config, one forward + one train step on CPU; asserts
output shapes and no NaNs. Also decode-vs-full consistency per family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import RunConfig, lm
from repro.models.layers import unembed
from repro.optim import adamw

RUN = RunConfig(
    remat="none", loss_chunk=8, q_chunk=8, k_chunk=8, mamba_chunk=4,
    mlstm_chunk=4, microbatches=1,
)
B, S = 2, 16


def make_batch(cfg, rs, seq=S):
    if cfg.frontend == "audio_frames":
        return {
            "embeds": jnp.asarray(rs.randn(B, seq, cfg.d_model), jnp.float32),
            "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, seq)), jnp.int32),
        }
    if cfg.frontend == "vision_patches":
        P = cfg.num_prefix
        mask = np.zeros((B, seq), np.float32)
        mask[:, P:] = 1
        return {
            "embeds": jnp.asarray(rs.randn(B, P, cfg.d_model), jnp.float32),
            "tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, seq - P)), jnp.int32),
            "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, seq)), jnp.int32),
            "loss_mask": jnp.asarray(mask),
        }
    return {
        "tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, seq)), jnp.int32),
        "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, seq)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch, rs):
    cfg = get_config(arch).reduced()
    assert sum(s.num_layers for s in cfg.segments()) == cfg.num_layers
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, rs)

    # forward: shapes + finiteness
    x, aux, _ = lm.forward(params, batch, cfg, RUN, mode="train")
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x)))

    # one train step: loss finite, params actually change
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    opt = adamw.init(opt_cfg, params)

    def loss_fn(p):
        return lm.loss_fn(p, batch, cfg, RUN)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    new_params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
    diff = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(
            lambda a, b: (a - b).astype(jnp.float32), new_params, params
        ),
        0.0,
    )
    assert diff > 0, f"{arch}: optimizer step was a no-op"


@pytest.mark.parametrize(
    "arch", ["qwen2_5_3b", "gemma3_27b", "mixtral_8x7b", "xlstm_1_3b", "jamba_1_5_large"]
)
def test_decode_matches_full_forward(arch, rs):
    """prefill+decode must reproduce the full-forward next-token logits.

    MoE archs use a non-binding capacity factor: capacity token-dropping is
    *expected* to make train-time prefill differ from decode (production MoE
    semantics); with capacity non-binding the paths must agree.
    """
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    x, _, _ = lm.forward(params, {"tokens": toks}, cfg, RUN, mode="train")
    full_logits = unembed(params["lm_head"], x[:, -1])

    _, caches = lm.prefill(params, {"tokens": toks[:, :S]}, cfg, RUN, cache_len=S + 2)
    dec_logits, _ = lm.decode_step(
        params, toks[:, S:], caches, jnp.asarray(S, jnp.int32), cfg, RUN
    )
    np.testing.assert_allclose(full_logits, dec_logits, rtol=2e-4, atol=2e-4)


def test_gemma3_local_layers_have_windowed_cache():
    cfg = get_config("gemma3_27b")
    caches = lm.cache_specs(cfg, batch=4, cache_len=32768)
    seg0 = caches[0]  # 6-layer super-block ×10
    # first 5 layers local (window 1024), 6th global (full 32768)
    for i in range(5):
        assert seg0[f"l{i}"]["k"].shape[2] == 1024, i
    assert seg0["l5"]["k"].shape[2] == 32768


def test_jamba_pattern():
    cfg = get_config("jamba_1_5_large")
    segs = cfg.segments()
    assert len(segs) == 1 and segs[0].repeats == 9
    pat = segs[0].pattern
    assert [s.mixer for s in pat] == ["attn"] + ["mamba"] * 7
    assert [("moe" in s.ffn) for s in pat] == [False, True] * 4


def test_arctic_parallel_dense_moe():
    cfg = get_config("arctic_480b")
    spec = cfg.segments()[0].pattern[0]
    assert spec.ffn == "moe+dense"


def test_param_counts_plausible():
    # param_count must be overflow-free and in the right ballpark
    expect = {
        "qwen2_0_5b": (0.4e9, 0.8e9),
        "minitron_4b": (4e9, 6.5e9),
        "mixtral_8x7b": (45e9, 50e9),
        "arctic_480b": (420e9, 520e9),
        "jamba_1_5_large": (330e9, 430e9),
        "gemma3_27b": (26e9, 32e9),
    }
    for arch, (lo, hi) in expect.items():
        n = lm.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"
