"""Prefill→decode state-continuity for every recurrent mixer.

``*_forward(x[:, :s], return_state=True)`` followed by ``*_decode`` over the
remainder must reproduce the full-length forward for *arbitrary* prefix
length vs chunk size. Regression coverage for the Mamba prefill-state bug:
the zero-padded chunk tail used to keep stepping the recurrence
(``dt = softplus(dt_bias) > 0`` on zero input, so ``dA < 1`` decays ``h``
for the pad steps), corrupting the handed-off state whenever
``s % chunk != 0``. (Hypothesis-free on purpose — these must run in tier-1
everywhere; the hypothesis property sweeps live in test_ssm.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

B, S, D, H = 2, 24, 32, 4


def _x(seed=1, s=S):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, s, D)) * 0.5


@pytest.mark.parametrize("s_prefix,chunk", [(13, 8), (17, 8), (24, 8), (5, 16)])
def test_mamba_prefill_state_continuity(s_prefix, chunk):
    p, _ = ssm.mamba_init(jax.random.PRNGKey(0), D, jnp.float32)
    x = _x()
    import functools

    from repro.kernels.ssm_scan import ssm_scan_chunked

    scan_fn = functools.partial(ssm_scan_chunked, chunk=chunk)
    y_full = ssm.mamba_forward(p, x, scan_fn=scan_fn)
    y_pre, state = ssm.mamba_forward(
        p, x[:, :s_prefix], scan_fn=scan_fn, return_state=True
    )
    ys = [y_pre]
    for t in range(s_prefix, S):
        yt, state = ssm.mamba_decode(p, x[:, t : t + 1], state)
        ys.append(yt)
    np.testing.assert_allclose(
        jnp.concatenate(ys, 1), y_full, rtol=2e-4, atol=2e-5
    )


@pytest.mark.parametrize("s_prefix", [13, 24])
def test_mlstm_prefill_state_continuity(s_prefix):
    p, _ = ssm.mlstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    y_full = ssm.mlstm_forward(p, x, n_heads=H, chunk=8)
    y_pre, state = ssm.mlstm_forward(
        p, x[:, :s_prefix], n_heads=H, chunk=8, return_state=True
    )
    ys = [y_pre]
    for t in range(s_prefix, S):
        yt, state = ssm.mlstm_decode(p, x[:, t : t + 1], state, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(
        jnp.concatenate(ys, 1), y_full, rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("s_prefix", [13, 24])
def test_slstm_prefill_state_continuity(s_prefix):
    p, _ = ssm.slstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = _x()
    y_full = ssm.slstm_forward(p, x, n_heads=H)
    y_pre, state = ssm.slstm_forward(
        p, x[:, :s_prefix], n_heads=H, return_state=True
    )
    ys = [y_pre]
    for t in range(s_prefix, S):
        yt, state = ssm.slstm_decode(p, x[:, t : t + 1], state, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(
        jnp.concatenate(ys, 1), y_full, rtol=2e-4, atol=2e-5
    )
