"""Trainer integration: loss decreases, checkpoint/restart mid-run recovers
exactly, failure injection triggers restore-and-replay, compression trains.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import RunConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

CFG = get_config("qwen2_0_5b").reduced()
RUN = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16, microbatches=1)
DATA = DataConfig(seed=0, batch_size=8, seq_len=32)
OPT = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, grad_clip=1.0)


def make_trainer(tmp_path, steps=12, compression="none", microbatches=1):
    run = dataclasses.replace(RUN, microbatches=microbatches)
    return Trainer(
        CFG, run, make_host_mesh(), Layout(), DATA, OPT,
        TrainerConfig(
            total_steps=steps,
            checkpoint_every=5,
            checkpoint_dir=str(tmp_path / "ckpt"),
            async_checkpoint=False,
            grad_compression=compression,
        ),
    )


def test_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, steps=12)
    losses = [tr.run_one_step()["loss"] for _ in range(12)]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4]), losses


def test_microbatched_equals_direct_loss(tmp_path):
    tr1 = make_trainer(tmp_path / "a", microbatches=1)
    tr2 = make_trainer(tmp_path / "b", microbatches=4)
    l1 = tr1.run_one_step()["loss"]
    l2 = tr2.run_one_step()["loss"]
    assert abs(l1 - l2) < 5e-3, (l1, l2)


def test_checkpoint_restart_exact(tmp_path):
    tr = make_trainer(tmp_path, steps=10)
    for _ in range(5):  # checkpoint fires at step 5
        tr.run_one_step()
    after5 = tr.run_one_step()["loss"]       # step 6 from live state

    tr2 = make_trainer(tmp_path, steps=10)
    restored = tr2.restore_checkpoint()
    assert restored == 5
    assert tr2.data.step == tr.data.step - 1
    replay5 = tr2.run_one_step()["loss"]     # step 6 from restored state
    assert abs(after5 - replay5) < 1e-5, (after5, replay5)


def test_failure_injection_recovers(tmp_path):
    tr = make_trainer(tmp_path, steps=12)
    fired = {"done": False}

    def fail_hook(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected failure")

    tr.train(fail_hook=fail_hook)
    assert tr.step == 12
    assert fired["done"]
    assert tr.ckpt.latest_step() in (10, 12)


def test_compression_still_learns(tmp_path):
    tr = make_trainer(tmp_path, steps=10, compression="int8_ef")
    losses = [tr.run_one_step()["loss"] for _ in range(10)]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
