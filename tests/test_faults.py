"""Unit tests for the deterministic fault-injection harness itself.

The chaos suites lean on this harness for their guarantees, so its own
contract — determinism under a seed, site/ctx matching, after/times/p
gating, scoping and global install — is pinned here first.
"""
import threading

import pytest

from repro.testing import (
    FaultPlan,
    FaultRule,
    InjectedFault,
    InjectedWorkerCrash,
    active_plan,
    fault_point,
)


def test_no_plan_is_inert():
    assert active_plan() is None
    assert fault_point("dispatch.kernel:matmul", tier="exact") is None


def test_error_kind_raises_and_records():
    plan = FaultPlan([FaultRule(site="a.b:*", kind="error", message="boom")])
    with plan:
        with pytest.raises(InjectedFault, match="boom"):
            fault_point("a.b:matmul")
        # non-matching site passes through
        assert fault_point("other.site") is None
    assert plan.fired == [("a.b:matmul", "error", 0)]
    assert plan.count("a.b:*") == 1
    assert plan.count("a.b:*", kind="nan") == 0
    # plan exited: inert again
    assert fault_point("a.b:matmul") is None


def test_crash_kind_is_base_exception():
    with FaultPlan([FaultRule(site="w:*", kind="crash")]):
        with pytest.raises(InjectedWorkerCrash):
            fault_point("w:job")
        # the whole point: except Exception must NOT absorb it
        with pytest.raises(InjectedWorkerCrash):
            try:
                fault_point("w:job")
            except Exception:  # noqa: BLE001
                pytest.fail("crash kind must escape `except Exception`")


def test_torn_kind_raises_plain_valueerror():
    # mimics what json.load raises on a half-written file, so real
    # corruption handlers catch it without knowing about the harness
    with FaultPlan([FaultRule(site="db.load:*", kind="torn")]):
        with pytest.raises(ValueError):
            fault_point("db.load:/tmp/x.json")


def test_nan_kind_returned_to_site():
    with FaultPlan([FaultRule(site="k:*", kind="nan")]) as plan:
        rule = fault_point("k:x")
    assert rule is not None and rule.kind == "nan"
    assert plan.count(kind="nan") == 1


def test_latency_kind_sleeps():
    import time

    with FaultPlan([FaultRule(site="slow:*", kind="latency", delay_s=0.05)]):
        t0 = time.monotonic()
        rule = fault_point("slow:step")
        assert time.monotonic() - t0 >= 0.05
        assert rule.kind == "latency"


def test_after_and_times_gating():
    # skip the first 2 eligible calls, then fire exactly twice
    plan = FaultPlan([FaultRule(site="s", kind="error", after=2, times=2)])
    with plan:
        outcomes = []
        for _ in range(6):
            try:
                fault_point("s")
                outcomes.append("ok")
            except InjectedFault:
                outcomes.append("fault")
    assert outcomes == ["ok", "ok", "fault", "fault", "ok", "ok"]


def test_probability_is_seeded_deterministic():
    def run(seed):
        fired = []
        with FaultPlan([FaultRule(site="p", kind="error", p=0.5)], seed=seed) as plan:
            for _ in range(32):
                try:
                    fault_point("p")
                    fired.append(0)
                except InjectedFault:
                    fired.append(1)
            assert len(plan.fired) == sum(fired)
        return fired

    a, b = run(7), run(7)
    assert a == b, "same seed must reproduce the same firing sequence"
    assert 0 < sum(a) < 32, "p=0.5 should fire sometimes, not always"
    assert run(8) != a, "different seed should differ (vanishingly unlikely tie)"


def test_when_ctx_narrowing():
    rule = FaultRule(site="dispatch.kernel:*", when={"tier": "exact"})
    with FaultPlan([rule]):
        assert fault_point("dispatch.kernel:matmul", tier="heuristic") is None
        with pytest.raises(InjectedFault):
            fault_point("dispatch.kernel:matmul", tier="exact")


def test_nested_plans_innermost_wins():
    outer = FaultPlan([FaultRule(site="x", kind="error")], name="outer")
    inner = FaultPlan([], name="inner")
    with outer:
        with inner:
            # inner plan has no rules; it shadows the outer one
            assert active_plan() is inner
            assert fault_point("x") is None
        with pytest.raises(InjectedFault):
            fault_point("x")


def test_install_reaches_fresh_threads():
    plan = FaultPlan([FaultRule(site="worker:*", kind="error")])
    plan.install()
    try:
        box = {}

        def work():
            try:
                fault_point("worker:job")
                box["out"] = "ok"
            except InjectedFault:
                box["out"] = "fault"

        t = threading.Thread(target=work)
        t.start()
        t.join()
        assert box["out"] == "fault", "worker threads must see installed plans"
    finally:
        plan.uninstall()
    assert active_plan() is None


def test_bad_kind_rejected():
    with pytest.raises(ValueError, match="fault kind"):
        FaultRule(site="x", kind="segfault")
