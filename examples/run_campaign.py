"""End-to-end tuning campaign: plan → run → export → serve with the artifact.

    PYTHONPATH=src python examples/run_campaign.py

The paper's deliverable is *generic code + a per-platform tuning database*.
This example produces and consumes that artifact on CPU in a few minutes:

  1. PLAN    — derive tuning jobs from three real arch configs (reduced
               dims) plus the serving engine's (batch, seq-bucket) jit
               keys; dedup by database key, rank by analytic priority,
               split a global evaluation budget, persist the manifest;
  2. RUN     — execute jobs best-first; each search warm-starts from the
               nearest record already banked (watch the 'seeded' count);
               kill the process mid-run and rerun — it resumes;
  3. EXPORT  — cluster winners into 'few fit most' cover sets and write
               the shippable single-platform database;
  4. SERVE   — a fresh engine + the artifact: `warmup` resolves every
               serving bucket with zero serve-time tuning, then decodes.

Identical flow on a TPU host, minus `reduced=True` and with real budgets:
the exported file is what you ship next to the model weights.
"""
import os
import tempfile

import jax
import numpy as np

from repro.campaign import export_campaign_db, plan_jobs, run_campaign
from repro.campaign.scheduler import analytic_scenario_seconds, build_manifest
from repro.core import TuningDatabase, WallClockEvaluator, detect_platform
from repro.configs import get_config
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.serving.engine import EngineConfig, Request, ServingEngine

ARCHES = ["qwen2_0_5b", "minitron_4b", "qwen2_5_3b"]


def main():
    workdir = tempfile.mkdtemp(prefix="repro_campaign_")
    manifest_path = os.path.join(workdir, "campaign.json")
    db_path = os.path.join(workdir, "tuning.json")
    artifact_path = os.path.join(workdir, "cpu-host.db.json")

    # 1. PLAN — small caps keep the CPU campaign snappy; shape bucketing
    # makes the records valid for anything landing in the same buckets.
    jobs = plan_jobs(
        ARCHES,
        train_shapes=("train_4k",),
        serving=(2, 32),
        kernels=("matmul", "rmsnorm"),
        reduced=True,
        max_tokens=128,
        max_seq=64,
    )
    manifest = build_manifest(
        jobs,
        total_budget=120,
        path=manifest_path,
        scenario_seconds=analytic_scenario_seconds(ARCHES, reduced=True),
    )
    funded = [j for j in manifest.jobs if j.budget > 0]
    print(f"planned {len(jobs)} jobs -> {len(manifest.jobs)} unique keys, "
          f"{len(funded)} funded ({manifest.total_budget} evals budget)")

    # 2. RUN — interrupt-safe; rerunning this script section would resume.
    db = TuningDatabase(db_path)
    summary = run_campaign(
        manifest, db, evaluator=WallClockEvaluator(repeats=1, warmup=0)
    )
    print(f"ran {summary['done']} jobs, {summary['evaluations_spent']} evals, "
          f"mean speedup {summary['mean_speedup']:.2f}x, "
          f"{summary['seeded_jobs']} warm-started by transfer")

    # 3. EXPORT — the shippable per-platform artifact (records + covers).
    platform = detect_platform().name
    artifact = export_campaign_db(db, artifact_path, platform)
    print(f"exported {len(artifact)} records, covers for "
          f"{sorted(k.split('|')[0] for k in artifact.covers())} -> {artifact_path}")

    # 4. SERVE — fresh deployment: generic engine + the artifact.
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(
        cfg, RunConfig(remat="none"), params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=2, max_seq=32),
    )
    serve_db = TuningDatabase(artifact_path)
    # zero tuning: lookups + covers only; warmup also installs the artifact
    # as the process default db so ops dispatch under the engine consumes it
    resolved = engine.warmup(serve_db)
    print(f"warmed {len(resolved)} bucket kernel-configs from the artifact")

    rs = np.random.RandomState(0)
    engine.submit(Request(prompt=rs.randint(0, cfg.vocab_size, 8).astype(np.int32),
                          max_new_tokens=4))
    (done,) = engine.serve()
    print(f"served 1 request: {done.output.tolist()} "
          f"(latency {done.latency_s * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
