"""Continuous-batching serving example: slot pool + in-flight admission.

The engine owns `max_batch` slots, each one batch row of a shared KV/SSM
cache. Requests are admitted one at a time — prompt right-padded to a
power-of-two bucket, prefilled at batch 1, cache inserted into a free slot
— and the whole pool decodes in ONE jitted step per tick with per-slot
positions. A request that hits its own `max_new_tokens` frees its slot
immediately; queued traffic (staggered here via `arrival_time` ticks) is
admitted mid-flight while other slots keep decoding.

jit-key invariant: prefill keys are (1, seq-bucket), decode is a single
(max_batch,) pool key — exactly the buckets a tuning campaign warms via
``ServingEngine.warmup`` (see examples/run_campaign.py), so per-platform
databases stay valid while batch composition changes continuously.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b   # SWA cache
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

import repro
from repro.configs import get_config
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.transformer import RunConfig
from repro.serving.engine import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    print(f"serving {cfg.name} (reduced): {cfg.num_layers}L d={cfg.d_model}")

    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    run = RunConfig(remat="none", loss_chunk=32, q_chunk=32, k_chunk=32)
    # The engine gets its own scoped dispatch runtime: its kernel db/mode
    # and telemetry are isolated from anything else in the process.
    rt = repro.runtime(mode="auto", name="serve-example")
    engine = ServingEngine(
        cfg, run, params, make_host_mesh(), Layout(),
        EngineConfig(max_batch=4, max_seq=96),
        runtime=rt,
    )

    rs = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rs.randint(0, cfg.vocab_size, 16).astype(np.int32)
        engine.submit(Request(
            prompt=prompt,
            # skewed lengths + staggered arrivals: slots retire and re-admit
            max_new_tokens=args.new_tokens if i % 3 else 3 * args.new_tokens,
            temperature=0.8 if i % 2 else 0.0, seed=i,
            arrival_time=2.0 * i,
        ))

    t0 = time.time()
    done = engine.serve()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    for i, r in enumerate(done):
        mode = "sampled" if i % 2 else "greedy"
        print(f"req{i} ({mode}, slot {r.slot}, "
              f"admit@{r.admitted_step} lat {r.latency_steps} ticks): "
              f"{r.output.tolist()}")
    st = engine.stats
    print(f"\n{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print(f"pool: {st['decode_steps']} decode steps, {st['prefill_calls']} "
          f"admission prefills, {st['tokens_out']/max(1, st['decode_steps']):.2f} tok/step, "
          f"{st['slot_steps_idle']} idle slot-steps")
    # Which resolution tier served each kernel×bucket during tracing
    # (all-reference here unless REPRO_USE_PALLAS=1 / a tuned db is pinned):
    print(rt.telemetry.report())


if __name__ == "__main__":
    main()
