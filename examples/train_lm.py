"""End-to-end training driver: data → sharded model → AdamW → checkpoints →
failure recovery, on any of the 10 assigned architectures (reduced configs
by default so it runs on a laptop CPU; pass --full-scale on a pod).

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 30
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --steps 10
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --inject-failure

--inject-failure kills step 7 once and shows restore-and-replay.
"""
import argparse
import logging

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.distributed.sharding import Layout
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import RunConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--full-scale", action="store_true",
                    help="use the full (paper-size) config — pod hardware only")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_scale:
        cfg = cfg.reduced()
    print(f"arch {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"({'full' if args.full_scale else 'reduced smoke config'})")

    run = RunConfig(remat="none", loss_chunk=32, q_chunk=32, k_chunk=32,
                    microbatches=1)
    trainer = Trainer(
        cfg,
        run,
        make_host_mesh(),
        Layout(),
        DataConfig(seed=0, batch_size=args.batch, seq_len=args.seq),
        adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps),
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=10,
            checkpoint_dir=args.ckpt_dir,
            grad_compression=args.compression,
            log_every=5,
            async_checkpoint=True,
        ),
    )

    fail_hook = None
    if args.inject_failure:
        fired = {"done": False}

        def fail_hook(step):
            if step == 7 and not fired["done"]:
                fired["done"] = True
                print(">>> injecting simulated node failure at step 7 <<<")
                raise RuntimeError("simulated node failure")

    first = trainer.run_one_step()
    print(f"step 1: loss {first['loss']:.4f}")
    metrics = trainer.train(fail_hook=fail_hook)
    print(f"final step {trainer.step}: loss {metrics['loss']:.4f} "
          f"(started at {first['loss']:.4f})")
    if trainer.monitor.flagged:
        print("straggler steps flagged:", trainer.monitor.flagged)
    print("checkpoints kept:", trainer.ckpt.all_steps())


if __name__ == "__main__":
    main()
