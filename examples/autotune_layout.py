import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# ^ must precede any jax import: this example tunes the DISTRIBUTION layout,
# so it needs a small fake mesh (2 data x 4 model) on the CPU host.

"""Layout autotuning — the paper's technique applied to sharding.

The knob space here is not a tile shape but the distribution layout
(head-aware TP, FSDP, microbatch count, grad wire format). Variants are
scored by the CostModelEvaluator: each candidate is lowered + compiled for
the mesh and its dominant roofline term (from compiled HLO, trip-aware
collective parse) is the objective — exactly the loop behind the §Perf
hillclimbs, shrunk to run in ~2 minutes on CPU.

    PYTHONPATH=src python examples/autotune_layout.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.core import (
    BoolParam,
    CostModelEvaluator,
    EnumParam,
    IntParam,
    ParamSpace,
    tunable,
)
from repro.core.search import ExhaustiveSearch
from repro.core.search.base import Trial
from repro.distributed.sharding import Layout
from repro.launch import steps
from repro.launch.defaults import default_run
from repro.configs.base import SHAPES, ShapeSpec
from repro.models.transformer import RunConfig


LAYOUT_SPACE = ParamSpace(
    [
        BoolParam("head_aware"),
        BoolParam("fsdp"),
        IntParam("microbatches", [1, 2]),
        EnumParam("grad_compression", ["none", "bf16"]),
    ]
)


def main():
    cfg = get_config("qwen2-0.5b").reduced()
    # a small but real shape so compiles stay ~seconds
    shape = ShapeSpec("mini_train", seq_len=128, global_batch=8, kind="train")
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    evaluator = CostModelEvaluator(chips=8)

    def lower_variant(**knobs):
        layout = Layout(
            fsdp=knobs["fsdp"],
            head_aware=knobs["head_aware"],
            counts=(("heads", cfg.num_heads), ("kv_heads", cfg.num_kv_heads)),
        )
        run = RunConfig(
            remat="none", q_chunk=64, k_chunk=64, loss_chunk=64,
            microbatches=knobs["microbatches"],
            grad_compression=knobs["grad_compression"],
        )
        cell = steps.build_cell(cfg, shape, mesh, layout, run)
        lowered = steps.lower_cell(cell, mesh)
        return lowered.compile()

    def objective(config):
        m = evaluator.evaluate(lambda: lower_variant(**config))
        r = m.meta.get("roofline", {})
        print(
            f"  {config} -> "
            + (
                f"step bound {m.objective*1e3:.2f}ms (dominant: {r.get('dominant')})"
                if m.ok
                else f"INVALID: {m.error}"
            )
        )
        return Trial(config=config, objective=m.objective, ok=m.ok,
                     meta=m.meta)

    print(f"searching {LAYOUT_SPACE.cardinality} layout variants "
          f"(compile-and-analyse each):")
    res = ExhaustiveSearch(budget=16).run(LAYOUT_SPACE, objective)
    print(f"\nbest layout: {res.best_config}")
    print(f"step-time bound: {res.best_objective*1e3:.2f}ms")
    best_roofline = res.best.meta["roofline"]
    print(f"terms: compute {best_roofline['compute_s']*1e3:.2f}ms | "
          f"memory {best_roofline['memory_s']*1e3:.2f}ms | "
          f"collective {best_roofline['collective_s']*1e3:.2f}ms")


if __name__ == "__main__":
    main()
