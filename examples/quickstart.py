"""Quickstart: autotune one site, watch the database make it free next time.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's full loop in ~a minute on CPU:
  1. a reference implementation runs untouched (correctness oracle);
  2. the @tunable annotation declares the knob space;
  3. empirical search finds the best variant for THIS machine and shape;
  4. the result persists keyed by (platform, shape) — the second call hits
     the database and specializes instantly (performance portability).
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CoordinateDescent,
    TuningDatabase,
    WallClockEvaluator,
    autotune,
    tune_or_lookup,
)
from repro.models.tunables import attention_chunked


def main():
    rs = np.random.RandomState(0)
    s = 512
    q = jnp.asarray(rs.randn(1, 4, s, 32) * 0.3, jnp.float32)
    k = jnp.asarray(rs.randn(1, 2, s, 32) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(1, 2, s, 32), jnp.float32)

    db = TuningDatabase("/tmp/quickstart_tuning.json")

    print("== 1. untuned call (heuristic default config) ==")
    cfg = attention_chunked.default_config(q, k, v)
    print("   default config:", cfg)

    print("== 2. autotune (compile+run+gate per variant) ==")
    t0 = time.time()
    res = autotune(
        attention_chunked,
        (q, k, v),
        search=CoordinateDescent(budget=14, restarts=1),
        evaluator=WallClockEvaluator(repeats=3, warmup=1),
        db=db,
    )
    print(f"   searched {res.evaluations} variants in {time.time()-t0:.1f}s")
    print(f"   baseline {res.default_objective*1e3:.2f}ms -> "
          f"tuned {res.best_objective*1e3:.2f}ms  ({res.speedup:.2f}x)")
    print(f"   winning config: {res.best_config}")

    print("== 3. deployment lookup (zero-cost specialization) ==")
    t0 = time.time()
    cfg = tune_or_lookup(attention_chunked, (q, k, v), db=db)
    print(f"   lookup took {1e3*(time.time()-t0):.2f}ms -> {cfg}")
    assert cfg == res.best_config

    print("== 4. the database is platform-keyed ==")
    print("   records by platform:", db.platforms())


if __name__ == "__main__":
    main()
