"""Campaign report: what a finished (or interrupted) campaign bought.

Reads a campaign manifest + tuning database and reports, per kernel:
  * jobs done/pending/failed and evaluations spent vs allocated;
  * banked speedups (default heuristic vs tuned winner, from the records);
  * transfer effectiveness: evaluations of warm-started vs cold jobs;
  * cover-set compression: distinct winners vs tuned buckets ('a few fit
    most' — the smaller the cover, the more an unseen shape benefits);
  * with --telemetry: sustained-performance accounting from deployment
    runtime snapshots (launch.train/serve --telemetry-out) — per-tier hit
    rates and per-kernel exact-hit shares, i.e. how much real traffic the
    campaign's records actually served.

Run after a campaign:
    PYTHONPATH=src python -m benchmarks.campaign_report \
        --manifest campaign.json --db tuning.json \
        [--telemetry train_telemetry.json] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.campaign.scheduler import CampaignManifest
from repro.campaign.transfer import cluster_winners
from repro.core import TuningDatabase, split_key

RESULTS = os.path.join("benchmarks", "results")


def kernel_rows(manifest: CampaignManifest, db: TuningDatabase) -> List[Dict]:
    by_kernel: Dict[str, List] = {}
    for job in manifest.jobs:
        by_kernel.setdefault(job.kernel, []).append(job)
    rows = []
    for kernel, jobs in sorted(by_kernel.items()):
        done = [j for j in jobs if j.status == "done"]
        speedups = [
            j.default_objective / j.best_objective
            for j in done if j.best_objective > 0 and j.default_objective > 0
        ]
        warm = [j.evaluations for j in done if j.seeded]
        cold = [j.evaluations for j in done if not j.seeded]
        recs = [r for r in db.records()
                if split_key(r.key)[0] == kernel
                and split_key(r.key)[1] == manifest.platform]
        cover = cluster_winners(recs) if recs else []
        rows.append({
            "kernel": kernel,
            "jobs": len(jobs),
            "done": len(done),
            "failed": sum(1 for j in jobs if j.status == "failed"),
            "evals_spent": sum(j.evaluations for j in jobs),
            "evals_allocated": sum(j.budget for j in jobs),
            "mean_speedup": sum(speedups) / len(speedups) if speedups else 0.0,
            "max_speedup": max(speedups) if speedups else 0.0,
            "warm_jobs": len(warm),
            "mean_evals_warm": sum(warm) / len(warm) if warm else 0.0,
            "mean_evals_cold": sum(cold) / len(cold) if cold else 0.0,
            "tuned_buckets": len(recs),
            "distinct_winners": len({str(sorted(r.config.items())) for r in recs}),
            "cover_size": len(cover),
            "cover_share": sum(e["share"] for e in cover),
        })
    return rows


def telemetry_rows(paths) -> List[Dict]:
    """Summaries of exported runtime telemetry snapshots, one per file."""
    from repro.campaign.runner import load_telemetry

    return [{"source": path, **load_telemetry(path)} for path in paths]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--manifest", default="campaign.json")
    ap.add_argument("--db", default=None)
    ap.add_argument("--telemetry", action="append", default=[],
                    help="runtime telemetry snapshot JSON (repeatable)")
    ap.add_argument("--json", default=None, help="also write the report here")
    args = ap.parse_args()

    manifest = CampaignManifest.load(args.manifest)
    db = TuningDatabase(
        args.db or os.environ.get("REPRO_TUNING_DB", ".repro_tuning.json")
    )
    rows = kernel_rows(manifest, db)
    report = {"summary": manifest.summary(), "kernels": rows}
    if args.telemetry:
        report["telemetry"] = telemetry_rows(args.telemetry)

    s = report["summary"]
    print(f"campaign on {s['platform']}: {s['done']}/{s['jobs']} jobs done, "
          f"{s['evaluations_spent']}/{s['total_budget']} evals spent, "
          f"mean speedup {s['mean_speedup']:.2f}x, "
          f"{s['seeded_jobs']} warm-started")
    hdr = (f"{'kernel':<16} {'done':>6} {'evals':>7} {'speedup':>8} "
           f"{'warm-evals':>10} {'cold-evals':>10} {'buckets':>8} {'cover':>6}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['kernel']:<16} {r['done']:>4}/{r['jobs']:<2}"
              f" {r['evals_spent']:>6} {r['mean_speedup']:>7.2f}x"
              f" {r['mean_evals_warm']:>10.1f} {r['mean_evals_cold']:>10.1f}"
              f" {r['tuned_buckets']:>8} {r['cover_size']:>3}/{r['distinct_winners']}")

    from repro.campaign.runner import format_telemetry

    for t in report.get("telemetry", ()):
        print("\n" + format_telemetry(t, t["source"]))

    if args.json:
        os.makedirs(os.path.dirname(os.path.abspath(args.json)), exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
