"""Benchmark harness — one entry per paper table/figure + framework microbenches.

Emits ``name,us_per_call,derived`` CSV rows (derived = %speedup or context).

  fig1.*       — the paper's Figure 1 protocol: autotuned vs default across
                 input sizes (benchmarks/fig1_autotune.py)
  search.*     — Orio-style search-strategy comparison
  serving.*    — continuous (slot-pool) vs lock-step engine under Poisson
                 arrivals (benchmarks/serving_throughput.py)
  dispatch.*   — runtime resolution overhead, cold pipeline vs warm cache
                 (benchmarks/dispatch_overhead.py)
  obs.*        — observability-plane overhead: per-step obs cost vs the
                 kernel-mode step, disabled vs enabled collector
                 (benchmarks/obs_overhead.py)
  train.*      — smoke train-step throughput under a pinned dispatch runtime
                 (benchmarks/train_step_throughput.py); train.bwd_* compares
                 the reference-VJP backward recompute against the tuned
                 backward plane (gradients as dispatch sites)
  kernel.*     — Pallas-kernel interpret-mode correctness-at-speed spot check
  ssm.*        — selective-scan dispatch plane: chunked associative scan vs
                 the sequential lax.scan reference oracle
  moe.*        — grouped expert-gemm dispatch vs the per-expert einsum
                 reference (the three ``ecd,edf`` contractions it replaced)
  analysis.*   — static legality pruning: configs the abstract grid-model
                 checker removes from each kernel's space on a tpu-v5e
                 fingerprint before any measurement is spent

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, repeats=3):
    out = jax.jit(fn)(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.jit(fn)(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--budget", type=int, default=None)
    args = ap.parse_args()
    budget = args.budget or (8 if args.quick else 14)

    rows = []

    # --- Figure 1 analogue ------------------------------------------------
    from benchmarks import fig1_autotune

    fig1 = fig1_autotune.bench(budget=budget, quick=args.quick)
    for site, site_rows in fig1.items():
        for r in site_rows:
            rows.append(
                (f"fig1.{site}.size{r['size']}.baseline", r["baseline_s"] * 1e6, ""),
            )
            rows.append(
                (
                    f"fig1.{site}.size{r['size']}.tuned",
                    r["tuned_s"] * 1e6,
                    f"+{r['speedup_pct']:.0f}%",
                )
            )

    # --- search strategies --------------------------------------------------
    from benchmarks import search_convergence

    for r in search_convergence.bench(budget=max(8, budget)):
        rows.append(
            (
                f"search.{r['algorithm']}",
                r["best_s"] * 1e6,
                f"evals_to_best={r['evals_to_best']}",
            )
        )

    # --- serving: slot-pool vs lock-step scheduling -------------------------
    from benchmarks import serving_throughput

    sres = serving_throughput.bench(quick=args.quick)
    for eng_name, r in sres.items():
        rows.append((
            f"serving.{eng_name}.decode_steps", float(r["decode_steps"]),
            f"tok_per_step={r['tok_per_step']:.2f}",
        ))
    rows.append((
        "serving.continuous.steps_saved_pct",
        sres["continuous"]["steps_saved_pct"],
        "vs lockstep",
    ))

    # --- dispatch runtime: resolution-cache cold vs warm --------------------
    from benchmarks import dispatch_overhead

    dres = dispatch_overhead.bench(iters=50 if args.quick else 200)
    rows.append((
        "dispatch.resolve_cold", dres["cold_us"],
        f"buckets={dres['buckets']}",
    ))
    rows.append((
        "dispatch.resolve_warm", dres["warm_us"],
        f"hit_rate={dres['cache_hit_rate']:.2f}",
    ))

    # --- observability plane: overhead contract -----------------------------
    from benchmarks import obs_overhead

    ores = obs_overhead.bench(quick=args.quick)
    rows.append((
        "obs.step_instr_disabled", ores["step"]["instr_disabled_us"],
        f"+{ores['step']['overhead_disabled_pct']:.3f}% of step",
    ))
    rows.append((
        "obs.step_instr_enabled", ores["step"]["instr_enabled_us"],
        f"+{ores['step']['overhead_enabled_pct']:.3f}% of step",
    ))
    rows.append((
        "obs.resolve_enabled", ores["resolve"]["enabled_us"],
        f"+{ores['resolve']['overhead_enabled_pct']:.1f}% vs disabled",
    ))

    # --- training: step throughput under the dispatch runtime ---------------
    from benchmarks import train_step_throughput

    tres = train_step_throughput.bench(quick=args.quick)
    rows.append((
        "train.step_us", tres["step_us"],
        f"tok_per_s={tres['tok_per_s']:.0f}",
    ))
    rows.append((
        "train.dispatches", float(tres["dispatches"]),
        f"exact_share={tres['exact_share']:.2f}",
    ))
    # backward plane: kernel-mode step, reference-VJP recompute vs tuned
    # backward dispatch (gradients as first-class dispatch sites)
    bres = train_step_throughput.bench_bwd(quick=args.quick)
    rows.append((
        "train.bwd_reference_vjp.step_us", bres["fwd_only"]["step_us"],
        "fwd-only tuned (gradients recompute the reference)",
    ))
    rows.append((
        "train.bwd_dispatch.step_us", bres["fwd_bwd"]["step_us"],
        f"{bres['bwd_step_delta_pct']:+.0f}% vs reference-VJP",
    ))
    rows.append((
        "train.bwd_dispatch.sites", float(bres["fwd_bwd"]["bwd_dispatches"]),
        f"bwd_exact_share={bres['fwd_bwd']['bwd_exact_share']:.2f}",
    ))

    # --- kernels (interpret-mode; correctness-weighted spot check) ---------
    from repro.kernels import ref
    from repro.kernels.rmsnorm import rmsnorm_pallas

    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(256, 512), jnp.float32)
    w = jnp.asarray(rs.randn(512), jnp.float32)
    t_ref = _time(ref.rmsnorm, x, w)
    rows.append(("kernel.rmsnorm.ref_jnp", t_ref * 1e6, ""))
    out = rmsnorm_pallas(x, w, block_rows=64, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref.rmsnorm(x, w))))
    rows.append(("kernel.rmsnorm.pallas_interp_maxerr", err, "correctness"))

    # --- SSM / MoE dispatch plane: tuned form vs reference oracle ----------
    import functools

    import repro
    from repro.kernels.ssm_scan import ssm_scan_chunked

    b, s, di, ds = 2, (64 if args.quick else 256), 32, 16
    xc = jnp.asarray(rs.randn(b, s, di) * 0.3, jnp.float32)
    dt = jnp.asarray(np.abs(rs.randn(b, s, di)) * 0.1 + 0.01, jnp.float32)
    Bc = jnp.asarray(rs.randn(b, s, ds) * 0.3, jnp.float32)
    Cc = jnp.asarray(rs.randn(b, s, ds) * 0.3, jnp.float32)
    A = jnp.asarray(-np.abs(rs.randn(di, ds)) - 0.1, jnp.float32)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    t_seq = _time(ref.ssm_scan, xc, dt, Bc, Cc, A, h0)
    rows.append(("ssm.scan.ref_sequential", t_seq * 1e6, f"s={s}"))
    t_chunk = _time(functools.partial(ssm_scan_chunked, chunk=32),
                    xc, dt, Bc, Cc, A, h0)
    rows.append((
        "ssm.scan.chunked32", t_chunk * 1e6,
        f"{(t_seq / t_chunk - 1) * 100:+.0f}% vs sequential",
    ))

    e, cap, k, n = 4, (32 if args.quick else 128), 64, 128
    gx = jnp.asarray(rs.randn(e, cap, k) * 0.3, jnp.float32)
    gw = jnp.asarray(rs.randn(e, k, n) * 0.3, jnp.float32)
    t_eg_ref = _time(ref.expert_gemm, gx, gw)
    rows.append(("moe.expert_gemm.ref_einsum", t_eg_ref * 1e6, f"e={e} c={cap}"))
    with repro.runtime(mode="kernel"):
        t_eg = _time(lambda a, w_: repro.dispatch("expert_gemm", a, w_), gx, gw)
    rows.append((
        "moe.expert_gemm.dispatch", t_eg * 1e6,
        f"{(t_eg_ref / t_eg - 1) * 100:+.0f}% vs einsum",
    ))

    # --- static analysis: legality pruning per kernel config space ---------
    from repro.core.gridmodel import registered_models, space_report
    from repro.core.runtime import ensure_registered

    ensure_registered()
    for kernel in sorted(registered_models()):
        rep = space_report(kernel, "tpu-v5e")
        rows.append((
            f"analysis.{kernel}.pruned", float(rep["illegal"]),
            f"{rep['legal']} of {rep['total']} legal on tpu-v5e",
        ))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
