"""Train-step throughput under the dispatch runtime.

Measures the smoke trainer's steady-state step time on the host mesh with a
pinned `repro.runtime(...)` scope — the training analogue of the serving
throughput row. Reported alongside: tokens/sec and the runtime's tier
accounting (exact share > 0 means the step ran on tuned records; on an
empty database everything resolves at reference/heuristic, the untuned
baseline the campaign is supposed to beat).

:func:`bench_bwd` compares the two backward strategies in kernel mode —
``bwd_dispatch=False`` (the old reference-VJP recompute: gradients bypass
tuning entirely) vs ``bwd_dispatch=True`` (the tuned backward plane:
gradients are dispatch sites of their own) — the ``train.bwd_*`` rows.

Run directly:
    PYTHONPATH=src python -m benchmarks.train_step_throughput [--db DB] [--out J]
or via the harness: PYTHONPATH=src python -m benchmarks.run (train.* rows).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional


def bench(quick: bool = False, db_path: Optional[str] = None,
          mode: str = "auto") -> Dict:
    import repro
    from repro.configs.base import SHAPES, get_config
    from repro.core.database import TuningDatabase
    from repro.data.pipeline import DataConfig
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig
    import tempfile

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]
    run = defaults.default_run(cfg, shape)
    layout = defaults.default_layout(cfg)
    steps = 3 if quick else 6

    rt = repro.runtime(
        db=TuningDatabase(db_path) if db_path else TuningDatabase(None),
        mode=mode, name="bench-train",
    )
    trainer = Trainer(
        cfg, run, make_host_mesh(), layout,
        DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
        adamw.AdamWConfig(total_steps=steps + 1),
        TrainerConfig(total_steps=steps + 1, checkpoint_every=10_000,
                      checkpoint_dir=tempfile.mkdtemp(prefix="bench_ckpt_"),
                      async_checkpoint=False, log_every=10_000),
        runtime=rt,
    )
    trainer.run_one_step()                       # compile + warm caches
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        trainer.run_one_step()
        times.append(time.perf_counter() - t0)
    step_s = sorted(times)[len(times) // 2]
    tokens = shape.global_batch * shape.seq_len
    from repro.campaign.runner import summarize_telemetry

    snap = rt.telemetry.snapshot()
    summary = summarize_telemetry(snap)
    rollup = summary["kernels"].values()
    calls = max(1, snap["calls"])
    return {
        "step_us": step_s * 1e6,
        "tokens_per_step": tokens,
        "tok_per_s": tokens / step_s,
        "dispatches": snap["calls"],
        "exact_share": snap["tiers"].get("exact", 0) / calls,
        "measured_share": sum(
            r["measured_share"] * r["calls"] for r in rollup
        ) / calls if rollup else 0.0,
        "tiers": dict(snap["tiers"]),
    }


def _one_kernel_run(steps: int, db_path: Optional[str], bwd_dispatch: bool) -> Dict:
    import tempfile

    import repro
    from repro.configs.base import SHAPES, get_config
    from repro.core.database import TuningDatabase
    from repro.data.pipeline import DataConfig
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]
    rt = repro.runtime(
        db=TuningDatabase(db_path) if db_path else TuningDatabase(None),
        mode="kernel", bwd_dispatch=bwd_dispatch,
        name=f"bench-train-bwd{int(bwd_dispatch)}",
    )
    trainer = Trainer(
        cfg, defaults.default_run(cfg, shape), make_host_mesh(),
        defaults.default_layout(cfg),
        DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
        adamw.AdamWConfig(total_steps=steps + 1),
        TrainerConfig(total_steps=steps + 1, checkpoint_every=10_000,
                      checkpoint_dir=tempfile.mkdtemp(prefix="bench_ckpt_"),
                      async_checkpoint=False, log_every=10_000),
        runtime=rt,
    )
    trainer.run_one_step()                       # compile + warm caches
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        trainer.run_one_step()
        times.append(time.perf_counter() - t0)
    snap = rt.telemetry.snapshot()
    phases = snap.get("phases", {})
    bwd = phases.get("bwd", {})
    return {
        "step_us": sorted(times)[len(times) // 2] * 1e6,
        "dispatches": snap["calls"],
        "bwd_dispatches": sum(bwd.values()),
        "bwd_exact_share": (bwd.get("exact", 0) / sum(bwd.values())) if bwd else 0.0,
        "tiers": dict(snap["tiers"]),
        "phases": {p: dict(v) for p, v in phases.items()},
    }


def bench_bwd(quick: bool = False, db_path: Optional[str] = None) -> Dict:
    """Kernel-mode step time: reference-VJP backward vs tuned backward plane.

    On a TPU with a campaign database, ``fwd_bwd`` is the win this PR is
    about (gradient FLOPs stop running at reference speed); on the CPU host
    the row still proves the protocol — the bwd plane dispatches, resolves,
    and is observable per phase.
    """
    steps = 2 if quick else 4
    fwd_only = _one_kernel_run(steps, db_path, bwd_dispatch=False)
    fwd_bwd = _one_kernel_run(steps, db_path, bwd_dispatch=True)
    return {
        "fwd_only": fwd_only,
        "fwd_bwd": fwd_bwd,
        "bwd_step_delta_pct": 100.0 * (fwd_bwd["step_us"] - fwd_only["step_us"])
        / max(fwd_only["step_us"], 1e-9),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--db", default=None,
                    help="campaign-exported tuning database to dispatch against")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "kernel", "reference"))
    ap.add_argument("--bwd-compare", action="store_true",
                    help="also run the fwd-only vs fwd+bwd kernel-mode rows")
    ap.add_argument("--out", default=None,
                    help="write the result dict as JSON (the committed "
                         "benchmarks/results/BENCH_train.json baseline)")
    args = ap.parse_args()
    r = bench(quick=args.quick, db_path=args.db, mode=args.mode)
    print(f"train step: {r['step_us']:.0f} us ({r['tok_per_s']:.0f} tok/s), "
          f"{r['dispatches']} dispatches, exact share "
          f"{100 * r['exact_share']:.0f}% (tiers: {r['tiers']})")
    if args.bwd_compare or args.out:
        b = bench_bwd(quick=args.quick, db_path=args.db)
        r["bwd_compare"] = b
        print(f"kernel-mode step: fwd-only-tuned {b['fwd_only']['step_us']:.0f} us "
              f"vs fwd+bwd-tuned {b['fwd_bwd']['step_us']:.0f} us "
              f"({b['bwd_step_delta_pct']:+.0f}%), "
              f"{b['fwd_bwd']['bwd_dispatches']} bwd dispatches "
              f"(exact {100 * b['fwd_bwd']['bwd_exact_share']:.0f}%)")
    if args.out:
        import json

        with open(args.out, "w") as f:
            json.dump(r, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
