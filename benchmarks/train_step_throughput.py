"""Train-step throughput under the dispatch runtime.

Measures the smoke trainer's steady-state step time on the host mesh with a
pinned `repro.runtime(...)` scope — the training analogue of the serving
throughput row. Reported alongside: tokens/sec and the runtime's tier
accounting (exact share > 0 means the step ran on tuned records; on an
empty database everything resolves at reference/heuristic, the untuned
baseline the campaign is supposed to beat).

Run directly:
    PYTHONPATH=src python -m benchmarks.train_step_throughput [--db DB]
or via the harness: PYTHONPATH=src python -m benchmarks.run (train.* rows).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional


def bench(quick: bool = False, db_path: Optional[str] = None,
          mode: str = "auto") -> Dict:
    import repro
    from repro.configs.base import SHAPES, get_config
    from repro.core.database import TuningDatabase
    from repro.data.pipeline import DataConfig
    from repro.launch import defaults
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig
    import tempfile

    cfg = get_config("qwen2_0_5b").reduced()
    shape = SHAPES["train_smoke"]
    run = defaults.default_run(cfg, shape)
    layout = defaults.default_layout(cfg)
    steps = 3 if quick else 6

    rt = repro.runtime(
        db=TuningDatabase(db_path) if db_path else TuningDatabase(None),
        mode=mode, name="bench-train",
    )
    trainer = Trainer(
        cfg, run, make_host_mesh(), layout,
        DataConfig(seed=0, batch_size=shape.global_batch, seq_len=shape.seq_len),
        adamw.AdamWConfig(total_steps=steps + 1),
        TrainerConfig(total_steps=steps + 1, checkpoint_every=10_000,
                      checkpoint_dir=tempfile.mkdtemp(prefix="bench_ckpt_"),
                      async_checkpoint=False, log_every=10_000),
        runtime=rt,
    )
    trainer.run_one_step()                       # compile + warm caches
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        trainer.run_one_step()
        times.append(time.perf_counter() - t0)
    step_s = sorted(times)[len(times) // 2]
    tokens = shape.global_batch * shape.seq_len
    from repro.campaign.runner import summarize_telemetry

    snap = rt.telemetry.snapshot()
    summary = summarize_telemetry(snap)
    rollup = summary["kernels"].values()
    calls = max(1, snap["calls"])
    return {
        "step_us": step_s * 1e6,
        "tokens_per_step": tokens,
        "tok_per_s": tokens / step_s,
        "dispatches": snap["calls"],
        "exact_share": snap["tiers"].get("exact", 0) / calls,
        "measured_share": sum(
            r["measured_share"] * r["calls"] for r in rollup
        ) / calls if rollup else 0.0,
        "tiers": dict(snap["tiers"]),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--db", default=None,
                    help="campaign-exported tuning database to dispatch against")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "kernel", "reference"))
    args = ap.parse_args()
    r = bench(quick=args.quick, db_path=args.db, mode=args.mode)
    print(f"train step: {r['step_us']:.0f} us ({r['tok_per_s']:.0f} tok/s), "
          f"{r['dispatches']} dispatches, exact share "
          f"{100 * r['exact_share']:.0f}% (tiers: {r['tiers']})")


if __name__ == "__main__":
    main()
