"""Roofline report: merge dry-run JSON records with the analytic model.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun),
computes the three roofline terms per (arch × shape × mesh):

    compute   — analytic step FLOPs / (chips × 197 TFLOP/s)
    memory    — analytic HBM traffic / (chips × 819 GB/s)
    collective— trip-count-aware HLO collective bytes × wire factor / 50 GB/s

and emits the §Roofline markdown table + a machine-readable summary JSON.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.configs.base import SHAPES, get_config
from repro.models import lm
from repro.tools.analytic import analytic_roofline

RESULTS_DIR = os.path.join("benchmarks", "results", "dryrun")

_PCACHE = {}


def _counts(arch):
    if arch not in _PCACHE:
        cfg = get_config(arch)
        _PCACHE[arch] = (cfg, lm.param_count(cfg), lm.active_param_count(cfg))
    return _PCACHE[arch]


def load_records(mesh_filter=None, include_variants=False):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        if os.path.basename(path).startswith("summary"):
            continue
        with open(path) as f:
            r = json.load(f)
        if not isinstance(r, dict) or "cell" not in r:
            continue
        parts = r["cell"].split("__")
        if len(parts) > 3 and not include_variants:
            continue  # hillclimb variants handled separately
        if mesh_filter and (len(parts) < 3 or parts[2] != mesh_filter):
            continue
        recs.append(r)
    return recs


def enrich(rec):
    """Attach analytic roofline terms to one dry-run record."""
    if rec.get("status") != "ok":
        return rec
    cfg, n_params, n_active = _counts(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mesh_shape = rec["mesh"]
    model_par = mesh_shape[-1]
    ar = analytic_roofline(
        cfg,
        shape,
        chips=rec["chips"],
        collective_bytes_by_kind=rec["collectives"]["bytes_by_kind"],
        model_par=model_par,
        fsdp=rec["layout"].get("fsdp", False),
        remat=rec["run"].get("remat", "dots"),
        fused_xent=False,
        params=n_params,
        active_params=n_active,
    )
    rec["analytic"] = ar.to_json()
    return rec


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs):
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "useful | roofline-frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        cell = r["cell"].split("__")
        if r.get("status") == "skipped":
            lines.append(
                f"| {cell[0]} | {cell[1]} | {cell[2]} | — | — | — | — | — | — | "
                f"SKIP: sub-quadratic rule |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {cell[0]} | {cell[1]} | {cell[2]} | — | — | — | — | — | — | "
                f"ERROR {r.get('error','')[:60]} |"
            )
            continue
        a = r["analytic"]
        lines.append(
            "| {arch} | {shape} | {mesh} | {c} | {m} | {x} | **{dom}** | "
            "{useful:.2f} | {rf:.1%} | |".format(
                arch=cell[0], shape=cell[1], mesh=cell[2],
                c=fmt_s(a["compute_s"]), m=fmt_s(a["memory_s"]),
                x=fmt_s(a["collective_s"]), dom=a["dominant"],
                useful=min(a["useful_ratio"], 9.99), rf=a["roofline_fraction"],
            )
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--json-out", default=os.path.join(RESULTS_DIR, "summary.json"))
    args = ap.parse_args()
    recs = [enrich(r) for r in load_records(args.mesh)]
    recs.sort(key=lambda r: r["cell"])
    print(table(recs))
    with open(args.json_out, "w") as f:
        json.dump(recs, f, indent=1)
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["analytic"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["analytic"]["collective_s"]
                   / max(r["analytic"]["step_time_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['cell']} "
              f"({worst['analytic']['roofline_fraction']:.1%})", file=sys.stderr)
        print(f"most collective-bound:  {coll['cell']}", file=sys.stderr)


if __name__ == "__main__":
    main()
