"""Dispatch-overhead microbenchmark: resolution cost, cold vs warm runtime.

The dispatch runtime resolves (kernel × shape-bucket × dtype) → config
through its policy pipeline once per bucket, then serves repeats from the
per-runtime resolution cache. This benchmark quantifies both sides:

* **cold** — first resolution per bucket: db key construction + policy
  pipeline (exact lookup, cover scan, heuristic) per call;
* **warm** — cached resolution: one dict probe + telemetry per call.

The gap is what repeated jit traces (retracing the same serving buckets)
no longer pay, and the cache hit rate comes straight from the runtime's
telemetry. Run standalone::

    PYTHONPATH=src python benchmarks/dispatch_overhead.py

or as the ``dispatch.*`` rows of ``python -m benchmarks.run``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.core import Record, TuningDatabase, TunedRuntime, make_key
from repro.core.platform import detect_platform
from repro.kernels.matmul import matmul as matmul_tunable


def _shapes(n: int = 8) -> List[Tuple[int, int, int]]:
    # Distinct power-of-two m => n distinct shape buckets (no aliasing).
    return [(64 << i, 128, 64) for i in range(n)]


def bench(iters: int = 200, n_buckets: int = 8) -> Dict:
    platform = detect_platform().name
    args_list = [
        (jnp.zeros((m, k), jnp.float32), jnp.zeros((k, n), jnp.float32))
        for m, k, n in _shapes(n_buckets)
    ]
    # Records for half the buckets: the cold pass exercises both an exact
    # hit and the full fall-through to the heuristic tier.
    db = TuningDatabase(None)
    for x, w in args_list[: len(args_list) // 2]:
        key = make_key("matmul", platform, [x.shape, w.shape], "float32")
        db.put(Record(key, {"bm": 8, "bn": 128, "bk": 128},
                      1e-6, "wallclock", 1, 0.0), save=False)

    rt = TunedRuntime(db=db, mode="kernel", name="dispatch-bench")
    t0 = time.perf_counter()
    for x, w in args_list:
        rt.resolve(matmul_tunable, (x, w))
    cold_us = (time.perf_counter() - t0) / len(args_list) * 1e6
    cold_tiers = dict(rt.telemetry.snapshot()["tiers"])

    t0 = time.perf_counter()
    for _ in range(iters):
        for x, w in args_list:
            rt.resolve(matmul_tunable, (x, w))
    warm_us = (time.perf_counter() - t0) / (iters * len(args_list)) * 1e6

    snap = rt.telemetry.snapshot()
    return {
        "cold_us": cold_us,
        "warm_us": warm_us,
        "speedup": cold_us / warm_us if warm_us else float("inf"),
        "cache_hit_rate": snap["cache_hit_rate"],
        "tiers": cold_tiers,
        "buckets": len(args_list),
    }


if __name__ == "__main__":
    r = bench()
    print(f"cold resolve: {r['cold_us']:.1f} us/call over {r['buckets']} buckets "
          f"(tiers: {r['tiers']})")
    print(f"warm resolve: {r['warm_us']:.2f} us/call "
          f"({r['speedup']:.0f}x vs cold, hit rate {r['cache_hit_rate']:.2%})")
