"""Figure-1 analogue: autotuned vs baseline across input sizes.

The paper's Figure 1 sweeps input-vector sizes and reports (a) absolute
kernel time and (b) % speedup of the autotuned variant over the `-O3`
auto-vectorized baseline, with the winning variant changing per size.

Protocol here, faithfully: for each tuning site (chunked attention, mamba
scan, fused-loss chunking) and each input size, measure the *default
config* (the framework's hand heuristic = the '-O3' baseline) and the
*autotuned best* (coordinate descent, wall-clock evaluator, correctness
gate vs the reference), then report per-size speedups and the per-size
winning config. Claims validated (EXPERIMENTS.md §Paper-claims):
  C3  — autotuned ≥ baseline everywhere (search never regresses: the tuner
        re-measures the default too);
  C5  — gains are input-size-dependent and the best config varies with
        size, the reason the tuning database is shape-keyed.

Run: PYTHONPATH=src python -m benchmarks.fig1_autotune [--budget 14]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoordinateDescent, TuningDatabase, WallClockEvaluator, autotune
from repro.models import ssm
from repro.models.tunables import attention_chunked, make_mamba_tunable

RESULTS = os.path.join("benchmarks", "results")


def tune_site(tun, args_list, sizes, budget, repeats=3):
    rows = []
    db = TuningDatabase(os.path.join(RESULTS, "fig1_db.json"))
    for size, args in zip(sizes, args_list):
        res = autotune(
            tun,
            args,
            search=CoordinateDescent(budget=budget, restarts=1),
            evaluator=WallClockEvaluator(repeats=repeats, warmup=1),
            db=db,
        )
        rows.append(
            {
                "size": size,
                "baseline_s": res.default_objective,
                "tuned_s": res.best_objective,
                "speedup_pct": 100.0 * (res.default_objective / res.best_objective - 1.0),
                "best_config": res.best_config,
                "evaluations": res.evaluations,
            }
        )
        print(
            f"  size {size:>6}: baseline {res.default_objective*1e3:8.2f}ms "
            f"tuned {res.best_objective*1e3:8.2f}ms "
            f"(+{rows[-1]['speedup_pct']:.0f}%)  cfg={res.best_config}"
        )
    return rows


def bench(budget=14, quick=False):
    rs = np.random.RandomState(0)
    out = {}

    sizes = [128, 256, 512] if quick else [128, 256, 512, 1024]
    print("site: chunked attention (q_chunk, k_chunk)")
    args_list = []
    for s in sizes:
        q = jnp.asarray(rs.randn(1, 4, s, 32) * 0.3, jnp.float32)
        k = jnp.asarray(rs.randn(1, 2, s, 32) * 0.3, jnp.float32)
        v = jnp.asarray(rs.randn(1, 2, s, 32), jnp.float32)
        args_list.append((q, k, v))
    out["attention"] = tune_site(attention_chunked, args_list, sizes, budget)

    print("site: mamba scan chunk")
    p, _ = ssm.mamba_init(jax.random.PRNGKey(0), 64, jnp.float32)
    mamba_tun = make_mamba_tunable(p)
    sizes_m = [128, 512] if quick else [128, 512, 2048]
    args_list = [
        (jnp.asarray(rs.randn(2, s, 64) * 0.5, jnp.float32),) for s in sizes_m
    ]
    out["mamba"] = tune_site(mamba_tun, args_list, sizes_m, budget)

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fig1.json"), "w") as f:
        json.dump(out, f, indent=1)

    # paper-claims checks
    flat = [r for rows in out.values() for r in rows]
    assert all(r["tuned_s"] <= r["baseline_s"] * 1.05 for r in flat), \
        "autotuned variant must not regress"
    configs = {json.dumps(r["best_config"], sort_keys=True) for r in out["attention"]}
    print(f"\ndistinct winning attention configs across sizes: {len(configs)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=14)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    bench(args.budget, args.quick)


if __name__ == "__main__":
    main()
