"""Search-strategy comparison (Orio ships multiple strategies; this is the
table justifying which one the framework defaults to).

Each algorithm gets the same budget on the same wall-clock objective
(chunked attention at one shape); we report best-found time and the
evaluation count at which it was first reached.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import ALGORITHMS, WallClockEvaluator, make_search
from repro.core.search.base import Trial
from repro.models.tunables import ATTN_CHUNK_SPACE, attention_chunked

RESULTS = os.path.join("benchmarks", "results")


def bench(budget=16, seed=0):
    rs = np.random.RandomState(0)
    s = 512
    q = jnp.asarray(rs.randn(1, 4, s, 32) * 0.3, jnp.float32)
    k = jnp.asarray(rs.randn(1, 2, s, 32) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(1, 2, s, 32), jnp.float32)
    ev = WallClockEvaluator(repeats=3, warmup=1)

    rows = []
    for name in sorted(ALGORITHMS):
        measured = {}

        def objective(cfg):
            key = tuple(sorted(cfg.items()))
            if key not in measured:
                m = ev.evaluate(attention_chunked.variant(**cfg), (q, k, v))
                measured[key] = m
            m = measured[key]
            return Trial(config=cfg, objective=m.objective, ok=m.ok)

        res = make_search(name, budget=budget, seed=seed).run(
            ATTN_CHUNK_SPACE, objective
        )
        # first index reaching the best
        best = res.best_objective
        first = next(
            (i + 1 for i, t in enumerate(res.trials) if t.objective <= best * 1.001),
            res.evaluations,
        )
        rows.append(
            {
                "algorithm": name,
                "best_s": best,
                "evals": res.evaluations,
                "evals_to_best": first,
            }
        )
        print(
            f"  {name:12s} best {best*1e3:7.2f}ms in {res.evaluations:3d} evals "
            f"(first hit at {first})"
        )
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "search_convergence.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    bench()
