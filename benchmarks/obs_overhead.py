"""Observability-overhead benchmark: the obs plane's no-cost contract.

`repro.obs` instruments the hot paths (dispatch resolution, the per-step
span + metrics the trainer records, serving ticks). The contract is that a
**disabled** collector — the process default — costs one predicate branch
per site, and an **enabled** default-sampled collector stays in noise for a
kernel-mode step whose real work is jitted compute. This benchmark bounds
both:

* ``step.*`` — a jitted kernel-mode fwd+bwd step (matmul + rmsnorm through
  ``repro.dispatch``, gradients included) vs the per-step cost of exactly
  the obs calls the trainer adds around it (span + observe + counter),
  measured in isolation where microsecond precision is possible; overhead
  is their ratio (see :func:`bench_step` for why not A+B-vs-B timing).
* ``resolve.*`` — the eager dispatch-resolution hot path (where the obs
  calls run per-call, not per-trace): warm cached resolves with the
  collector disabled vs enabled.

Assertion mode (``--assert-overhead``, the CI obs leg) enforces the
acceptance bars: disabled < 2% step overhead, enabled < 5%.

Run:  PYTHONPATH=src python -m benchmarks.obs_overhead [--quick] [--out J]
or as the ``obs.*`` rows of ``python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict


def _min_round_us(fn, rounds: int, steps: int) -> float:
    """Median-free, drift-robust timing: per-round mean, min across rounds."""
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(steps):
            fn()
        best = min(best, (time.perf_counter() - t0) / steps)
    return best * 1e6


def bench_step(quick: bool = False) -> Dict:
    """Kernel-mode fwd+bwd step overhead, bounded by isolated instrumentation cost.

    A jitted CPU step's wall time is noisy at the ±5% level, so timing
    (step + obs) against (step) cannot resolve a 2% bound in CI. Instead we
    measure the two quantities whose ratio *is* the overhead, each where it
    can be measured precisely: the kernel-mode step time (min-of-rounds over
    the jitted fwd+bwd), and the per-step cost of exactly the obs calls the
    trainer adds around it (span + observe + counter, timed in isolation
    over thousands of iterations). ``overhead = instr_cost / step_time`` is
    an upper bound on the added fraction — the obs calls do the same work
    whether or not a jitted call sits inside the span.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    import repro.obs as obs
    from repro.obs.collect import current_collector
    from repro.obs.trace import span

    rt = repro.runtime(mode="kernel", name="obs-bench")
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(128, 256), jnp.float32)
    w = jnp.asarray(rs.randn(256, 256), jnp.float32)
    g = jnp.asarray(rs.randn(256), jnp.float32)

    def loss(x, w, g):
        h = repro.dispatch("matmul", x, w)
        h = repro.dispatch("rmsnorm", h, g)
        return jnp.sum(h * h)

    with rt:
        step = jax.jit(jax.grad(loss, argnums=(1, 2)))
        jax.block_until_ready(step(x, w, g))     # trace + compile once

    def raw():
        jax.block_until_ready(step(x, w, g))

    def instr_only():
        # exactly what Trainer.run_one_step wraps around the jitted step,
        # with the step itself removed
        t0 = time.perf_counter()
        with span("train.step"):
            pass
        col = current_collector()
        if col.enabled:
            col.observe("train.step_s", time.perf_counter() - t0)
            col.counter("train.tokens", x.shape[0])

    rounds, steps = (3, 10) if quick else (5, 30)
    step_us = _min_round_us(raw, rounds, steps)
    n = 2000 if quick else 10000
    # no collector entered: the ambient one is the disabled process default
    instr_disabled_us = _min_round_us(instr_only, 3, n)
    with obs.collect(name="obs-bench"):
        instr_enabled_us = _min_round_us(instr_only, 3, n)
    return {
        "step_us": step_us,
        "instr_disabled_us": instr_disabled_us,
        "instr_enabled_us": instr_enabled_us,
        "overhead_disabled_pct": 100.0 * instr_disabled_us / step_us,
        "overhead_enabled_pct": 100.0 * instr_enabled_us / step_us,
    }


def bench_resolve(quick: bool = False) -> Dict:
    """Warm cached dispatch resolution, collector disabled vs enabled.

    This is the path where obs code runs per *call* (resolve happens at
    trace time under jit, but eager callers and retraces pay it live).
    """
    import jax.numpy as jnp

    import repro.obs as obs
    from repro.core import TunedRuntime
    from repro.kernels.matmul import matmul as matmul_tunable

    rt = TunedRuntime(mode="kernel", name="obs-resolve-bench")
    args_list = [
        (jnp.zeros((64 << i, 128), jnp.float32),
         jnp.zeros((128, 64), jnp.float32))
        for i in range(4)
    ]
    for a in args_list:                          # warm the resolution cache
        rt.resolve(matmul_tunable, a)

    def loop():
        for a in args_list:
            rt.resolve(matmul_tunable, a)

    rounds, steps = (3, 20) if quick else (5, 100)
    disabled_us = _min_round_us(loop, rounds, steps) / len(args_list)
    with obs.collect(name="obs-resolve-bench"):
        enabled_us = _min_round_us(loop, rounds, steps) / len(args_list)
    return {
        "disabled_us": disabled_us,
        "enabled_us": enabled_us,
        "overhead_enabled_pct": max(
            0.0, 100.0 * (enabled_us - disabled_us) / disabled_us
        ),
    }


def bench(quick: bool = False) -> Dict:
    return {"step": bench_step(quick=quick), "resolve": bench_resolve(quick=quick)}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write the result dict as JSON (the committed "
                         "benchmarks/results/BENCH_obs.json baseline)")
    ap.add_argument("--assert-overhead", action="store_true",
                    help="fail (exit 1) unless disabled < 2%% and "
                         "enabled < 5%% step overhead — the CI gate")
    args = ap.parse_args()
    r = bench(quick=args.quick)
    s = r["step"]
    print(f"kernel-mode step: {s['step_us']:.0f} us; per-step obs cost "
          f"disabled {s['instr_disabled_us']:.2f} us "
          f"(+{s['overhead_disabled_pct']:.3f}%), "
          f"enabled {s['instr_enabled_us']:.2f} us "
          f"(+{s['overhead_enabled_pct']:.3f}%)")
    rv = r["resolve"]
    print(f"warm resolve: obs-disabled {rv['disabled_us']:.2f} us/call, "
          f"obs-enabled {rv['enabled_us']:.2f} us/call "
          f"(+{rv['overhead_enabled_pct']:.1f}%)")
    if args.out:
        import json

        with open(args.out, "w") as f:
            json.dump(r, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")
    if args.assert_overhead:
        ok = (s["overhead_disabled_pct"] < 2.0
              and s["overhead_enabled_pct"] < 5.0)
        print(f"overhead contract: "
              f"{'OK' if ok else 'VIOLATED'} "
              f"(disabled < 2%, enabled-default-sampled < 5%)")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
