"""Serving-engine scheduling benchmark: slot-pool continuous batching vs
the lock-step static batcher, under Poisson arrivals with skewed lengths.

Workload model: requests arrive by a seeded Poisson process (exponential
inter-arrival gaps, in decode ticks) with geometric-ish skewed
``max_new_tokens`` — a few long generations among many short ones, the
regime where lock-step batching wastes the most decode work.

Reported per engine:
  decode_steps   — pool decode invocations to drain the workload
  tok_per_step   — kept tokens per decode invocation (higher is better)
  p50/p95_lat    — per-request latency in ticks, admission → own last token

Run: PYTHONPATH=src python -m benchmarks.serving_throughput [--quick]
(or through ``python -m benchmarks.run``).
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np


def _workload(cfg, n_requests: int, seed: int = 0) -> List[dict]:
    rs = np.random.RandomState(seed)
    out = []
    t = 0.0
    for _ in range(n_requests):
        t += float(rs.exponential(2.0))            # Poisson arrivals, ~0.5 req/tick
        long_tail = rs.rand() < 0.2
        max_new = int(rs.randint(16, 25)) if long_tail else int(rs.randint(2, 6))
        out.append(dict(
            prompt=rs.randint(0, cfg.vocab_size, int(rs.randint(4, 14))).astype(np.int32),
            max_new_tokens=max_new,
            arrival_time=t,
        ))
    return out


def _latency_ticks(done) -> np.ndarray:
    return np.asarray(sorted(r.latency_steps for r in done), np.float64)


def bench(n_requests: int = 24, quick: bool = False, seed: int = 0) -> Dict[str, dict]:
    import jax

    from repro.configs import get_config
    from repro.distributed.sharding import Layout
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.models.transformer import RunConfig
    from repro.serving.engine import (
        EngineConfig, LockStepEngine, Request, ServingEngine,
    )

    if quick:
        n_requests = min(n_requests, 10)
    cfg = get_config("qwen2_0_5b").reduced()
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    run = RunConfig(remat="none", loss_chunk=16, q_chunk=16, k_chunk=16)
    ecfg = EngineConfig(max_batch=4, max_seq=64)
    specs = _workload(cfg, n_requests, seed)

    results: Dict[str, dict] = {}
    for name, Engine in (("continuous", ServingEngine), ("lockstep", LockStepEngine)):
        eng = Engine(cfg, run, params, make_host_mesh(), Layout(), ecfg)
        for s in specs:
            kw = dict(s)
            if name == "lockstep":
                kw.pop("arrival_time")     # the static batcher ignores arrivals
            eng.submit(Request(**kw))
        done = eng.serve()
        lat = _latency_ticks(done) if name == "continuous" else None
        steps = eng.stats["decode_steps"]
        toks = sum(len(r.output) for r in done)
        results[name] = {
            "decode_steps": steps,
            "tokens": toks,
            "tok_per_step": toks / max(1, steps),
            "p50_lat_ticks": float(lat[len(lat) // 2]) if lat is not None else float("nan"),
            "p95_lat_ticks": float(lat[int(0.95 * (len(lat) - 1))]) if lat is not None else float("nan"),
        }
    c, l = results["continuous"], results["lockstep"]
    results["continuous"]["steps_saved_pct"] = 100.0 * (1 - c["decode_steps"] / max(1, l["decode_steps"]))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res = bench(n_requests=args.requests, quick=args.quick, seed=args.seed)
    print("engine,decode_steps,tokens,tok_per_step,p50_lat_ticks,p95_lat_ticks")
    for name, r in res.items():
        print(f"{name},{r['decode_steps']},{r['tokens']},{r['tok_per_step']:.2f},"
              f"{r['p50_lat_ticks']:.1f},{r['p95_lat_ticks']:.1f}")
    saved = res["continuous"]["steps_saved_pct"]
    print(f"# in-flight admission saved {saved:.0f}% of pool decode steps")


if __name__ == "__main__":
    main()
