"""Deterministic fault injection for the resilient dispatch plane.

A :class:`FaultPlan` is a seeded list of :class:`FaultRule` entries, each
naming an injection *site* (fnmatch pattern) and a failure *kind*. Code
under test declares its sites with :func:`fault_point`::

    fault_point("dispatch.kernel:matmul", tier="exact")

and the active plan decides — deterministically, from its seed and the
per-site call count — whether that call fails. Sites wired today:

    dispatch.kernel:<tunable>     runtime kernel execution (guarded path)
    bgtune.worker:<kernel>        background-tuner job execution
    campaign.job:<kernel>         campaign runner job execution
    db.load:<path>                tuning-database file read
    checkpoint.write:<step>       checkpointer staged write
    train.step:<step>             trainer step (chaos train tests)

Fault kinds:

    error     raise :class:`InjectedFault` (an ordinary ``Exception`` —
              what guards/retries are expected to absorb)
    nan       return the rule to the call site, which must corrupt its
              concrete output with NaNs (the non-finite-probe drill)
    latency   ``time.sleep(rule.delay_s)`` then continue (straggler /
              timeout drill)
    crash     raise :class:`InjectedWorkerCrash` — a ``BaseException``
              that escapes ``except Exception`` retry loops, killing the
              worker thread it fires on (crash-isolation drill)
    torn      raise ``ValueError`` mimicking a torn/corrupt file read
              (what ``json.load`` raises on a half-written file)

Activation is contextvar-scoped (``with plan:``) so concurrent tests are
isolated; a plan can additionally be installed process-globally
(``plan.install()``) for worker threads that start with a fresh context.
Every firing is recorded in ``plan.fired`` so tests can assert exactly
which faults were exercised. With no plan active, :func:`fault_point` is
one module-global bool check — the production hot path stays free.

This module is stdlib-only by design: the dispatch runtime imports it at
module scope and must not gain a dependency cycle (or a jax import).
"""
from __future__ import annotations

import contextvars
import dataclasses
import fnmatch
import random
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """A seeded, injected failure — ordinary Exception; guards absorb it."""


class InjectedWorkerCrash(BaseException):
    """An injected crash that escapes ``except Exception`` retry loops.

    Raised for kind="crash": the thread it fires on dies (its top-level
    ``except Exception`` cannot catch a BaseException), which is exactly
    the condition worker-isolation logic must survive.
    """


_KINDS = ("error", "nan", "latency", "crash", "torn")


@dataclasses.dataclass
class FaultRule:
    """One injection rule: where, what, and how often.

    ``site`` is an fnmatch pattern against the call site's name
    (``"dispatch.kernel:matmul*"``). ``when`` optionally narrows by the
    site's context fields (fnmatch per value — e.g. ``{"tier": "exact"}``
    fires only when the guarded call runs a stored record, leaving the
    heuristic fall-through healthy). ``p`` is the per-eligible-call firing
    probability drawn from the plan's seeded stream; ``after`` skips the
    first N eligible calls and ``times`` caps total firings, so "fail the
    3rd save, once" is expressible and exactly reproducible.
    """

    site: str
    kind: str = "error"
    p: float = 1.0
    times: Optional[int] = None
    after: int = 0
    delay_s: float = 0.0
    when: Dict[str, str] = dataclasses.field(default_factory=dict)
    message: str = ""
    # runtime state
    seen: int = 0
    fired: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {_KINDS}")

    def matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        if not fnmatch.fnmatchcase(site, self.site):
            return False
        for k, pat in self.when.items():
            if not fnmatch.fnmatchcase(str(ctx.get(k, "")), str(pat)):
                return False
        return True


class FaultPlan:
    """A seeded set of fault rules, activatable as a context manager.

    Deterministic: the same plan (rules + seed) against the same sequence
    of :func:`fault_point` calls fires the same faults. ``fired`` keeps
    ``(site, kind, rule_index)`` tuples in firing order for assertions.
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0, name: str = "faults"):
        self.rules: List[FaultRule] = list(rules)
        self.seed = int(seed)
        self.name = name
        self.fired: List[Tuple[str, str, int]] = []
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()

    # -- activation -----------------------------------------------------------
    def __enter__(self) -> "FaultPlan":
        global _enabled
        _ctx.set(_ctx.get() + (self,))
        _enabled = True
        return self

    def __exit__(self, *exc) -> None:
        s = _ctx.get()
        for i in range(len(s) - 1, -1, -1):
            if s[i] is self:
                _ctx.set(s[:i] + s[i + 1:])
                break
        _refresh_enabled()

    def install(self) -> "FaultPlan":
        """Also activate process-globally: worker threads start with a fresh
        contextvar context and would otherwise never see a scoped plan."""
        global _global_plan, _enabled
        _global_plan = self
        _enabled = True
        return self

    def uninstall(self) -> None:
        global _global_plan
        if _global_plan is self:
            _global_plan = None
        _refresh_enabled()

    # -- consultation ---------------------------------------------------------
    def consult(self, site: str, ctx: Dict[str, Any]) -> Optional[FaultRule]:
        with self._lock:
            for i, rule in enumerate(self.rules):
                if not rule.matches(site, ctx):
                    continue
                rule.seen += 1
                if rule.seen <= rule.after:
                    continue
                if rule.times is not None and rule.fired >= rule.times:
                    continue
                if rule.p < 1.0 and self._rng.random() >= rule.p:
                    continue
                rule.fired += 1
                self.fired.append((site, rule.kind, i))
                return rule
        return None

    def count(self, site_pattern: str = "*", kind: Optional[str] = None) -> int:
        return sum(
            1 for s, k, _ in self.fired
            if fnmatch.fnmatchcase(s, site_pattern) and (kind is None or k == kind)
        )

    def __repr__(self) -> str:
        return (f"<FaultPlan {self.name} seed={self.seed} "
                f"rules={len(self.rules)} fired={len(self.fired)}>")


# ---------------------------------------------------------------------------
# Activation plumbing
# ---------------------------------------------------------------------------

_ctx: "contextvars.ContextVar[Tuple[FaultPlan, ...]]" = contextvars.ContextVar(
    "repro_fault_plans", default=()
)
_global_plan: Optional[FaultPlan] = None
# Module-global fast path: False means no plan has been active anywhere, so
# fault_point is a single bool check on production hot paths.
_enabled = False


def _refresh_enabled() -> None:
    global _enabled
    _enabled = bool(_ctx.get()) or _global_plan is not None


def active_plan() -> Optional[FaultPlan]:
    """The innermost scoped plan, else the process-global one, else None."""
    s = _ctx.get()
    if s:
        return s[-1]
    return _global_plan


def fault_point(site: str, **ctx: Any) -> Optional[FaultRule]:
    """Declare one injection site; enact whatever the active plan says.

    Raises :class:`InjectedFault` (kind="error"), ``ValueError``
    (kind="torn"), or :class:`InjectedWorkerCrash` (kind="crash"); sleeps
    for kind="latency"; returns the rule for kinds the *call site* must
    enact itself (kind="nan" — only the site knows its output value).
    Returns None when nothing fires.
    """
    if not _enabled:
        return None
    plan = active_plan()
    if plan is None:
        return None
    rule = plan.consult(site, ctx)
    if rule is None:
        return None
    if rule.kind == "error":
        raise InjectedFault(rule.message or f"injected fault at {site}")
    if rule.kind == "crash":
        raise InjectedWorkerCrash(rule.message or f"injected crash at {site}")
    if rule.kind == "torn":
        raise ValueError(rule.message or f"injected torn read at {site}")
    if rule.kind == "latency":
        time.sleep(rule.delay_s)
        return rule
    return rule  # "nan": the site corrupts its own (concrete) output
