"""Test-support substrate shipped with the library (not only under tests/):
the deterministic fault-injection harness lives here so the chaos CI leg,
external integration suites, and staging environments can all drive the
same seeded failure scenarios against a real process.
"""
from .faults import (  # noqa: F401
    FaultPlan,
    FaultRule,
    InjectedFault,
    InjectedWorkerCrash,
    active_plan,
    fault_point,
)
