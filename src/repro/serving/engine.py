"""Continuous-batching serving engine: slot pool, in-flight admission,
per-slot completion.

The engine owns a fixed pool of ``max_batch`` *slots*. Each slot is one
batch row of a shared cache pytree (allocated once at ``max_seq`` capacity)
plus host-side per-slot state: the request occupying it, its absolute
position, its sampling RNG, and the tokens emitted so far. The serve loop
is::

    admit   — while a slot is free and a request has arrived, right-pad its
              prompt to a power-of-two bucket, prefill it at batch 1, and
              *insert* the fresh cache into the slot (a full overwrite —
              nothing from the previous occupant survives);
    decode  — ONE jitted step over the whole pool per tick, with a per-slot
              position vector; inactive slots decode a dummy token that is
              never read;
    retire  — a slot whose request hit its own ``max_new_tokens`` is freed
              immediately and the next queued request is admitted mid-flight,
              while the other slots keep decoding.

Compare :class:`LockStepEngine` (the old static batcher, kept for
regression benchmarks): it packs a whole batch, decodes until *every*
member finishes, and only then admits new traffic. On skewed workloads the
slot pool strictly reduces total decode steps (see
``tests/test_serving_throughput.py`` and ``benchmarks/serving_throughput.py``).

jit-key invariant: admission prefills compile one (1, seq-bucket) key per
power-of-two bucket and decode compiles ONE (max_batch,) pool key — exactly
the slot-pool buckets ``campaign.planner.serving_buckets`` enumerates, so a
campaign-exported per-platform database warmed via :meth:`ServingEngine.warmup`
keeps hitting while the batch composition changes continuously. Database
bucket keys are unchanged from the static engine (same ``shape_bucket``
discipline), so existing campaign exports stay valid.

Equivalence contract: greedy (and seeded-temperature) outputs are
token-for-token identical to running each request alone, for any arrival
pattern — causal masking keeps right-pad tokens out of real positions,
window caches are ring-aligned to the true prompt length, and decode masks
each slot's unwritten cache rows (property-tested in
``tests/test_serving_continuous.py``). Archs with SSM mixers prefill at the
exact prompt length instead (a state polluted by pad tokens cannot be
masked after the fact); MoE archs need capacity headroom, as ever, since
expert capacity couples batch rows.

Timing: the engine has a virtual tick clock (1 tick = one pool decode
step; ``Request.arrival_time`` is in ticks) for deterministic scheduling
tests, and an injectable wall clock for latency. ``latency_s`` measures
admission → the request's own last token, so late-admitted requests are
not charged for time they spent unqueued or for earlier occupants' work.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.database import shape_bucket
from ..core.runtime import TunedRuntime
from ..distributed import sharding as shd
from ..models import lm
from ..models.transformer import RunConfig
from ..obs.collect import current_collector as _obs_collector
from ..obs.trace import span as _obs_span


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [len] int32
    max_new_tokens: int = 16
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    arrival_time: float = 0.0       # engine ticks (decode steps); 0 = already here
    # filled by the engine:
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0          # admission -> THIS request's last token (wall)
    latency_steps: int = 0          # admission -> last token, in decode ticks
    queue_steps: int = 0            # arrival -> admission, in decode ticks
    admitted_step: int = -1
    finished_step: int = -1
    slot: int = -1
    # admission backpressure (structured shed response): submit() refused
    # this request because the engine queue was at EngineConfig.max_queue.
    shed: bool = False
    shed_reason: str = ""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8              # slot-pool width (= the one decode jit key)
    max_seq: int = 256              # per-slot cache capacity (prefill + decode)
    min_prefill_bucket: int = 16    # smallest admission-prefill seq bucket
    max_queue: int = 0              # bounded admission queue (0 = unbounded):
    #                                 past this depth submit() sheds instead of
    #                                 queueing — backpressure, not OOM


def _sample_one(logits_row: np.ndarray, req: Request, rng) -> int:
    if req.temperature <= 0:
        return int(np.argmax(logits_row))
    z = logits_row / req.temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))


@dataclasses.dataclass
class _Slot:
    req: Request
    rng: Any
    cur: int                        # next token to feed
    pos: int                        # absolute position `cur` will occupy
    max_new: int
    emitted: List[int]
    t_admit: float


class ServingEngine:
    """Slot-pool continuous-batching engine (see module docstring)."""

    def __init__(
        self,
        cfg: ArchConfig,
        run: RunConfig,
        params,
        mesh: jax.sharding.Mesh,
        layout: shd.Layout,
        ecfg: EngineConfig = EngineConfig(),
        clock: Callable[[], float] = time.perf_counter,
        runtime: Optional[TunedRuntime] = None,
    ):
        if cfg.frontend is not None:
            raise NotImplementedError(
                "the engine serves token-in/token-out archs; frontend "
                "archs need an embedding service in front"
            )
        self.cfg, self.run, self.ecfg = cfg, run, ecfg
        self.params = params
        self.mesh, self.layout = mesh, layout
        self.clock = clock
        # Engine-pinned dispatch runtime: every prefill/decode trace (and
        # warmup resolution) runs under this scope, so the engine's db/mode
        # and telemetry are isolated from other engines and from tests.
        # None = legacy behavior: dispatch reads whatever runtime is ambient
        # at serve time.
        self.runtime = runtime
        self._has_ssm = any(
            spec.mixer != "attn" for seg in cfg.segments() for spec in seg.pattern
        )
        self._prefill = jax.jit(
            lambda p, toks, L: lm.prefill(
                p, {"tokens": toks}, cfg, run, cache_len=ecfg.max_seq, true_len=L
            )
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, run)
        )
        self._insert = jax.jit(lm.insert_cache)
        self._caches = lm.init_cache(cfg, ecfg.max_batch, ecfg.max_seq)
        self._slots: List[Optional[_Slot]] = [None] * ecfg.max_batch
        self.queue: List[Request] = []
        self._order = 0
        # Graceful degradation: a fault escaping a prefill/decode call (one
        # the dispatch guard could not absorb — e.g. an unguarded runtime, or
        # a failure outside any dispatch site) flips the engine onto separate
        # reference-path jits; sticky until reset_degraded(). Lazy: the
        # fallback jits and their pinned reference-mode runtime are only
        # built on first fault.
        self.degraded = False
        self._ref_rt: Optional[TunedRuntime] = None
        self._prefill_ref = None
        self._decode_ref = None
        self.reset_stats()

    # ----------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.stats: Dict[str, int] = {
            "decode_steps": 0,        # pool decode invocations (= ticks)
            "prefill_calls": 0,
            "prefill_tokens": 0,      # padded (bucketed) prefill tokens
            "slot_steps_active": 0,   # slot·steps that produced a kept token
            "slot_steps_idle": 0,     # slot·steps burned on empty slots
            "tokens_out": 0,
            "requests_shed": 0,       # submissions refused at max_queue
            "degraded_calls": 0,      # prefill/decode calls served by the
            #                           reference fallback after a fault
        }

    def _scope(self):
        """The engine's runtime scope (no-op when no runtime is pinned)."""
        return self.runtime if self.runtime is not None else contextlib.nullcontext()

    # --------------------------------------------------------- degraded path
    def reset_degraded(self) -> None:
        """Re-arm the kernel path after an operator fixed the fault."""
        self.degraded = False

    def _note_degraded(self, site: str, exc: Exception) -> None:
        self.degraded = True
        col = _obs_collector()
        if col.enabled:
            col.counter("serve.degraded", site=site)
        # warn_once fires even with metrics off — a silently-degraded engine
        # is the hazard class this plane exists for.
        col.warn_once(
            "serve.degraded", key=site, site=site,
            error=f"{type(exc).__name__}: {exc}",
        )

    def _ref_scope(self):
        """Pinned reference-mode runtime for the fallback jits (lazy).

        jit specializes on shapes, not on ambient contextvars — the fallback
        needs its OWN jit objects, traced under a reference-mode scope, or it
        would reuse the kernel-path executable and re-fault identically.
        """
        if self._ref_rt is None:
            with self._scope():
                # Construction-time inheritance picks up the engine runtime's
                # db/platform; only the mode flips.
                self._ref_rt = TunedRuntime(mode="reference", name="engine-degraded")
        return self._ref_rt

    def _run_prefill(self, toks, L):
        if not self.degraded:
            try:
                with self._scope(), _obs_span("serve.admit.prefill"):
                    return self._prefill(self.params, toks, L)
            except Exception as e:  # fault mid-admission: demote, complete
                self._note_degraded("prefill", e)
        self.stats["degraded_calls"] += 1
        if self._prefill_ref is None:
            cfg, run, ecfg = self.cfg, self.run, self.ecfg
            self._prefill_ref = jax.jit(
                lambda p, t, n: lm.prefill(
                    p, {"tokens": t}, cfg, run, cache_len=ecfg.max_seq, true_len=n
                )
            )
        with self._scope(), self._ref_scope():
            return self._prefill_ref(self.params, toks, L)

    def _run_decode(self, tokens, pos):
        if not self.degraded:
            try:
                with self._scope():
                    return self._decode(self.params, tokens, self._caches, pos)
            except Exception as e:  # fault mid-tick: demote, complete the tick
                self._note_degraded("decode", e)
        self.stats["degraded_calls"] += 1
        if self._decode_ref is None:
            cfg, run = self.cfg, self.run
            self._decode_ref = jax.jit(
                lambda p, t, c, q: lm.decode_step(p, t, c, q, cfg, run)
            )
        # self._caches is only reassigned from a call that RETURNED, so the
        # retry reruns the identical inputs — completed requests stay
        # bit-identical to a fault-free run (the equivalence contract).
        with self._scope(), self._ref_scope():
            return self._decode_ref(self.params, tokens, self._caches, pos)

    # ----------------------------------------------------------------- queue
    def submit(self, req: Request) -> bool:
        """Queue a request; returns False (with a structured shed response
        on the request) when admission backpressure refuses it."""
        L = len(req.prompt)
        if not 1 <= L < self.ecfg.max_seq:
            raise ValueError(
                f"prompt length {L} not in [1, max_seq={self.ecfg.max_seq})"
            )
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.ecfg.max_queue > 0 and len(self.queue) >= self.ecfg.max_queue:
            req.shed = True
            req.shed_reason = (
                f"queue_full: depth {len(self.queue)} at "
                f"max_queue={self.ecfg.max_queue}"
            )
            self.stats["requests_shed"] += 1
            col = _obs_collector()
            if col.enabled:
                col.counter("serve.shed", reason="queue_full")
            return False
        req._order = self._order          # submission order, for serve()'s return
        self._order += 1
        self.queue.append(req)
        return True

    def _bucket_len(self, prompt_len: int) -> int:
        if self._has_ssm:
            # SSM state integrates every input token — pad tokens cannot be
            # masked out after the fact, so SSM archs prefill exact-length.
            return prompt_len
        b = max(self.ecfg.min_prefill_bucket, shape_bucket((prompt_len,))[0])
        return min(b, self.ecfg.max_seq)

    # ------------------------------------------------------------- admission
    def _admit(self, req: Request, slot: int, now: int, done: List[Request]) -> None:
        t_wall = time.perf_counter()
        L = len(req.prompt)
        sb = self._bucket_len(L)
        toks = np.zeros((1, sb), np.int32)
        toks[0, :L] = req.prompt
        with _obs_span("serve.admit", slot=slot, prompt_len=L):
            logits, cache = self._run_prefill(
                jnp.asarray(toks), jnp.asarray(L, jnp.int32)
            )
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += sb

        req.admitted_step = now
        req.queue_steps = max(0, now - int(np.ceil(req.arrival_time)))
        req.slot = slot
        t_admit = self.clock()
        rng = np.random.default_rng(req.seed)
        first = _sample_one(np.asarray(logits, np.float32)[0], req, rng)
        col = _obs_collector()
        if col.enabled:
            # admission → first token: prefill + the first sample, wall time.
            col.observe("serve.admission_s", time.perf_counter() - t_wall)
            col.counter("serve.requests")
        max_new = min(req.max_new_tokens, self.ecfg.max_seq - L)
        state = _Slot(req=req, rng=rng, cur=first, pos=L, max_new=max_new,
                      emitted=[first], t_admit=t_admit)
        if len(state.emitted) >= max_new:
            self._finish(state, now)      # one-token request: never occupies
            done.append(req)
            return
        self._caches = self._insert(self._caches, cache, jnp.asarray(slot, jnp.int32))
        self._slots[slot] = state

    def _finish(self, state: _Slot, now: int) -> None:
        req = state.req
        req.output = np.asarray(state.emitted, np.int32)
        req.finished_step = now
        req.latency_steps = now - req.admitted_step
        req.latency_s = self.clock() - state.t_admit
        self.stats["tokens_out"] += len(state.emitted)
        col = _obs_collector()
        if col.enabled:
            n = len(state.emitted)
            col.observe("serve.latency_s", req.latency_s)
            if n:
                col.observe("serve.per_token_s", req.latency_s / n)
                col.counter("serve.tokens", n)

    # ----------------------------------------------------------------- serve
    def serve(self) -> List[Request]:
        """Run until the queue drains; return requests in submission order."""
        pending = sorted(self.queue, key=lambda r: r.arrival_time)
        self.queue = []
        done: List[Request] = []
        now = 0
        B = self.ecfg.max_batch
        col = _obs_collector()
        t_serve0 = time.perf_counter()
        tok0 = self.stats["tokens_out"]

        def active() -> int:
            return sum(s is not None for s in self._slots)

        while pending or active():
            if not active() and pending and pending[0].arrival_time > now:
                now = int(np.ceil(pending[0].arrival_time))
            # in-flight admission: fill every free slot with arrived traffic
            free = [i for i in range(B) if self._slots[i] is None]
            while free and pending and pending[0].arrival_time <= now:
                i = free.pop(0)
                self._admit(pending.pop(0), i, now, done)
                if self._slots[i] is None:   # finished at admission: reusable
                    free.append(i)
            if not active():
                continue

            tokens = np.zeros((B, 1), np.int32)
            pos = np.zeros((B,), np.int32)
            for i, s in enumerate(self._slots):
                if s is not None:
                    tokens[i, 0] = s.cur
                    pos[i] = s.pos
            logits, self._caches = self._run_decode(
                jnp.asarray(tokens), jnp.asarray(pos)
            )
            n_act = active()
            self.stats["decode_steps"] += 1
            self.stats["slot_steps_active"] += n_act
            self.stats["slot_steps_idle"] += B - n_act
            # Per-tick gauges go through the sampler: ticks are the engine's
            # highest-frequency site, and the last-written value is what a
            # gauge means anyway.
            if col.enabled and col.sample():
                col.gauge("serve.queue_depth", len(pending))
                col.gauge("serve.slots_active", n_act)
            now += 1
            logits_np = np.asarray(logits, np.float32)
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                nxt = _sample_one(logits_np[i], s.req, s.rng)
                s.emitted.append(nxt)
                s.pos += 1
                s.cur = nxt
                if len(s.emitted) >= s.max_new:
                    self._finish(s, now)
                    done.append(s.req)
                    self._slots[i] = None     # freed: next arrival admits here
        if col.enabled:
            wall = time.perf_counter() - t_serve0
            if wall > 0:
                col.gauge(
                    "serve.tokens_per_s",
                    (self.stats["tokens_out"] - tok0) / wall,
                )
        return sorted(done, key=lambda r: r._order)

    # ---------------------------------------------------------------- warmup
    def serving_buckets(self) -> List[tuple]:
        """The (batch, seq-bucket) jit/db keys this engine can hit."""
        from ..campaign.planner import serving_buckets

        return serving_buckets(self.ecfg.max_batch, self.ecfg.max_seq,
                               min_seq=self.ecfg.min_prefill_bucket)

    def warmup(
        self,
        db=None,
        allow_tune: bool = False,
        install: bool = True,
        max_tokens: int = 65536,
        **tune_kwargs,
    ) -> Dict[str, Dict]:
        """Pre-resolve kernel configs for every slot-pool bucket this engine serves.

        This is the deployment end of a tuning campaign: pair the generic
        engine with a campaign-exported per-platform database and every
        admission-prefill (1, seq-bucket) and decode-pool (max_batch,) key
        the engine will jit resolves its kernel configs up front through the
        engine's dispatch runtime — its resolution cache is hot and its
        telemetry records which tier (exact / cover / heuristic / ...)
        serves each bucket, so no request pays resolution or heuristic-miss
        cost mid-flight. With `allow_tune=True` missing buckets are tuned on
        the spot instead (an online mini-campaign for this engine only).

        Database plumbing: with an engine-pinned runtime, a passed `db` is
        pinned on that runtime (scoped — nothing global is touched, and
        `install` is ignored). Without one, the legacy behavior holds:
        `install=True` makes `db` the process-wide default, because serve-
        time dispatch then reads the ambient runtime, whose database is
        ``default_db()`` — warming one database while serving reads another
        would silently waste the artifact.

        Returns {db_key: resolved config} for observability (``None`` for a
        bucket a custom policy pipeline routed to reference execution).
        """
        from ..core.annotate import get_tunable
        from ..core.database import default_db, set_default_db
        from ..core.runtime import current_runtime
        from ..core.platform import detect_platform
        from ..campaign.planner import plan_serving_jobs
        from ..campaign.runner import materialize_args

        rt = self.runtime
        if rt is not None:
            if db is not None and db is not rt.db:
                # Buckets resolved under the previous database are stale;
                # the db-identity check in resolve() would skip them anyway,
                # but dropping them keeps cache_size honest.
                rt.db = db
                rt.clear_cache()
        else:
            if db is not None and install:
                set_default_db(db)
            # Serve-time dispatch will read the ambient runtime; warm that
            # same runtime so its resolution cache actually gets hit.
            rt = current_runtime()
            if db is not None:
                effective = rt.db if rt.db is not None else default_db()
                if effective is not db:
                    # install=False, or warmup invoked inside a scope pinned
                    # to some other database: the caller asked for *this*
                    # artifact, so resolve against it on an ephemeral scoped
                    # runtime (serve-time caching is forfeit by construction
                    # here — the served db is a different one).
                    rt = TunedRuntime(db=db, name="warmup")

        platform = detect_platform().name
        jobs = plan_serving_jobs(
            self.cfg, self.ecfg.max_batch, self.ecfg.max_seq,
            max_tokens=max_tokens,
        )
        if allow_tune:
            # Cached resolutions would shadow TuneNow for already-seen
            # buckets; the caller asked for an online mini-campaign.
            rt.clear_cache()
        resolved: Dict[str, Dict] = {}
        for job in jobs:
            key = job.db_key(platform)
            if key in resolved:
                continue
            tunable = get_tunable(job.kernel)
            args = materialize_args(job)
            # Per-call permission grant: never mutates the runtime, which
            # other serving threads may be dispatching through right now.
            res = rt.resolve(
                tunable, args, key_extra=job.key_extra,
                allow_tune=allow_tune or None,
                tune_kwargs=tune_kwargs or None,
            )
            resolved[key] = res.config
        return resolved


class LockStepEngine:
    """The old static batcher, kept as the regression baseline.

    Packs up to ``max_batch`` queued requests, left-pads to a shared prefill
    length, then decodes lock-step until the *longest* member finishes; new
    traffic waits for the whole batch. ``stats["decode_steps"]`` counts the
    same unit as the continuous engine, so the two are directly comparable.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        run: RunConfig,
        params,
        mesh: jax.sharding.Mesh,
        layout: shd.Layout,
        ecfg: EngineConfig = EngineConfig(),
        clock: Callable[[], float] = time.perf_counter,
    ):
        if cfg.frontend is not None:
            raise NotImplementedError("token-in/token-out archs only")
        self.cfg, self.run, self.ecfg = cfg, run, ecfg
        self.params = params
        self.mesh, self.layout = mesh, layout
        self.clock = clock
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, run, cache_len=ecfg.max_seq)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, run)
        )
        self.queue: List[Request] = []
        self.stats: Dict[str, int] = {"decode_steps": 0, "tokens_out": 0}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run_batch(self, reqs: List[Request]) -> List[Request]:
        t0 = self.clock()
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        max_new = min(max(r.max_new_tokens for r in reqs), self.ecfg.max_seq - plen)

        outs = np.zeros((B, max_new), np.int32)
        rngs = [np.random.default_rng(r.seed) for r in reqs]
        cur = np.asarray(
            [_sample_one(np.asarray(logits, np.float32)[i], r, rngs[i])
             for i, r in enumerate(reqs)], np.int32)
        done_at = np.zeros((B,), np.float64)
        for step in range(max_new):
            outs[:, step] = cur
            t_now = self.clock() - t0
            for i, r in enumerate(reqs):
                if r.max_new_tokens == step + 1:
                    done_at[i] = t_now
            pos = jnp.asarray(plen + step, jnp.int32)
            logits, caches = self._decode(
                self.params, jnp.asarray(cur)[:, None], caches, pos
            )
            self.stats["decode_steps"] += 1
            cur = np.asarray(
                [_sample_one(np.asarray(logits, np.float32)[i], r, rngs[i])
                 for i, r in enumerate(reqs)], np.int32)

        dt = self.clock() - t0
        for i, r in enumerate(reqs):
            r.output = outs[i, : r.max_new_tokens]
            r.latency_s = float(done_at[i]) if done_at[i] > 0 else dt
            self.stats["tokens_out"] += len(r.output)
        return reqs

    def serve(self) -> List[Request]:
        """Drain the queue in max_batch groups (arrival times ignored)."""
        done: List[Request] = []
        while self.queue:
            batch, self.queue = (
                self.queue[: self.ecfg.max_batch],
                self.queue[self.ecfg.max_batch:],
            )
            done.extend(self.run_batch(batch))
        return done
