"""Batched serving engine: prefill + decode with windowed/SSM caches.

A deliberately small continuous-batching core:
  * requests queue up; the engine packs up to `max_batch` of them,
    left-pads to a shared prefill length (so every sequence's last prompt
    token sits at the same position and decode starts aligned), prefills
    once, then decodes lock-step until every sequence hits its stop length;
  * per-layer caches come from the model (`lm.cache_specs` layouts): rolling
    windows for SWA layers, O(1) states for SSM layers, ring-less full
    caches for global attention;
  * both steps are jitted once per (batch, seq-bucket) — the tuning
    database's shape-bucketing logic is reused for the serving buckets, so
    a production deployment warms exactly the buckets it serves:
    :meth:`ServingEngine.warmup` resolves (or tunes) the kernel configs for
    every bucket this engine can jit, straight from a campaign-exported
    per-platform database.

Sampling: greedy or temperature; seeded per request for reproducibility.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..distributed import sharding as shd
from ..models import lm
from ..models.transformer import RunConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [len] int32
    max_new_tokens: int = 16
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0          # batch start -> THIS request's last token
    batch_latency_s: float = 0.0    # whole-batch wall time (shared by the batch)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256              # cache capacity (prefill + decode)


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        run: RunConfig,
        params,
        mesh: jax.sharding.Mesh,
        layout: shd.Layout,
        ecfg: EngineConfig = EngineConfig(),
    ):
        if cfg.frontend is not None:
            raise NotImplementedError(
                "the toy engine serves token-in/token-out archs; frontend "
                "archs need an embedding service in front"
            )
        self.cfg, self.run, self.ecfg = cfg, run, ecfg
        self.params = params
        self.mesh, self.layout = mesh, layout
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, run, cache_len=ecfg.max_seq)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, run)
        )
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------ batch
    def _pack(self, reqs: List[Request]):
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        return jnp.asarray(toks), plen

    def run_batch(self, reqs: List[Request]) -> List[Request]:
        t0 = time.perf_counter()
        cfg, ecfg = self.cfg, self.ecfg
        tokens, plen = self._pack(reqs)
        B = tokens.shape[0]
        logits, caches = self._prefill(self.params, {"tokens": tokens})
        max_new = max(r.max_new_tokens for r in reqs)
        max_new = min(max_new, ecfg.max_seq - plen)

        outs = np.zeros((B, max_new), np.int32)
        rngs = [np.random.default_rng(r.seed) for r in reqs]
        cur = self._sample(logits, reqs, rngs)
        # Lock-step decode still finishes short requests early in wall-clock
        # terms: a request's latency is the time to ITS last token, not the
        # batch's (the whole-batch time is kept separately for throughput
        # accounting — charging it to every request overstates p50 latency).
        done_at = np.zeros((B,), np.float64)
        for step in range(max_new):
            outs[:, step] = np.asarray(cur)
            now = time.perf_counter() - t0
            for i, r in enumerate(reqs):
                if r.max_new_tokens == step + 1:
                    done_at[i] = now
            pos = jnp.asarray(plen + step, jnp.int32)
            logits, caches = self._decode(
                self.params, jnp.asarray(cur)[:, None], caches, pos
            )
            cur = self._sample(logits, reqs, rngs)

        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.output = outs[i, : r.max_new_tokens]
            r.latency_s = float(done_at[i]) if done_at[i] > 0 else dt
            r.batch_latency_s = dt
        return reqs

    def _sample(self, logits, reqs, rngs) -> np.ndarray:
        logits = np.asarray(logits, np.float32)  # [B, vocab]
        out = np.zeros((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            if r.temperature <= 0:
                out[i] = int(np.argmax(logits[i]))
            else:
                z = logits[i] / r.temperature
                z = z - z.max()
                p = np.exp(z)
                p /= p.sum()
                out[i] = int(rngs[i].choice(len(p), p=p))
        return out

    # ---------------------------------------------------------------- warmup
    def serving_buckets(self) -> List[tuple]:
        """The (batch, seq-bucket) jit keys this engine can hit."""
        from ..campaign.planner import serving_buckets

        return serving_buckets(self.ecfg.max_batch, self.ecfg.max_seq)

    def warmup(
        self,
        db=None,
        allow_tune: bool = False,
        install: bool = True,
        max_tokens: int = 65536,
        **tune_kwargs,
    ) -> Dict[str, Dict]:
        """Pre-resolve kernel configs for every bucket this engine serves.

        This is the deployment end of a tuning campaign: pair the generic
        engine with a campaign-exported per-platform database and every
        (batch, seq-bucket) the engine will jit resolves its kernel configs
        up front — exact record, else cover-set entry, else heuristic — so
        no request ever pays tuning or heuristic-miss cost mid-flight. With
        `allow_tune=True` missing buckets are tuned on the spot instead
        (an online mini-campaign for this engine only).

        `install=True` (default) makes a passed `db` the process-wide
        default, because the kernels/ops dispatch the model executes under
        `_prefill`/`_decode` resolves through ``default_db()`` — warming one
        database while serving reads another would silently waste the
        artifact.

        Returns {db_key: resolved config} for observability.
        """
        from ..core.annotate import get_tunable
        from ..core.database import default_db, set_default_db
        from ..core.tuner import tune_or_lookup
        from ..core.platform import detect_platform
        from ..campaign.planner import plan_serving_jobs
        from ..campaign.runner import materialize_args

        if db is None:
            db = default_db()
        elif install:
            set_default_db(db)
        platform = detect_platform().name
        jobs = plan_serving_jobs(
            self.cfg, self.ecfg.max_batch, self.ecfg.max_seq,
            max_tokens=max_tokens,
        )
        resolved: Dict[str, Dict] = {}
        for job in jobs:
            key = job.db_key(platform)
            if key in resolved:
                continue
            tunable = get_tunable(job.kernel)
            args = materialize_args(job)
            resolved[key] = tune_or_lookup(
                tunable, args, db=db, allow_tune=allow_tune,
                key_extra=job.key_extra, **tune_kwargs,
            )
        return resolved

    def serve(self) -> List[Request]:
        """Drain the queue in max_batch groups."""
        done: List[Request] = []
        while self.queue:
            batch, self.queue = (
                self.queue[: self.ecfg.max_batch],
                self.queue[self.ecfg.max_batch:],
            )
            done.extend(self.run_batch(batch))
        return done
