from .engine import EngineConfig, LockStepEngine, Request, ServingEngine

__all__ = ["EngineConfig", "LockStepEngine", "Request", "ServingEngine"]
