from .engine import EngineConfig, Request, ServingEngine
