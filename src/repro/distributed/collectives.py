"""Distributed-optimization tricks: gradient compression + manual collectives.

Gradient compression targets the cross-pod ("pod" axis) all-reduce, which
rides DCN, not ICI — its bytes are the multi-pod scaling tax. Two schemes:

  * bf16: cast grads before reduction (2× bytes). Lossy but empirically safe
    for LM training at these scales.
  * int8 + error feedback: per-tensor symmetric quantization; the residual
    (g - dequant(quant(g))) is carried in optimizer-side state and added to
    the next step's gradient. 1-bit-SGD-style EF guarantees the *accumulated*
    gradient is unbiased over time; test_collectives proves convergence on a
    quadratic matches fp32 within tolerance.

`ring_all_reduce` is a shard_map/ppermute reference implementation of the
bidirectional ring schedule — the 'collective schedule' variant the layout
tuner can select against XLA's built-in all-reduce (and the unit test proves
it numerically identical to psum).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# Gradient compression with error feedback
# ---------------------------------------------------------------------------


def ef_init(params) -> Any:
    """Zero error-feedback residuals, shaped like params (fp32)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state, mode: str = "none"):
    """Apply compression (simulating the wire format of the cross-pod
    all-reduce) + error feedback. Returns (compressed_grads, new_ef_state).

    In a real deployment the quant/dequant brackets the DCN all-reduce; under
    pjit the reduction is compiler-inserted, so we compress the gradient
    *contribution* — same numerics, and the wire-byte savings are reported in
    the roofline collective term by the corresponding layout variant.
    """
    if mode == "none":
        return grads, ef_state
    if mode == "bf16":
        out = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
        )
        return out, ef_state
    if mode == "int8_ef":

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = _quant_int8(g32)
            deq = _dequant_int8(q, s)
            return deq, g32 - deq

        g_flat, treedef = jax.tree_util.tree_flatten(grads)
        e_flat = jax.tree_util.tree_leaves(ef_state)
        pairs = [one(g, e) for g, e in zip(g_flat, e_flat)]
        out = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
        new_ef = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
        return out, new_ef
    raise ValueError(f"unknown compression mode {mode!r}")


# ---------------------------------------------------------------------------
# Manual ring all-reduce (collective-schedule variant)
# ---------------------------------------------------------------------------


def ring_all_reduce(x: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """Bidirectional-ring all-reduce via explicit ppermute hops.

    Semantics: `x` is a global [n, d] array sharded along dim0 by `axis`
    (row i = device i's contribution). Returns the same global shape where
    EVERY row equals the elementwise sum — i.e. an all-reduce whose schedule
    we own: n-1 reduce-scatter hops + n-1 all-gather hops, each moving d/n
    elements per device. Total wire bytes per device = 2·d·(n-1)/n — the
    bandwidth-optimal ring, vs XLA's opaque choice. Exists as a searchable
    collective-schedule variant and as the overlap template (each hop is a
    fori_loop step that XLA may interleave with independent compute).

    test_collectives proves it equals psum exactly on an 8-device host mesh.
    """
    n = mesh.shape[axis]
    if n == 1:
        return x
    from jax.experimental.shard_map import shard_map

    d = x.shape[-1]
    pad = (-d) % n

    def body(v):
        # v: local row [1, d_padded] — split into n ring chunks
        chunks = v.reshape(n, -1)
        me = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % n) for i in range(n)]

        def rs(step, acc):
            send_idx = (me - step) % n
            buf = jax.lax.ppermute(acc[send_idx], axis, perm)
            recv_idx = (me - step - 1) % n
            return acc.at[recv_idx].add(buf)

        acc = jax.lax.fori_loop(0, n - 1, rs, chunks)
        # fully-reduced chunk now lives at index (me + 1) % n

        def ag(step, acc):
            send_idx = (me + 1 - step) % n
            buf = jax.lax.ppermute(acc[send_idx], axis, perm)
            recv_idx = (me - step) % n
            return acc.at[recv_idx].set(buf)

        acc = jax.lax.fori_loop(0, n - 1, ag, acc)
        return acc.reshape(1, -1)

    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    fn = shard_map(body, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis, None))
    out = fn(xp)
    return out[:, :d] if pad else out
