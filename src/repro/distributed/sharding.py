"""Divisibility-aware sharding solver: logical axes → mesh axes.

Model code annotates every parameter dim with a *logical* name ("vocab",
"ff", "heads", ...). This module decides which *mesh* axis shards which dim,
given a :class:`Layout`. The assignment is greedy by rule priority with two
hard checks: (a) the dim size must divide the mesh-axis size, (b) a mesh
axis may shard at most one dim per tensor.

Why a solver instead of fixed Megatron rules: the assigned archs have head
counts (24, 14, 56, 8) that do NOT divide a 16-way tensor axis, expert
counts (8) smaller than it, and vocab/ff dims that always divide. Fixed
rules would simply fail; the solver downgrades gracefully (shard ff instead
of experts, replicate heads and lean on batch sharding, ...) and the
*choice set* is exposed to the autotuner as the layout search space — the
paper's "performance directive" applied to distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Layout:
    """One point in the distribution-layout search space."""

    tensor_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)       # batch axes (pod prepended if present)
    fsdp: bool = False            # additionally shard params' d_model over data
    shard_experts: bool = True    # prefer expert-parallel over expert-ff TP
    scan_layers: bool = True      # (informational; model always scans)
    # Logical-unit counts: ("heads", 24) means the "heads" dim is 24 physical
    # units (the fused dim is heads·head_dim) — sharding must not split a
    # unit, so divisibility is checked against the COUNT, not the dim size.
    # Splitting mid-head forces an activation reshard at every [b,s,h,hd]
    # reshape, which the baseline dry-run showed costs ~100× the step's
    # useful collective traffic.
    counts: Tuple[Tuple[str, int], ...] = ()
    head_aware: bool = True       # False reproduces the naive baseline
    name: str = "default"

    def count_of(self, logical: str) -> Optional[int]:
        for k, v in self.counts:
            if k == logical:
                return v
        return None


# priority: lower = assigned first. Only these names are ever sharded.
_TENSOR_RULES: Dict[str, int] = {
    "vocab": 0,
    "experts": 1,
    "ff": 2,
    "ff2": 3,
    "heads": 4,
    "kv_heads": 5,
}
_FSDP_NAME = "d_model"


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def spec_for_dims(
    dims: Sequence[str],
    shape: Sequence[int],
    mesh: Mesh,
    layout: Layout,
) -> P:
    """PartitionSpec for one tensor given its logical dim names."""
    t_axis = layout.tensor_axis
    t_size = axis_size(mesh, t_axis) if t_axis in mesh.axis_names else 1
    d_axes = tuple(a for a in layout.data_axes if a in mesh.axis_names)
    d_size = 1
    for a in d_axes:
        d_size *= axis_size(mesh, a)

    assignment: Dict[int, Any] = {}
    used_tensor = False

    def unit_ok(name: str, size: int) -> bool:
        if size % t_size:
            return False
        if layout.head_aware:
            c = layout.count_of(name)
            if c is not None and c % t_size:
                return False
        return True

    # 1. tensor-parallel dim: best-priority shardable logical name
    candidates = [
        (prio, i)
        for i, name in enumerate(dims)
        for prio in [_TENSOR_RULES.get(name)]
        if prio is not None and t_size > 1 and unit_ok(name, shape[i])
    ]
    if not layout.shard_experts:
        candidates = [(p, i) for (p, i) in candidates if dims[i] != "experts"]
    if candidates:
        _, idx = min(candidates)
        assignment[idx] = t_axis
        used_tensor = True

    # 2. FSDP dim: shard d_model over the data axes (XLA all-gathers on use)
    if layout.fsdp and d_size > 1:
        for i, name in enumerate(dims):
            if i in assignment or name != _FSDP_NAME:
                continue
            if shape[i] % d_size == 0:
                assignment[i] = d_axes if len(d_axes) > 1 else d_axes[0]
                break

    if not assignment:
        return P()
    parts = [assignment.get(i) for i in range(len(dims))]
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(axes_tree, shapes_tree, mesh: Mesh, layout: Layout):
    """NamedSharding tree for a params pytree (axes_tree gives dim names)."""
    is_names = lambda x: isinstance(x, tuple) and all(isinstance(s, str) for s in x)

    def one(ax, leaf):
        return NamedSharding(mesh, spec_for_dims(ax, leaf.shape, mesh, layout))

    return jax.tree_util.tree_map(one, axes_tree, shapes_tree, is_leaf=is_names)


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------


def _divisible_data_axes(
    sizes: Dict[str, int], layout: Layout, batch_size: int
) -> Tuple[Tuple[str, ...], int]:
    """Greedy data-axis selection for a batch-like dim: which of the
    data-parallel axes (pod first, then the layout's data axes) shard a dim
    of `batch_size`, and their combined degree.

    This single rule backs both :func:`batch_spec` (the sharding the trainer
    actually requests) and :func:`local_shard_shape` (the per-device shape
    the tuning database keys on) — keeping them one function is what makes
    campaign records match training dispatch.
    """
    seen, use = set(), []
    prod = 1
    for a in ("pod",) + tuple(layout.data_axes):
        if a in seen or a not in sizes:
            continue
        seen.add(a)
        s = int(sizes[a])
        if s > 0 and batch_size % (prod * s) == 0:
            use.append(a)
            prod *= s
    return tuple(use), prod


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_parallel_degree(
    sizes: Dict[str, int], layout: Layout, batch_size: int
) -> int:
    """How many ways a batch-like dim of `batch_size` is split on this mesh."""
    return _divisible_data_axes(sizes, layout, batch_size)[1]


def local_shard_shape(
    shape: Sequence[int], sizes: Dict[str, int], layout: Layout
) -> Tuple[int, ...]:
    """The per-device shape of a batch-leading global array under `layout`.

    Only the leading (batch/token) dim is divided — mirror of
    :func:`batch_spec`: activations inside a jit-sharded trace carry global
    shapes, but each device executes the local shard, and that is the shape
    a tuning campaign measures. Dims the mesh cannot divide stay global.
    """
    shape = tuple(int(d) for d in shape)
    if not shape:
        return shape
    dp = data_parallel_degree(sizes, layout, shape[0])
    if dp <= 1:
        return shape
    return (shape[0] // dp,) + shape[1:]


def localize_shapes(
    shapes: Sequence[Sequence[int]],
    batch_arg_indices: Optional[Sequence[int]] = None,
    batch_arg_dims: Optional[Dict[int, int]] = None,
) -> Tuple[Tuple[int, ...], ...]:
    """Localize batch-sharded shapes by the *ambient* data-parallel degree.

    This is the runtime's local-shape keying hook (see
    ``repro.core.tuner._args_key``). The degree comes from the enclosing
    :func:`mesh_context`'s explicit ``dp_degree`` — computed ONCE by whoever
    owns the step's input sharding (the Trainer: from its batch dim), never
    re-derived from an individual argument's leading dim. Per-arg derivation
    would silently diverge from both the real sharding and the campaign
    planner whenever a data axis happens to divide a *flattened* activation
    dim (batch·seq) but not the batch itself. Outside a mesh context, or
    when the context carries no degree, this is the identity — unsharded
    database keys are unchanged.

    ``batch_arg_indices`` localizes the *leading* dim of those shapes — the
    forward convention. ``batch_arg_dims`` (``{shape index: dim index}``)
    localizes an arbitrary dim instead: backward dispatch sites need this
    because transposed operands carry the token dim elsewhere (matmul's
    dL/dw is ``x.T [d, T] @ ct [T, n]`` — the sharded dim of arg 0 is dim
    1). A dim the degree does not divide is left global (those rows are
    replicated, not sharded).
    """
    dp = _DP_CTX.get()
    if not dp or dp <= 1:
        return tuple(tuple(int(d) for d in s) for s in shapes)
    if batch_arg_dims is not None:
        dims = dict(batch_arg_dims)
    elif batch_arg_indices is not None:
        dims = {i: 0 for i in batch_arg_indices}
    else:
        dims = {i: 0 for i in range(len(shapes))}

    def one(i, s):
        s = tuple(int(d) for d in s)
        dim = dims.get(i)
        if dim is not None and len(s) > dim and s[dim] % dp == 0:
            return s[:dim] + (s[dim] // dp,) + s[dim + 1:]
        return s

    return tuple(one(i, s) for i, s in enumerate(shapes))


def batch_spec(mesh: Mesh, layout: Layout, batch_size: int) -> P:
    """Shard the batch dim over every data-ish axis that divides it."""
    use, _ = _divisible_data_axes(mesh_axis_sizes(mesh), layout, batch_size)
    if not use:
        return P()
    return P(use if len(use) > 1 else use[0])


def data_specs(batch_tree, mesh: Mesh, layout: Layout):
    """Shardings for a training/serving batch: dim0 = batch."""

    def one(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_spec(mesh, layout, leaf.shape[0]))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh, layout: Layout):
    """Shardings for decode caches.

    Leaves are stacked (layers, batch, ...). Strategy:
      dim0 (layers) replicated; dim1 (batch) over data axes if divisible;
      then the largest remaining dim divisible by the tensor axis gets it
      (kv-heads when divisible, else cache-length / feature dims — for B=1
      long-context cells this lands on the sequence dim, i.e. sequence
      parallelism of the KV cache).
    """
    t_axis = layout.tensor_axis
    t_size = axis_size(mesh, t_axis) if t_axis in mesh.axis_names else 1
    d_axes = tuple(a for a in ("pod",) + tuple(layout.data_axes) if a in mesh.axis_names)

    def one(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim < 2:
            return NamedSharding(mesh, P())
        parts: list = [None] * leaf.ndim
        # batch over data axes
        bs = leaf.shape[1]
        use, prod, seen = [], 1, set()
        for a in d_axes:
            if a in seen:
                continue
            seen.add(a)
            s = axis_size(mesh, a)
            if bs % (prod * s) == 0:
                use.append(a)
                prod *= s
        if use:
            parts[1] = tuple(use) if len(use) > 1 else use[0]
        leftover_data = [a for a in d_axes if a not in use]
        # tensor axis on the largest divisible remaining dim (prefer last dims)
        if t_size > 1:
            best = None
            for i in range(leaf.ndim - 1, 1, -1):
                if leaf.shape[i] % t_size == 0 and leaf.shape[i] >= t_size:
                    if best is None or leaf.shape[i] > leaf.shape[best]:
                        best = i
            if best is not None:
                parts[best] = t_axis
        # unsharded batch (B=1): put leftover data axes on the longest dim
        if leftover_data and parts[1] is None and leaf.ndim >= 3:
            d_size = 1
            for a in leftover_data:
                d_size *= axis_size(mesh, a)
            cand = [
                i for i in range(2, leaf.ndim)
                if parts[i] is None and leaf.shape[i] % d_size == 0
                and leaf.shape[i] >= d_size
            ]
            if cand:
                i = max(cand, key=lambda j: leaf.shape[j])
                parts[i] = tuple(leftover_data) if len(leftover_data) > 1 else leftover_data[0]
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Ambient mesh/layout context — lets deep model code (e.g. MoE dispatch)
# place with_sharding_constraint hints without threading mesh objects
# through every layer signature. Set by build_cell / Trainer.
# ---------------------------------------------------------------------------

import contextlib
import contextvars

_MESH_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh_layout", default=None
)
# The step's data-parallel degree, for local-shape database keying. Kept in
# its own contextvar (not the mesh/layout tuple) so current_mesh_layout()
# keeps its two-tuple contract for constrain()/model code.
_DP_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dp_degree", default=None
)

# Whether the ambient dp_degree is an *approximation*: the scope owner
# computed it from a dim (the microbatch) whose divisibility differs from
# the full input batch XLA actually sharded, so local-shape keys may not
# match the true per-device shard. Carried separately so the keying layer
# (tuner._args_key) can emit a structured one-time warning naming the key.
_DP_APPROX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dp_approx", default=False
)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, layout: Layout, dp_degree: Optional[int] = None,
                 dp_approx: bool = False):
    """Ambient mesh/layout scope.

    `dp_degree` opts the scope into local-shape database keying (see
    :func:`localize_shapes`): it is the degree the step's *batch dim* is
    actually sharded at — the owner of the input shardings computes it via
    :func:`data_parallel_degree` on that batch dim (as the Trainer does).
    Left at None (the dry-run / lower_cell scopes), dispatch keys stay
    global.

    `dp_approx` flags that degree as approximate (see :data:`_DP_APPROX`):
    the Trainer sets it when the per-microbatch batch dim divides the mesh
    differently from the full input batch, so keys computed under this scope
    trigger the one-time ``dispatch.local_key_approx`` obs warning.
    """
    tok = _MESH_CTX.set((mesh, layout))
    tok_dp = _DP_CTX.set(dp_degree)
    tok_ap = _DP_APPROX.set(bool(dp_approx))
    try:
        yield
    finally:
        _MESH_CTX.reset(tok)
        _DP_CTX.reset(tok_dp)
        _DP_APPROX.reset(tok_ap)


def current_mesh_layout():
    return _MESH_CTX.get()


def current_dp_degree() -> Optional[int]:
    return _DP_CTX.get()


def current_dp_approx() -> bool:
    """Is the ambient local-shape keying degree an approximation?"""
    return bool(_DP_APPROX.get())


def constrain(x, *dims):
    """Best-effort sharding hint: dims are mesh-axis names or None.

    No-op outside a mesh_context, so model code stays runnable on the bare
    1-device host without ceremony.
    """
    ctx = _MESH_CTX.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    parts = [d if (d is None or d in mesh.axis_names) else None for d in dims]
    while parts and parts[-1] is None:
        parts.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts))
    )


def constrain_heads(x, n_units: int, unit_dim: int):
    """Sharding annotation for attention activations around the head
    split/merge reshapes: batch (dim 0) over the data axes that divide it,
    the head dim (`unit_dim`, carrying `n_units` head units) over the
    tensor axis when it divides the *unit count* (never mid-head), all
    other dims replicated.

    These anchors are what lets the SPMD partitioner walk the
    ``[b, s, h·hd] ⇄ [b, h, s, hd] ⇄ [b·h, s, hd]`` reshape chain around a
    flash-attention dispatch without an "involuntary full
    rematerialization" (an all-gather + reshard of the whole activation —
    the warning the sharded smoke step used to print). No-op outside a
    mesh_context.
    """
    ctx = _MESH_CTX.get()
    if ctx is None:
        return x
    mesh, layout = ctx
    sizes = mesh_axis_sizes(mesh)
    parts: list = [None] * x.ndim
    use, _ = _divisible_data_axes(sizes, layout, int(x.shape[0]))
    if use:
        parts[0] = tuple(use) if len(use) > 1 else use[0]
    t = layout.tensor_axis
    t_size = int(sizes.get(t, 1))
    if (t_size > 1 and n_units % t_size == 0
            and int(x.shape[unit_dim]) % t_size == 0):
        parts[unit_dim] = t
    while parts and parts[-1] is None:
        parts.pop()
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))
