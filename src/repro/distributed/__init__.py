from .sharding import Layout, batch_spec, cache_shardings, data_specs, param_shardings, spec_for_dims
