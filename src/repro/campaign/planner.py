"""Workload planner: real scenarios → concrete tuning jobs.

A tuning job is (kernel × argument shapes × dtype × key_extra) — exactly the
granularity of one database record. Jobs come from two scenario families:

* **train/prefill cells**: for each registered :class:`ArchConfig` and each
  requested :class:`ShapeSpec`, every kernel call site the model step makes
  (qkv/o projections, FFN matmuls, RMSNorm rows, the fused loss, causal
  attention) becomes one job, weighted by how many times the site executes
  per step (layer counts from ``cfg.segments()``).
* **serving buckets**: the :class:`~repro.serving.engine.ServingEngine` jits
  one prefill/decode pair per (batch, seq-bucket); the planner enumerates
  those buckets — powers of two up to (max_batch, max_seq), mirroring
  ``database.shape_bucket`` — so a deployment can pre-tune exactly the
  buckets it will serve (``ServingEngine.warmup`` calls back into this).

The planner never evaluates anything: output is a deterministic, sorted job
list; dedup/priorities/budget are the scheduler's concern. Leading (token)
dims are capped by ``max_tokens`` so a campaign on a small host stays
materializable — shape bucketing makes the records equally valid for the
full-size step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..configs.base import SHAPES, ArchConfig, ShapeSpec, get_config
from ..core.database import make_key, shape_bucket
from ..core.tuner import promoted_dtype
from ..models.moe import expert_capacity

# Kernels a campaign tunes by default. `attn_chunks` is the model-level
# chunked-attention tunable (meaningful on any platform); the rest are the
# Pallas kernel sites behind runtime dispatch — the `*_bwd` entries are the
# tuned backward plane (gradient dispatch sites; matmul and expert_gemm
# gradients reuse their forward tunables with transposed operands, so they
# need no entry).
DEFAULT_KERNELS = (
    "matmul",
    "rmsnorm",
    "flash_attention",
    "softmax_xent",
    "attn_chunks",
    "ssm_scan",
    "ssm_update",
    "expert_gemm",
    "rmsnorm_bwd",
    "flash_attention_bwd",
    "softmax_xent_bwd",
    "ssm_scan_bwd",
    "ssm_update_bwd",
    # Fused-epilogue candidates: tuning these keys is what opts a site into
    # fusion — `runtime.fusion_wins` routes through the fused tunable only
    # where the database banked a record for the exact key.
    "matmul_bias_act",
    "rmsnorm_matmul",
)


def _register_tunables() -> None:
    """Populate the tunable registry (delegates to the runtime's one list)."""
    from ..core.runtime import ensure_registered

    ensure_registered()


@dataclasses.dataclass
class TuningJob:
    """One schedulable unit of tuning work + its manifest execution state."""

    kernel: str                                   # tunable registry name
    arg_shapes: Tuple[Tuple[int, ...], ...]       # concrete arrays to materialize
    arg_dtypes: Tuple[str, ...]                   # one dtype per arg
    key_extra: str = ""                           # e.g. flash attention's "cTruew0"
    scenarios: Tuple[str, ...] = ()               # provenance, e.g. "qwen2_0_5b/train_4k"
    weight: float = 1.0                           # executions of this site per step
    # scheduler-assigned
    priority: float = 0.0                         # analytic seconds at stake per step
    budget: int = 0                               # allocated search evaluations
    # runner-updated (persisted in the manifest → resumability)
    status: str = "pending"                       # pending | done | poisoned
    #                                               ("failed" in old manifests)
    attempts: int = 0                             # attempts consumed (across resumes)
    evaluations: int = 0
    best_objective: float = 0.0
    default_objective: float = 0.0
    seeded: bool = False                          # warm-started from a transfer seed
    error: str = ""

    def db_key(self, platform: str) -> str:
        # Must mirror tuner._args_key: all arg shapes, the *promoted* dtype
        # of all args (order-independent; e.g. softmax_xent's f32 logits ×
        # int32 labels key as float32, not as the trailing labels dtype).
        return make_key(
            self.kernel, platform, self.arg_shapes,
            promoted_dtype(self.arg_dtypes), self.key_extra,
        )

    def bucketed_shapes(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(shape_bucket(s) for s in self.arg_shapes)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "TuningJob":
        d = dict(d)
        d["arg_shapes"] = tuple(tuple(int(x) for x in s) for s in d["arg_shapes"])
        d["arg_dtypes"] = tuple(d["arg_dtypes"])
        d["scenarios"] = tuple(d.get("scenarios", ()))
        return TuningJob(**d)


def _site_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Per-step execution counts of each kernel site family."""
    n_attn = n_dense_ffn = n_norm = 0.0
    n_mamba = n_mlstm = n_slstm = n_moe = 0.0
    for seg in cfg.segments():
        for spec in seg.pattern:
            if spec.mixer == "attn":
                n_attn += seg.repeats
            elif spec.mixer == "mamba":
                n_mamba += seg.repeats
            elif spec.mixer == "mlstm":
                n_mlstm += seg.repeats
            elif spec.mixer == "slstm":
                n_slstm += seg.repeats
            if spec.ffn in ("dense", "moe+dense"):
                n_dense_ffn += seg.repeats
            if "moe" in spec.ffn:
                n_moe += seg.repeats
            n_norm += 2 * seg.repeats            # pre-mixer + pre-ffn norms
    return {
        "attn": n_attn, "ffn": n_dense_ffn, "norm": n_norm,
        "mamba": n_mamba, "mlstm": n_mlstm, "slstm": n_slstm, "moe": n_moe,
    }


def _mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(d_inner, d_state, dt_rank) as ``ssm.mamba_init`` derives them."""
    di = cfg.mamba_expand * cfg.d_model
    dtr = max(1, -(-cfg.d_model // 16))          # ceil(d / 16)
    return di, cfg.mamba_d_state, dtr


def _slstm_ff(d: int) -> int:
    """sLSTM post-MLP width (GeGLU pf=4/3, rounded up to 64)."""
    return ((4 * d // 3 + 63) // 64) * 64


def plan_train_jobs(
    cfg: ArchConfig,
    shape: ShapeSpec,
    kernels: Sequence[str] = DEFAULT_KERNELS,
    max_tokens: int = 4096,
    max_seq: int = 4096,
) -> List[TuningJob]:
    """Kernel jobs for one (arch × train/prefill shape) cell."""
    _register_tunables()
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.num_heads, cfg.num_kv_heads
    f = str(cfg.jdtype)
    scen = f"{cfg.name}/{shape.name}"
    B, S = shape.global_batch, shape.seq_len
    T = max(1, min(max_tokens, B * S))
    counts = _site_counts(cfg)
    jobs: List[TuningJob] = []

    def add(kernel, shapes, dtypes, weight, extra=""):
        if kernel in kernels and weight > 0:
            jobs.append(TuningJob(
                kernel=kernel,
                arg_shapes=tuple(tuple(int(x) for x in s) for s in shapes),
                arg_dtypes=tuple(dtypes),
                key_extra=extra,
                scenarios=(scen,),
                weight=float(weight),
            ))

    # Projections and FFN gemms: x[T, d] @ w[d, n].
    add("matmul", [(T, d), (d, H * hd)], [f, f], counts["attn"])
    if cfg.d_ff > 0:
        add("matmul", [(T, d), (d, cfg.d_ff)], [f, f], counts["ffn"])
    add("rmsnorm", [(T, d), (d,)], [f, f], counts["norm"])
    if shape.kind == "train":
        add("softmax_xent", [(T, cfg.vocab_size), (T,)], [f, "int32"], 1.0)

    # Causal attention over the (capped) sequence; batch fills max_tokens.
    s_att = max(1, min(S, max_seq))
    b_att = max(1, min(B, max_tokens // s_att))
    q = (b_att, H, s_att, hd)
    kv = (b_att, KV, s_att, hd)
    # dispatch key_extra must match flash_attention's f"c{causal}w{window}"
    add("flash_attention", [q, kv, kv], [f, f, f], counts["attn"], extra="cTruew0")
    add("attn_chunks", [q, kv, kv], [f, f, f], counts["attn"])

    # SSM mixers: projection gemms at token rows + the batch-shaped scan.
    if counts["mamba"] > 0:
        di, ds, dtr = _mamba_dims(cfg)
        add("matmul", [(T, d), (d, 2 * di)], [f, f], counts["mamba"])
        add("matmul", [(T, di), (di, dtr + 2 * ds)], [f, f], counts["mamba"])
        add("matmul", [(T, dtr), (dtr, di)], ["float32", "float32"], counts["mamba"])
        add("matmul", [(T, di), (di, d)], ["float32", "float32"], counts["mamba"])
        add("ssm_scan",
            [(b_att, s_att, di), (b_att, s_att, di), (b_att, s_att, ds),
             (b_att, s_att, ds), (di, ds), (b_att, di, ds)],
            [f, "float32", "float32", "float32", "float32", "float32"],
            counts["mamba"])
    if counts["mlstm"] > 0:
        di = 2 * d
        add("matmul", [(T, d), (d, 2 * di)], [f, f], counts["mlstm"])
        add("matmul", [(T, di), (di, di)], [f, f], 3 * counts["mlstm"])
        add("matmul", [(T, di), (di, d)], ["float32", "float32"], counts["mlstm"])
    if counts["slstm"] > 0:
        ffs = _slstm_ff(d)
        add("matmul", [(T, d), (d, 4 * d)], [f, f], counts["slstm"])
        add("matmul", [(T, d), (d, ffs)], [f, f], 2 * counts["slstm"])
        add("matmul", [(T, ffs), (ffs, d)], [f, f], counts["slstm"])
    # MoE expert FFN: grouped gemms keyed on (experts × capacity × hidden).
    # Capacity follows the *global* traced token count (what moe_apply sees
    # under jit), capped for materializability like every leading dim.
    if counts["moe"] > 0 and cfg.num_experts > 0:
        e = cfg.num_experts
        cap = min(max_tokens, expert_capacity(
            B * S, e, cfg.experts_per_token, cfg.capacity_factor))
        n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
        add("expert_gemm", [(e, cap, d), (e, d, cfg.d_ff)], [f, f],
            n_up * counts["moe"])
        add("expert_gemm", [(e, cap, cfg.d_ff), (e, cfg.d_ff, d)], [f, f],
            counts["moe"])
    return jobs


def _parse_mesh_axes(mesh_axes) -> Dict[str, int]:
    """Accept {"data": 2, "model": 4}, "2x4", or "2x16x16" (pod first)."""
    if mesh_axes is None:
        return {}
    if isinstance(mesh_axes, str):
        from ..launch.mesh import parse_mesh_spec

        dims, names = parse_mesh_spec(mesh_axes)
        return dict(zip(names, dims))
    return {k: int(v) for k, v in dict(mesh_axes).items()}


def plan_training_jobs(
    cfg: ArchConfig,
    shape: ShapeSpec,
    layout=None,
    mesh_axes=None,
    run=None,
    kernels: Sequence[str] = DEFAULT_KERNELS,
    max_tokens: int = 4096,
    max_seq: int = 4096,
) -> List[TuningJob]:
    """Sharding-aware training jobs: every kernel the train step *dispatches*,
    keyed at per-device **local shard** shapes.

    This is the campaign half of the runtime's local-shape keying: the
    trainer traces under its ``mesh_context``, so dispatch divides
    batch-leading dims by the data-parallel degree of the production
    ``Layout`` × mesh before looking up the database — and this planner
    derives jobs at exactly those shapes, so ``campaign run`` pre-tunes the
    shards training will actually execute (ExactHit at step one, no tuning
    on the pod).

    Unlike :func:`plan_train_jobs` (shape-level roster used when no mesh is
    specified), the site list here mirrors the model's dispatch sites
    one-for-one: q/k/v/o projections, FFN gemms (per ``ffn_kind``), the
    per-loss-chunk unembed matmul + fused xent rows, rmsnorm rows, one
    flash-attention job per distinct sliding-window value in the layer
    pattern (``key_extra`` must match dispatch's ``c{causal}w{window}``),
    the SSM plane (mamba projection gemms + the ``ssm_scan`` /
    ``ssm_scan_bwd`` selective-scan sites at the local batch shard,
    mLSTM/sLSTM projection gemms), and the MoE plane (``expert_gemm``
    grouped gemms keyed on experts × capacity × hidden, capacity from
    ``capacity_factor`` at the global traced token count).

    The roster covers the **backward plane** too: every matmul site derives
    its dL/dx (``ct @ wᵀ``) and dL/dw (``xᵀ @ ct``) transposed-operand
    matmul jobs — dL/dw keyed with the *token* dim localized, mirroring the
    ``dp_dims`` override backward dispatch uses — and every rmsnorm / xent /
    flash site derives its ``*_bwd`` tunable job (grad shapes follow the
    same Layout × mesh local-shape rules, cotangents take the forward
    output's shape, and the forward's saved residuals — flash o/lse,
    rmsnorm inv-rms, xent lse — ride along as keyed operands per the
    residual contract). A campaign run against this plan pre-tunes both
    what the forward *and* the backward of the train step resolve.

    `mesh_axes` is the mesh's axis→size map (or a "DATAxMODEL" spec string);
    no live mesh is needed, so a dev host can plan for a 256-chip pod.
    `run` carries microbatches/loss_chunk (defaults to the launcher's
    defaults for this arch×shape). Leading dims above `max_tokens` are
    capped so jobs stay materializable — capped jobs can only warm-start,
    not exact-hit, which the campaign report will show.
    """
    from ..distributed.sharding import data_parallel_degree
    from ..launch import defaults as _defaults

    _register_tunables()
    layout = layout if layout is not None else _defaults.default_layout(cfg)
    run = run if run is not None else _defaults.default_run(cfg, shape)
    sizes = _parse_mesh_axes(mesh_axes)

    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.num_heads, cfg.num_kv_heads
    f = str(cfg.jdtype)
    B, S = shape.global_batch, shape.seq_len
    mb = max(1, int(getattr(run, "microbatches", 1)))
    b_mb = max(1, B // mb)                      # per-microbatch global batch
    dp = data_parallel_degree(sizes, layout, b_mb) if sizes else 1
    b_loc = max(1, b_mb // dp)                  # per-device local batch
    scen = f"{cfg.name}/{shape.name}@dp{dp}"
    s = min(S, max_seq)
    T = min(b_loc * s, max_tokens)              # token rows per device
    jobs: List[TuningJob] = []

    def add(kernel, shapes, dtypes, weight, extra=""):
        if kernel in kernels and weight > 0:
            jobs.append(TuningJob(
                kernel=kernel,
                arg_shapes=tuple(tuple(int(x) for x in sh) for sh in shapes),
                arg_dtypes=tuple(dtypes),
                key_extra=extra,
                scenarios=(scen,),
                weight=float(weight),
            ))

    def add_gemm(m, kdim, n, weight, dtype=None):
        """One matmul dispatch site + its two backward dispatch sites.

        The backward jobs mirror what `_matmul_bwd` dispatches at trace
        time: dL/dx = ct[m,n] @ wᵀ[n,k] (token rows lead — ordinary
        local-shape keying) and dL/dw = xᵀ[k,m] @ ct[m,n], whose token dim
        sits at arg0-dim1/arg1-dim0 — dispatch passes ``dp_dims`` for it,
        and `m` here is already the local token count, so the shapes agree.
        `dtype` overrides the model dtype for fp32 sites (mamba's dt/out
        projections, mLSTM's out projection).
        """
        dt_ = dtype or f
        add("matmul", [(m, kdim), (kdim, n)], [dt_, dt_], weight)
        add("matmul", [(m, n), (n, kdim)], [dt_, dt_], weight)    # dL/dx
        add("matmul", [(kdim, m), (m, n)], [dt_, dt_], weight)    # dL/dw

    def add_egemm(e_, c_, kdim, n_, weight):
        """One expert_gemm dispatch site + its two backward sites.

        Mirrors `_expert_gemm_bwd`: dL/dx = ct[e,c,n] @ wᵀ[e,n,k] and
        dL/dw = xᵀ[e,k,c] @ ct[e,c,n] — both resolve as transposed-operand
        ``expert_gemm`` keys (no dedicated bwd tunable, like matmul). No
        arg is batch-sharded (capacity derives from the global token
        count), so shapes are global as-is.
        """
        add("expert_gemm", [(e_, c_, kdim), (e_, kdim, n_)], [f, f], weight)
        add("expert_gemm", [(e_, c_, n_), (e_, n_, kdim)], [f, f], weight)
        add("expert_gemm", [(e_, kdim, c_), (e_, c_, n_)], [f, f], weight)

    # Per-layer site families (weights = executions per step).
    n_attn = n_norm = n_ffn = 0.0
    n_mamba = n_mlstm = n_slstm = n_moe = 0.0
    windows: Dict[int, float] = {}
    for seg in cfg.segments():
        for spec in seg.pattern:
            n_norm += seg.repeats           # pre-mixer norm
            if spec.mixer == "attn":
                n_attn += seg.repeats
                windows[spec.window] = windows.get(spec.window, 0.0) + seg.repeats
            elif spec.mixer == "mamba":
                n_mamba += seg.repeats
            elif spec.mixer == "mlstm":
                n_mlstm += seg.repeats
            elif spec.mixer == "slstm":
                n_slstm += seg.repeats
            if spec.ffn != "none":
                n_norm += seg.repeats       # pre-ffn norm
            if spec.ffn in ("dense", "moe+dense"):
                n_ffn += seg.repeats
            if "moe" in spec.ffn:
                n_moe += seg.repeats

    # Attention projections: x[T, d] @ w (canonicalized to 2-D rows).
    add_gemm(T, d, H * hd, n_attn)                                # q proj
    add_gemm(T, d, KV * hd, 2 * n_attn)                           # k, v proj
    add_gemm(T, H * hd, d, n_attn)                                # o proj
    # FFN gemms, per ffn_kind (glu kinds run two up-projections).
    if cfg.d_ff > 0 and n_ffn > 0:
        n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
        add_gemm(T, d, cfg.d_ff, n_up * n_ffn)
        add_gemm(T, cfg.d_ff, d, n_ffn)
        # Fused-epilogue candidate for the activation up-projection:
        # `_act_matmul` keys matmul_bias_act with a zero bias and the
        # activation in key_extra; a banked record here is what flips
        # `fusion_wins` for the site (its backward decomposes onto the
        # matmul jobs above, per bwd_via).
        act = {"swiglu": "silu", "geglu": "gelu", "gelu": "gelu"}.get(
            cfg.ffn_kind)
        if act:
            add("matmul_bias_act", [(T, d), (d, cfg.d_ff), (cfg.d_ff,)],
                [f, f, f], n_ffn, extra=f"a{act}")
    # RMSNorm rows: per-layer norms + the final norm, fwd + fused bwd.
    # The bwd job carries the residual contract's operands: cotangent is
    # output-shaped ([T, d]) and the forward's saved inv-rms rides along as
    # a per-row f32 vector — residuals are dispatch args, so they are part
    # of the db key (and promote the key dtype to f32).
    add("rmsnorm", [(T, d), (d,)], [f, f], n_norm + 1)
    add("rmsnorm_bwd", [(T, d), (T, d), (d,), (T,)], [f, f, f, "float32"],
        n_norm + 1)
    # Chunked loss: each seq chunk runs one unembed gemm + one fused xent;
    # backward adds the unembed's transposed gemms and the fused d_logits
    # pass (per-row loss cotangent is fp32, like the loss output).
    if shape.kind == "train":
        chunk = max(1, min(int(getattr(run, "loss_chunk", 512)), s))
        rows = min(b_loc * chunk, max_tokens)
        n_chunks = max(1.0, s / chunk)
        add_gemm(rows, d, cfg.vocab_size, n_chunks)
        add("softmax_xent", [(rows, cfg.vocab_size), (rows,)], [f, "int32"],
            n_chunks)
        add("softmax_xent_bwd",
            [(rows,), (rows, cfg.vocab_size), (rows,), (rows,)],
            ["float32", f, "int32", "float32"], n_chunks)
    # Causal attention at the local batch, one job per distinct window
    # (dispatch keys flash_attention with extra=c{causal}w{window}) plus the
    # fused backward site: cotangent leads with the q shape, then the
    # forward's saved residuals (o: q-shaped output, lse: per-row f32
    # log-sum-exp) — the residual contract makes them dispatch args, so
    # they key the bwd site. No attn_chunks job: training never dispatches
    # that tunable (the chunked path calls chunked_attention directly) —
    # budget goes only to sites the step resolves.
    b_att = max(1, min(b_loc, max_tokens // max(1, s)))
    q = (b_att, H, s, hd)
    kv = (b_att, KV, s, hd)
    lse_s = (b_att, H, s)
    for w, n in sorted(windows.items()):
        add("flash_attention", [q, kv, kv], [f, f, f], n, extra=f"cTruew{w}")
        add("flash_attention_bwd", [q, q, kv, kv, q, lse_s],
            [f, f, f, f, f, "float32"], n, extra=f"cTruew{w}")

    # --- SSM mixers ------------------------------------------------------
    # Mamba: four projection gemm sites (dt/out run in fp32, matching
    # `ssm._mamba_dtBC` / `_mamba_out`) plus the selective scan at the
    # local batch shard — xc/dt/B/C/h0 are batch-sharded
    # (data_parallel_args), so b_att here mirrors what dispatch keys under
    # the trainer's mesh_context. The scan's gradient resolves the
    # dedicated `ssm_scan_bwd` tunable (cotangents take the y/hN output
    # shapes, fp32).
    if n_mamba > 0:
        di, ds, dtr = _mamba_dims(cfg)
        add_gemm(T, d, 2 * di, n_mamba)                           # in_proj
        add_gemm(T, di, dtr + 2 * ds, n_mamba)                    # x_proj
        add_gemm(T, dtr, di, n_mamba, dtype="float32")            # dt_proj
        add_gemm(T, di, d, n_mamba, dtype="float32")              # out_proj
        xc_s = (b_att, s, di)
        bc_s = (b_att, s, ds)
        a_s, h_s = (di, ds), (b_att, di, ds)
        add("ssm_scan", [xc_s, xc_s, bc_s, bc_s, a_s, h_s],
            [f, "float32", "float32", "float32", "float32", "float32"],
            n_mamba)
        add("ssm_scan_bwd",
            [xc_s, h_s, xc_s, xc_s, bc_s, bc_s, a_s, h_s],
            ["float32", "float32", f, "float32", "float32", "float32",
             "float32", "float32"],
            n_mamba)
    # mLSTM: chunkwise projections (the decayed intra-chunk score matmuls
    # stay fused in the scan body — the decay mask makes them
    # non-substitutable by a plain matmul record).
    if n_mlstm > 0:
        di = 2 * d
        add_gemm(T, d, 2 * di, n_mlstm)                           # in_proj
        add_gemm(T, di, di, 3 * n_mlstm)                          # wq/wk/wv
        add_gemm(T, di, d, n_mlstm, dtype="float32")              # out_proj
    if n_slstm > 0:
        ffs = _slstm_ff(d)
        add_gemm(T, d, 4 * d, n_slstm)                            # gate stack
        add_gemm(T, d, ffs, 2 * n_slstm)                          # up_g/up_u
        add_gemm(T, ffs, d, n_slstm)                              # down

    # --- MoE expert FFN --------------------------------------------------
    # Grouped gemms keyed on (experts × capacity × hidden). Capacity
    # follows the *global* per-microbatch token count — `moe_apply` traces
    # the unsharded shape, and expert_gemm args are not batch-sharded —
    # capped like every leading dim (capped jobs warm-start only).
    if n_moe > 0 and cfg.num_experts > 0:
        e = cfg.num_experts
        cap = min(max_tokens, expert_capacity(
            b_mb * S, e, cfg.experts_per_token, cfg.capacity_factor))
        n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
        add_egemm(e, cap, d, cfg.d_ff, n_up * n_moe)              # wg/wu
        add_egemm(e, cap, cfg.d_ff, d, n_moe)                     # wd
    return jobs


def _seq_buckets(max_seq: int, min_seq: int = 16) -> List[int]:
    seqs: List[int] = []
    s = min_seq
    while s < max_seq:
        seqs.append(s)
        s <<= 1
    seqs.append(shape_bucket((max_seq,))[0])
    return sorted(set(seqs))


def serving_buckets(max_batch: int, max_seq: int, min_seq: int = 16) -> List[Tuple[int, int]]:
    """The (batch, seq-bucket) jit keys a slot-pool ServingEngine can hit.

    The continuous engine admits one request at a time: each admission
    prefill jits at batch 1 × a power-of-two seq bucket (``(1, s)``), and
    the decode pool jits ONCE at the full slot width, touching the cache at
    every seq bucket up to capacity (``(max_batch, s)``). Bucket keys use
    the same ``database.shape_bucket`` discipline as the static engine, so
    campaign databases exported before the slot-pool rebuild stay valid.
    """
    seqs = _seq_buckets(max_seq, min_seq)
    return sorted({(1, s) for s in seqs} | {(max_batch, s) for s in seqs})


def plan_serving_jobs(
    cfg: ArchConfig,
    max_batch: int = 8,
    max_seq: int = 256,
    kernels: Sequence[str] = DEFAULT_KERNELS,
    max_tokens: int = 4096,
) -> List[TuningJob]:
    """Kernel jobs for every slot-pool bucket a continuous ServingEngine jits.

    Admission prefills run at batch 1 × seq-bucket: token-parallel sites see
    s rows, causal attention sees [1, H, s, hd]. The decode pool runs at the
    full slot width every tick: gemms/norms at `max_batch` rows, and
    decode-shaped attention lookups (q_len = 1 against an s-deep cache) —
    executed ~s times per request, hence the seq-length weight.

    The gemm roster is trace-faithful, mirroring `plan_training_jobs`' site
    list: q and k/v projections, the o projection ([.., H·hd] @ [H·hd, d]),
    FFN up/down, and the unembed — prefill reads logits only at the last
    real position ([1, d] rows), decode at every slot ([max_batch, d]) —
    so a warmed engine resolves every site it will dispatch at ExactHit.
    """
    if cfg.frontend is not None:
        return []                     # the engine serves token-in archs only
    _register_tunables()
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.num_heads, cfg.num_kv_heads
    f = str(cfg.jdtype)
    counts = _site_counts(cfg)
    jobs: List[TuningJob] = []

    def add(kernel, shapes, dtypes, weight, scen, extra=""):
        if kernel in kernels and weight > 0:
            jobs.append(TuningJob(
                kernel=kernel,
                arg_shapes=tuple(tuple(int(x) for x in s) for s in shapes),
                arg_dtypes=tuple(dtypes),
                key_extra=extra,
                scenarios=(scen,),
                weight=float(weight),
            ))

    B = max_batch
    seqs = _seq_buckets(max_seq)
    for s in seqs:
        # --- admission prefill: batch-1, right-padded to the seq bucket
        if s <= max_tokens:
            scen_p = f"{cfg.name}/serve_prefill_b1s{s}"
            add("matmul", [(s, d), (d, H * hd)], [f, f], counts["attn"], scen_p)
            add("matmul", [(s, d), (d, KV * hd)], [f, f], 2 * counts["attn"], scen_p)
            add("matmul", [(s, H * hd), (H * hd, d)], [f, f], counts["attn"], scen_p)
            if cfg.d_ff > 0:
                n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
                add("matmul", [(s, d), (d, cfg.d_ff)], [f, f],
                    n_up * counts["ffn"], scen_p)
                add("matmul", [(s, cfg.d_ff), (cfg.d_ff, d)], [f, f],
                    counts["ffn"], scen_p)
            # last-real-token logits: one [1, d] unembed gemm per admission
            add("matmul", [(1, d), (d, cfg.vocab_size)], [f, f], 1.0, scen_p)
            add("rmsnorm", [(s, d), (d,)], [f, f], counts["norm"], scen_p)
            q = (1, H, s, hd)
            kv = (1, KV, s, hd)
            add("flash_attention", [q, kv, kv], [f, f, f], counts["attn"], scen_p,
                extra="cTruew0")
            add("attn_chunks", [q, kv, kv], [f, f, f], counts["attn"], scen_p)
            # SSM mixers at prefill: projections over s rows + the batch-1
            # scan (prefill-with-state is the same ssm_scan site training
            # resolves, at the admission shape).
            if counts["mamba"] > 0:
                di, ds_, dtr = _mamba_dims(cfg)
                add("matmul", [(s, d), (d, 2 * di)], [f, f],
                    counts["mamba"], scen_p)
                add("matmul", [(s, di), (di, dtr + 2 * ds_)], [f, f],
                    counts["mamba"], scen_p)
                add("matmul", [(s, dtr), (dtr, di)], ["float32", "float32"],
                    counts["mamba"], scen_p)
                add("matmul", [(s, di), (di, d)], ["float32", "float32"],
                    counts["mamba"], scen_p)
                add("ssm_scan",
                    [(1, s, di), (1, s, di), (1, s, ds_), (1, s, ds_),
                     (di, ds_), (1, di, ds_)],
                    [f, "float32", "float32", "float32", "float32", "float32"],
                    counts["mamba"], scen_p)
            if counts["mlstm"] > 0:
                di = 2 * d
                add("matmul", [(s, d), (d, 2 * di)], [f, f],
                    counts["mlstm"], scen_p)
                add("matmul", [(s, di), (di, di)], [f, f],
                    3 * counts["mlstm"], scen_p)
                add("matmul", [(s, di), (di, d)], ["float32", "float32"],
                    counts["mlstm"], scen_p)
            if counts["slstm"] > 0:
                ffs = _slstm_ff(d)
                add("matmul", [(s, d), (d, 4 * d)], [f, f],
                    counts["slstm"], scen_p)
                add("matmul", [(s, d), (d, ffs)], [f, f],
                    2 * counts["slstm"], scen_p)
                add("matmul", [(s, ffs), (ffs, d)], [f, f],
                    counts["slstm"], scen_p)
            if counts["moe"] > 0 and cfg.num_experts > 0:
                e = cfg.num_experts
                cap = expert_capacity(s, e, cfg.experts_per_token,
                                      cfg.capacity_factor)
                n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
                add("expert_gemm", [(e, cap, d), (e, d, cfg.d_ff)], [f, f],
                    n_up * counts["moe"], scen_p)
                add("expert_gemm", [(e, cap, cfg.d_ff), (e, cfg.d_ff, d)],
                    [f, f], counts["moe"], scen_p)
        # --- decode pool: max_batch rows, once per generated token
        if B * s > max_tokens:
            continue
        scen_d = f"{cfg.name}/serve_decode_b{B}s{s}"
        add("matmul", [(B, d), (d, H * hd)], [f, f], counts["attn"] * s, scen_d)
        add("matmul", [(B, d), (d, KV * hd)], [f, f], 2 * counts["attn"] * s, scen_d)
        add("matmul", [(B, H * hd), (H * hd, d)], [f, f], counts["attn"] * s, scen_d)
        if cfg.d_ff > 0:
            n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
            add("matmul", [(B, d), (d, cfg.d_ff)], [f, f],
                n_up * counts["ffn"] * s, scen_d)
            add("matmul", [(B, cfg.d_ff), (cfg.d_ff, d)], [f, f],
                counts["ffn"] * s, scen_d)
        add("matmul", [(B, d), (d, cfg.vocab_size)], [f, f], float(s), scen_d)
        add("rmsnorm", [(B, d), (d,)], [f, f], counts["norm"] * s, scen_d)
        # Fused final-norm → unembed candidate for the decode hot loop
        # (`rmsnorm_dense` in decode_step): a banked record opts the site
        # into the rmsnorm_matmul fusion; otherwise it stays on the
        # separate rmsnorm + matmul keys above.
        add("rmsnorm_matmul", [(B, d), (d,), (d, cfg.vocab_size)], [f, f, f],
            float(s), scen_d)
        # SSM decode state: one fused `ssm_update` per mamba layer per tick
        # (the decode-state rows), plus the per-tick projection gemms.
        if counts["mamba"] > 0:
            di, ds_, dtr = _mamba_dims(cfg)
            add("matmul", [(B, d), (d, 2 * di)], [f, f],
                counts["mamba"] * s, scen_d)
            add("matmul", [(B, di), (di, dtr + 2 * ds_)], [f, f],
                counts["mamba"] * s, scen_d)
            add("matmul", [(B, dtr), (dtr, di)], ["float32", "float32"],
                counts["mamba"] * s, scen_d)
            add("matmul", [(B, di), (di, d)], ["float32", "float32"],
                counts["mamba"] * s, scen_d)
            add("ssm_update",
                [(B, di), (B, di), (B, ds_), (B, ds_), (di, ds_),
                 (B, di, ds_)],
                [f, "float32", "float32", "float32", "float32", "float32"],
                counts["mamba"] * s, scen_d)
        if counts["mlstm"] > 0:
            di = 2 * d
            add("matmul", [(B, d), (d, 2 * di)], [f, f],
                counts["mlstm"] * s, scen_d)
            add("matmul", [(B, di), (di, di)], [f, f],
                3 * counts["mlstm"] * s, scen_d)
            add("matmul", [(B, di), (di, d)], ["float32", "float32"],
                counts["mlstm"] * s, scen_d)
        if counts["slstm"] > 0:
            ffs = _slstm_ff(d)
            add("matmul", [(B, d), (d, 4 * d)], [f, f],
                counts["slstm"] * s, scen_d)
            add("matmul", [(B, d), (d, ffs)], [f, f],
                2 * counts["slstm"] * s, scen_d)
            add("matmul", [(B, ffs), (ffs, d)], [f, f],
                counts["slstm"] * s, scen_d)
        if counts["moe"] > 0 and cfg.num_experts > 0:
            e = cfg.num_experts
            cap = expert_capacity(B, e, cfg.experts_per_token,
                                  cfg.capacity_factor)
            n_up = 2 if cfg.ffn_kind in ("swiglu", "geglu") else 1
            add("expert_gemm", [(e, cap, d), (e, d, cfg.d_ff)], [f, f],
                n_up * counts["moe"] * s, scen_d)
            add("expert_gemm", [(e, cap, cfg.d_ff), (e, cfg.d_ff, d)],
                [f, f], counts["moe"] * s, scen_d)
    # decode-shaped attention lookup: one query row against the pool cache.
    # The slot pool allocates its cache at max_seq depth ONCE — decode never
    # sees a shallower kv tensor, so only the max_seq bucket is a live key.
    s_max = seqs[-1]
    if B * s_max <= max_tokens:
        qd = (B, H, 1, hd)
        kvd = (B, KV, s_max, hd)
        add("attn_chunks", [qd, kvd, kvd], [f, f, f], counts["attn"] * s_max,
            f"{cfg.name}/serve_decode_b{B}s{s_max}")
    return jobs


def plan_jobs(
    arch_names: Sequence[str],
    train_shapes: Sequence[str] = ("train_4k",),
    serving: Optional[Tuple[int, int]] = (8, 256),
    kernels: Sequence[str] = DEFAULT_KERNELS,
    reduced: bool = False,
    max_tokens: int = 4096,
    max_seq: int = 4096,
    train_mesh=None,
) -> List[TuningJob]:
    """The full campaign workload, deterministically ordered.

    `reduced=True` plans against the family-preserving smoke configs — the
    CPU-runnable campaign used by tests/examples; a TPU campaign plans the
    real dims. `serving=(max_batch, max_seq)` adds the engine buckets for
    every servable (token-in/token-out) arch; None skips them.

    `train_mesh` (axis→size map or a "DATAxMODEL" spec) switches the train
    cells to :func:`plan_training_jobs`: sharding-aware jobs at per-device
    local shard shapes under each arch's production Layout — what a trainer
    dispatching under that mesh will actually look up.
    """
    _register_tunables()
    jobs: List[TuningJob] = []
    for name in arch_names:
        cfg = get_config(name)
        if reduced:
            cfg = cfg.reduced()
        for shape_name in train_shapes:
            shape = SHAPES[shape_name]
            if train_mesh is not None:
                jobs.extend(plan_training_jobs(
                    cfg, shape, mesh_axes=train_mesh, kernels=kernels,
                    max_tokens=max_tokens, max_seq=max_seq,
                ))
            else:
                jobs.extend(plan_train_jobs(
                    cfg, shape, kernels=kernels, max_tokens=max_tokens,
                    max_seq=max_seq,
                ))
        if serving is not None:
            jobs.extend(plan_serving_jobs(
                cfg, serving[0], serving[1], kernels=kernels, max_tokens=max_tokens
            ))
    jobs.sort(key=lambda j: (j.kernel, j.arg_shapes, j.key_extra, j.scenarios))
    return jobs
