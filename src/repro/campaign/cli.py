"""``python -m repro.campaign`` — the campaign operator interface.

    plan    derive + schedule jobs, write the resumable manifest
    run     execute pending jobs best-first (interrupt-safe; rerun resumes)
    status  show the manifest's progress and banked speedups
    check   validate the tuning db + manifest (stale keys, missing bwd
            roster, capacity drift) via the repro.analysis passes
    export  write the shippable per-platform database (records + cover sets)
    drift   re-measure tuned sites and rank regressions vs db + roofline

A CPU smoke campaign end-to-end (the TPU flow is identical minus --reduced):

    python -m repro.campaign plan --reduced --arches qwen2_0_5b,minitron_4b,qwen2_5_3b \
        --budget 120 --max-tokens 256 --serving 4x64 --out campaign.json
    python -m repro.campaign run --manifest campaign.json --db tuning.json
    python -m repro.campaign status --manifest campaign.json
    python -m repro.campaign export --db tuning.json --out cpu-host.db.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from ..core.database import TuningDatabase
from ..core.evaluate import WallClockEvaluator
from ..core.platform import detect_platform
from . import planner, runner, scheduler

DEFAULT_ARCHES = "qwen2_0_5b,minitron_4b,qwen2_5_3b,gemma3_27b"


def _db_path(args) -> str:
    return args.db or os.environ.get("REPRO_TUNING_DB", ".repro_tuning.json")


def _fmt_job(j: planner.TuningJob, platform: str) -> str:
    shapes = "/".join("x".join(map(str, s)) for s in j.arg_shapes)
    state = j.status if j.budget or j.status != "pending" else "deferred"
    return (
        f"  [{state:>8}] {j.kernel:<16} {shapes:<28} budget={j.budget:<4}"
        f" prio={j.priority:.3g} × {len(j.scenarios)} scenario(s)"
    )


def cmd_plan(args) -> int:
    arches = [a for a in args.arches.split(",") if a]
    train_shapes = [s for s in args.train_shapes.split(",") if s]
    kernels = tuple(k for k in args.kernels.split(",") if k)
    serving = None
    if args.serving:
        try:
            b, s = args.serving.lower().split("x")
            serving = (int(b), int(s))
        except ValueError:
            raise SystemExit(
                f"error: --serving expects MAXBATCHxMAXSEQ (e.g. 8x256), "
                f"got {args.serving!r}"
            )
    jobs = planner.plan_jobs(
        arches,
        train_shapes=train_shapes,
        serving=serving,
        kernels=kernels,
        reduced=args.reduced,
        max_tokens=args.max_tokens,
        max_seq=args.max_seq,
        train_mesh=args.train_mesh or None,
    )
    profile = detect_platform()
    scen_sec = scheduler.analytic_scenario_seconds(
        arches, train_shapes, reduced=args.reduced, profile=profile
    )
    manifest = scheduler.build_manifest(
        jobs, args.budget, path=args.out, profile=profile,
        min_budget=args.min_budget, max_budget=args.max_budget,
        scenario_seconds=scen_sec,
    )
    print(f"planned {len(jobs)} jobs -> {len(manifest.jobs)} unique keys "
          f"on {manifest.platform} (budget {args.budget} evals) -> {args.out}")
    for j in manifest.jobs:
        print(_fmt_job(j, manifest.platform))
    return 0


def cmd_run(args) -> int:
    manifest = scheduler.CampaignManifest.load(args.manifest)
    if scheduler.manifest_missing_bwd(manifest) and not args.allow_missing_bwd:
        print(
            "error: manifest has sharding-aware training jobs (@dp scenarios) "
            "but no backward roster — it predates the tuned backward plane. "
            "Running it would bank a forward-only database: the train step's "
            "gradient dispatch sites would never ExactHit.\n"
            f"re-plan it:   python -m repro.campaign plan --train-mesh ... "
            f"--out {args.manifest}\n"
            "or pass --allow-missing-bwd to run forward-only anyway (pin "
            "repro.runtime(bwd_dispatch=False) at train time to match).",
            file=sys.stderr,
        )
        return 2
    if args.budget is not None:
        # re-split the new global budget across still-pending jobs
        pending = [j for j in manifest.jobs if j.status == "pending"]
        scheduler.allocate_budget(
            pending, args.budget, min_budget=args.min_budget,
            max_budget=args.max_budget,
        )
        manifest.total_budget = args.budget
        manifest.save()
    db = TuningDatabase(_db_path(args))
    if args.metrics_out:
        import repro.obs as obs

        col = obs.collect(name="campaign")
    else:
        import contextlib

        col = contextlib.nullcontext()
    with col:
        summary = runner.run_campaign(
            manifest, db,
            evaluator=WallClockEvaluator(repeats=args.repeats, warmup=1),
            max_jobs=args.max_jobs,
            warm_start=not args.no_warm_start,
            job_timeout=args.job_timeout,
            max_attempts=args.max_attempts,
        )
    print(json.dumps(summary, indent=1, sort_keys=True))
    if args.metrics_out:
        col.write(args.metrics_out)
        print(f"wrote metrics -> {args.metrics_out}")
    return 0


def cmd_drift(args) -> int:
    """Ranked drift report: re-measure tuned sites, attribute vs db + roofline."""
    from ..obs import drift as obs_drift

    db = TuningDatabase(_db_path(args))
    entries = obs_drift.drift_report(
        db, threshold=args.threshold, platform=args.platform,
    )
    print(obs_drift.format_drift(entries, args.threshold))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([e.to_json() for e in entries], f, indent=1)
        print(f"wrote drift report -> {args.json_out}")
    if args.fail_on_drift and any(e.regressed for e in entries):
        return 1
    return 0


def cmd_status(args) -> int:
    manifest = scheduler.CampaignManifest.load(args.manifest)
    print(json.dumps(manifest.summary(), indent=1, sort_keys=True))
    # Static-legality accounting stamped at plan time: configs the tuner
    # prunes before measurement, so budgets are read against *legal* spaces.
    for kernel, counts in sorted((manifest.meta.get("legality") or {}).items()):
        if counts.get("pruned"):
            print(f"  legality: {kernel}: pruned {counts['pruned']} of "
                  f"{counts['total']} configs ({counts['legal']} legal) "
                  f"on {manifest.platform}")
    for j in manifest.jobs:
        line = _fmt_job(j, manifest.platform)
        if j.status == "done" and j.best_objective > 0:
            speed = (j.default_objective / j.best_objective
                     if j.default_objective > 0 else 0.0)
            line += f"  {speed:.2f}x in {j.evaluations} evals"
            if j.seeded:
                line += " (warm)"
        elif j.status in ("failed", "poisoned"):
            line += f"  ERROR after {j.attempts or 1} attempt(s): {j.error[:60]}"
        print(line)
    # Sustained-performance accounting: the campaign run's own dispatches
    # (banked in the manifest) plus any deployment snapshots the operator
    # exported with `launch.train/serve --telemetry-out`.
    if manifest.meta.get("telemetry", {}).get("calls"):
        print(runner.format_telemetry(
            runner.summarize_telemetry(manifest.meta["telemetry"]), "campaign"
        ))
    for path in args.telemetry or ():
        print(runner.format_telemetry(runner.load_telemetry(path), path))
    return 0


def cmd_check(args) -> int:
    """Validate db + manifest through the repro.analysis contract passes."""
    from ..analysis import run_checks

    passes = ["contracts", "db"]
    if args.full:
        passes = ["lint", "legality"] + passes
    report = run_checks(
        db=_db_path(args),
        manifest=args.manifest,
        passes=passes,
    )
    print(report.format(verbose=args.verbose))
    return report.exit_code(strict=args.strict)


def cmd_export(args) -> int:
    db = TuningDatabase(_db_path(args))
    platform = args.platform or detect_platform().name
    out = runner.export_campaign_db(
        db, args.out, platform, cover_max_size=args.cover_size
    )
    covers = {k: len(v) for k, v in out.covers().items()}
    print(f"exported {len(out)} records + {sum(covers.values())} cover "
          f"entries for {platform} -> {args.out}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    pp = sub.add_parser("plan", help="derive + schedule jobs, write the manifest")
    pp.add_argument("--out", default="campaign.json", help="manifest path")
    pp.add_argument("--db", default=None, help="tuning database path")
    pp.add_argument("--arches", default=DEFAULT_ARCHES,
                    help="comma-separated arch config names")
    pp.add_argument("--train-shapes", default="train_4k",
                    help="comma-separated ShapeSpec names")
    pp.add_argument("--serving", default="8x256",
                    help="serving buckets as MAXBATCHxMAXSEQ ('' to skip)")
    pp.add_argument("--kernels", default=",".join(planner.DEFAULT_KERNELS))
    pp.add_argument("--reduced", action="store_true",
                    help="plan against the reduced smoke configs (CPU campaigns)")
    pp.add_argument("--budget", type=int, default=256,
                    help="global evaluation budget across all jobs")
    pp.add_argument("--min-budget", type=int, default=6)
    pp.add_argument("--max-budget", type=int, default=128)
    pp.add_argument("--max-tokens", type=int, default=4096,
                    help="cap on materialized leading (token) dims")
    pp.add_argument("--max-seq", type=int, default=4096,
                    help="cap on materialized attention sequence length")
    pp.add_argument("--train-mesh", default=None,
                    help="plan sharding-aware training jobs for this mesh "
                         "(DATAxMODEL, e.g. 16x16): jobs key on per-device "
                         "local shard shapes under each arch's production "
                         "Layout — what a trainer dispatching under that "
                         "mesh actually looks up")
    pp.set_defaults(fn=cmd_plan)

    pr = sub.add_parser("run", help="execute pending jobs (resumable)")
    pr.add_argument("--manifest", default="campaign.json")
    pr.add_argument("--db", default=None)
    pr.add_argument("--budget", type=int, default=None,
                    help="re-allocate this global budget over pending jobs")
    pr.add_argument("--min-budget", type=int, default=6)
    pr.add_argument("--max-budget", type=int, default=128)
    pr.add_argument("--max-jobs", type=int, default=None,
                    help="run at most N jobs this invocation")
    pr.add_argument("--repeats", type=int, default=3,
                    help="wall-clock evaluator repeats")
    pr.add_argument("--no-warm-start", action="store_true",
                    help="disable transfer seeding (cold-search control)")
    pr.add_argument("--job-timeout", type=float, default=None,
                    help="wall-clock bound per tuning attempt in seconds "
                         "(a stuck compile counts as a failed attempt)")
    pr.add_argument("--max-attempts", type=int, default=1,
                    help="attempts per job before it is quarantined as "
                         "poisoned (persisted; resume skips poisoned jobs)")
    pr.add_argument("--allow-missing-bwd", action="store_true",
                    help="run a training manifest that has no backward "
                         "roster (pre-backward-plane plan) instead of "
                         "failing with a re-plan instruction")
    pr.add_argument("--metrics-out", default=None,
                    help="enable the obs collector (per-job wall-time + "
                         "speedup histograms) and write its snapshot here")
    pr.set_defaults(fn=cmd_run)

    pd = sub.add_parser(
        "drift",
        help="re-measure tuned sites live and rank regressions vs the db "
             "record and the analytic roofline (re-tune trigger input)",
    )
    pd.add_argument("--db", default=None)
    pd.add_argument("--platform", default=None,
                    help="platform key to audit (default: detected)")
    pd.add_argument("--threshold", type=float, default=1.5,
                    help="flag sites whose live/tuned ratio exceeds this")
    pd.add_argument("--json-out", default=None,
                    help="write the ranked entries as JSON here")
    pd.add_argument("--fail-on-drift", action="store_true",
                    help="exit 1 if any site regressed past the threshold")
    pd.set_defaults(fn=cmd_drift)

    ps = sub.add_parser("status", help="show campaign progress")
    ps.add_argument("--manifest", default="campaign.json")
    ps.add_argument("--telemetry", action="append", default=[],
                    help="runtime telemetry snapshot JSON (from launch.train/"
                         "serve --telemetry-out); repeatable — prints per-tier "
                         "hit rates and per-kernel exact-hit shares")
    ps.set_defaults(fn=cmd_status)

    pk = sub.add_parser(
        "check",
        help="validate the tuning db + manifest (stale keys, missing "
             "backward roster, expert-capacity drift)",
    )
    pk.add_argument("--db", default=None)
    pk.add_argument("--manifest", default=None,
                    help="campaign manifest to cross-check (enables the "
                         "backward-roster and capacity-drift checks)")
    pk.add_argument("--full", action="store_true",
                    help="also run the lint + kernel-legality passes "
                         "(python -m repro.analysis check runs everything)")
    pk.add_argument("--strict", action="store_true",
                    help="exit 1 on warnings too")
    pk.add_argument("--verbose", "-v", action="store_true",
                    help="also print info findings")
    pk.set_defaults(fn=cmd_check)

    pe = sub.add_parser("export", help="write the per-platform database artifact")
    pe.add_argument("--db", default=None)
    pe.add_argument("--out", default="platform.db.json")
    pe.add_argument("--platform", default=None,
                    help="platform key (default: detected)")
    pe.add_argument("--cover-size", type=int, default=4,
                    help="max cover-set entries per kernel")
    pe.set_defaults(fn=cmd_export)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
