"""Offline tuning-campaign orchestration: plan → schedule → transfer → export.

The paper's deliverable is *generic code + a per-platform tuning database*.
The core layer already has every primitive — annotated tunables, budgeted
search strategies, evaluators, the keyed database — but only reactively, one
kernel at a time inside ``tune_or_lookup``. This subsystem turns those
primitives into the artifact pipeline:

  plan      derive the concrete tuning jobs (kernel × shape-bucket × dtype)
            a deployment will actually hit: train-step shapes from the
            registered ArchConfigs plus the serving engine's (batch,
            seq-bucket) jit keys                          → campaign.planner
  schedule  dedup jobs by database key, rank them by the analytic roofline
            seconds at stake, split a global evaluation budget, persist a
            resumable manifest                            → campaign.scheduler
  run       execute jobs best-first, warm-starting each search from the
            nearest existing record (transfer tuning)     → campaign.runner
  export    cluster winners into a small 'few fit most' cover set and write
            the shippable per-platform database           → campaign.runner

CLI: ``python -m repro.campaign {plan,run,status,export}``.
"""
from .planner import (
    TuningJob,
    plan_jobs,
    plan_serving_jobs,
    plan_train_jobs,
    plan_training_jobs,
)
from .scheduler import CampaignManifest, allocate_budget, dedupe_jobs, prioritize_jobs
from .transfer import cluster_winners, compute_covers, warm_start_configs
from .runner import export_campaign_db, run_campaign, summarize_telemetry

__all__ = [
    "TuningJob",
    "plan_jobs",
    "plan_serving_jobs",
    "plan_train_jobs",
    "plan_training_jobs",
    "summarize_telemetry",
    "CampaignManifest",
    "allocate_budget",
    "dedupe_jobs",
    "prioritize_jobs",
    "warm_start_configs",
    "cluster_winners",
    "compute_covers",
    "run_campaign",
    "export_campaign_db",
]
