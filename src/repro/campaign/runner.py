"""Campaign runner: execute the manifest best-first, bank results, export.

Each job materializes representative arguments (seeded, so a re-run measures
the same tensors), pulls warm-start seeds from the transfer layer, runs the
budgeted search through :func:`repro.core.tuner.autotune` (which writes the
database record), and persists the manifest after *every* job — kill the
process at any point and the next `campaign run` resumes at the first
pending job.

Export clusters the platform's winners into cover sets (transfer layer) and
writes the shippable single-platform database — the artifact a deployment
pairs with the generic code for zero-tuning serve-time specialization.
"""
from __future__ import annotations

import logging
import signal
import threading
import time
import zlib
from typing import Callable, Dict, Optional

import numpy as np

from ..core.annotate import get_tunable
from ..core.database import TuningDatabase
from ..core.evaluate import Evaluator, WallClockEvaluator
from ..core.runtime import TunedRuntime
from ..core.search import CoordinateDescent, SearchAlgorithm
from ..core.tuner import autotune, promoted_dtype
from ..obs.collect import current_collector as _obs_collector
from ..obs.trace import span as _obs_span
from ..testing.faults import fault_point as _fault_point
from .planner import TuningJob, _register_tunables
from .scheduler import CampaignManifest
from .transfer import compute_covers, warm_start_configs

log = logging.getLogger("repro.campaign")


def materialize_args(job: TuningJob, seed: int = 0):
    """Seeded representative tensors for one job.

    Float args are unit-scale gaussians (what the correctness gates and the
    paper's own measurements use); integer args are labels/ids drawn against
    the first ≥2-D arg's trailing dim (the vocab for softmax_xent and its
    backward, whose leading cotangent arg is 1-D). SSM scan/update jobs
    condition their coefficient args instead — dt must be a small positive
    step and A a negative decay rate, or exp(dt·A) leaves the regime the
    selective scan ever traces and the measurement is of overflow handling.

    Residual-threaded bwd jobs (rmsnorm_bwd, softmax_xent_bwd,
    flash_attention_bwd) get residual operands *derived from their primal
    args*, not sampled: an inv-rms or lse that is inconsistent with x /
    logits / (q, k, v) puts the kernel outside the numeric regime training
    ever hands it (e.g. exp(scores − lse) unbounded), and both the
    correctness gate and the measurement would be of garbage. Jobs from
    pre-residual manifests (shorter arg lists) keep the old behavior.
    """
    import jax.numpy as jnp

    # crc32, not hash(): str hashes are salted per process and the tensors
    # must be identical across resumed runs.
    rs = np.random.RandomState(seed ^ (zlib.crc32(job.kernel.encode()) & 0xFFFF))
    args = []
    hi = max(2, max(
        (int(s[-1]) for s in job.arg_shapes if len(s) >= 2),
        default=2,
    ))                                             # vocab bound for label args
    attn_like = ("flash_attention", "flash_attention_bwd", "attn_chunks")
    # (dt arg index, A arg index) per SSM kernel — bwd signatures lead with
    # the two cotangents, shifting the forward args right by two.
    ssm_coeffs = {
        "ssm_scan": (1, 4), "ssm_update": (1, 4),
        "ssm_scan_bwd": (3, 6), "ssm_update_bwd": (3, 6),
    }
    for i, (shape, dtype) in enumerate(zip(job.arg_shapes, job.arg_dtypes)):
        if dtype.startswith("int") or dtype.startswith("uint"):
            args.append(jnp.asarray(rs.randint(0, hi, size=shape), jnp.int32))
            continue
        t = rs.randn(*shape)
        if job.kernel in ssm_coeffs:
            dt_i, a_i = ssm_coeffs[job.kernel]
            if i == dt_i:
                t = np.abs(t) * 0.1 + 0.01         # post-softplus step sizes
            elif i == a_i:
                t = -np.abs(t) - 0.1               # stable decay rates
            else:
                t = t * 0.3
        elif job.kernel in attn_like:
            t = t * 0.3
        args.append(jnp.asarray(t, jnp.dtype(dtype)))
    # Residual contract: derive residual operands from the primal args they
    # were saved from (see docstring). Length guards keep pre-residual
    # manifests loadable.
    if job.kernel == "rmsnorm_bwd" and len(args) >= 4:
        import jax

        xf = args[1].astype(jnp.float32)
        args[3] = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1) + 1e-6)
    elif job.kernel == "softmax_xent_bwd" and len(args) >= 4:
        import jax

        args[3] = jax.nn.logsumexp(args[1].astype(jnp.float32), axis=-1)
    elif job.kernel == "flash_attention_bwd" and len(args) >= 6:
        from ..kernels import ref as _ref

        # Measurement runs the variant and the tuning reference at the
        # tunable's default kwargs (causal=True, window=0) — key_extra only
        # namespaces the record — so the residuals must be derived at those
        # same defaults or the provided lse disagrees with the measured
        # score math and every config fails the correctness gate.
        o, lse = _ref.attention_res(
            args[1], args[2], args[3], causal=True, window=0)
        args[4] = o.astype(args[4].dtype)
        args[5] = lse
    return tuple(args)


def _sigterm_to_interrupt(signum, frame):
    # Fleet schedulers send SIGTERM; route it through the same manifest-flush
    # path as Ctrl-C so a preempted campaign resumes exactly.
    raise KeyboardInterrupt("SIGTERM")


def _run_one_attempt(job, tunable, seeds, search, evaluator, db, arg_seed,
                     campaign_rt, job_timeout):
    """One tuning attempt, optionally bounded by a wall-clock timeout.

    With a timeout the attempt runs on a daemon thread: Python cannot cancel
    a stuck compile, so on expiry the thread is *abandoned* (daemon ⇒ it
    cannot block process exit) and the attempt counts as failed — exactly
    the stuck-job containment a fleet needs. BaseExceptions from the job
    body (KeyboardInterrupt raised by a callback, injected crashes) are
    re-raised in the caller's thread so interrupt handling stays uniform.
    """

    def body():
        _fault_point(f"campaign.job:{job.kernel}", attempt=job.attempts)
        args = materialize_args(job, seed=arg_seed)
        with campaign_rt, _obs_span(
            "campaign.job", kernel=job.kernel, budget=job.budget
        ):
            return autotune(
                tunable, args,
                search=search, evaluator=evaluator, db=db,
                key_extra=job.key_extra, seed_configs=seeds,
            )

    if job_timeout is None:
        return body()
    box: Dict[str, object] = {}

    def run():
        try:
            box["res"] = body()
        except BaseException as e:  # noqa: BLE001 — relayed to the caller
            box["exc"] = e

    t = threading.Thread(
        target=run, daemon=True, name=f"campaign-job-{job.kernel}"
    )
    t.start()
    t.join(job_timeout)
    if t.is_alive():
        raise TimeoutError(
            f"job {job.kernel} exceeded --job-timeout {job_timeout:g}s"
        )
    if "exc" in box:
        raise box["exc"]  # type: ignore[misc]
    return box["res"]


def run_campaign(
    manifest: CampaignManifest,
    db: TuningDatabase,
    evaluator: Optional[Evaluator] = None,
    search_factory: Optional[Callable[[TuningJob], SearchAlgorithm]] = None,
    max_jobs: Optional[int] = None,
    warm_start: bool = True,
    arg_seed: int = 0,
    job_timeout: Optional[float] = None,
    max_attempts: int = 1,
) -> Dict:
    """Execute pending jobs best-first; returns the updated summary.

    `max_jobs` bounds this invocation (the rest stays pending — that is the
    resumability story, and also how tests exercise interrupt/resume).
    `search_factory` lets callers swap the per-job strategy; the default is
    coordinate descent at the job's allocated budget, the workhorse for tile
    spaces.

    Fault containment: each job gets up to `max_attempts` tries (counted in
    ``job.attempts``, persisted — the budget spans resumes) and, with
    `job_timeout`, a wall-clock bound per attempt. A job that exhausts its
    attempts is quarantined as ``status="poisoned"`` (error recorded;
    ``pending()`` skips it, so resume never re-runs a poison pill; a later
    re-plan resets it). KeyboardInterrupt/SIGTERM flush the manifest — with
    the in-flight job still pending and its attempt count banked — and bank
    telemetry before re-raising, so an interrupted campaign resumes exactly.
    """
    _register_tunables()
    evaluator = evaluator or WallClockEvaluator(repeats=3, warmup=1)
    max_attempts = max(1, int(max_attempts))
    ran = 0
    # Scoped runtime for the whole campaign: any kernel dispatch nested
    # inside variant/reference evaluation resolves against the campaign db
    # without mutating the process default (no cross-talk with a serving
    # engine or test running in the same process).
    campaign_rt = TunedRuntime(db=db, name="campaign")
    prev_sigterm = None
    if threading.current_thread() is threading.main_thread():
        try:
            prev_sigterm = signal.signal(signal.SIGTERM, _sigterm_to_interrupt)
        except (ValueError, OSError):  # pragma: no cover — exotic hosts
            prev_sigterm = None
    interrupted = False
    try:
        for job in manifest.pending():
            if max_jobs is not None and ran >= max_jobs:
                break
            ran += 1
            tunable = get_tunable(job.kernel)
            seeds = []
            if warm_start:
                seeds = warm_start_configs(
                    db, job.kernel, manifest.platform, job.arg_shapes,
                    promoted_dtype(job.arg_dtypes), job.key_extra,
                    space=tunable.space,
                )
            col = _obs_collector()
            t_job = time.perf_counter()
            while True:
                job.attempts += 1
                # Fresh strategy per attempt: a search instance carries
                # consumed-budget state, so a retry must not inherit it.
                search = (
                    search_factory(job) if search_factory
                    else CoordinateDescent(budget=job.budget, restarts=2)
                )
                try:
                    res = _run_one_attempt(
                        job, tunable, seeds, search, evaluator, db,
                        arg_seed, campaign_rt, job_timeout,
                    )
                except KeyboardInterrupt:
                    raise      # handled by the outer flush path
                except Exception as e:  # a failed job must not sink the campaign
                    job.error = f"{type(e).__name__}: {e}"
                    if job.attempts < max_attempts:
                        log.warning(
                            "job %s %s attempt %d/%d failed (%s); retrying",
                            job.kernel, job.arg_shapes, job.attempts,
                            max_attempts, job.error,
                        )
                        manifest.save()      # attempt count survives a kill
                        continue
                    job.status = "poisoned"
                    if col.enabled:
                        col.counter("campaign.jobs", status="poisoned")
                    col.warn_once(
                        "campaign.job_poisoned", key=job.db_key(manifest.platform),
                        kernel=job.kernel, attempts=job.attempts, error=job.error,
                    )
                    log.warning(
                        "job %s %s poisoned after %d attempt(s): %s",
                        job.kernel, job.arg_shapes, job.attempts, job.error,
                    )
                    break
                job.status = "done"
                job.evaluations = res.evaluations
                job.best_objective = res.best_objective
                job.default_objective = res.default_objective
                job.seeded = bool(seeds)
                job.error = ""
                if col.enabled:
                    # tune wall-time + best-vs-heuristic speedup per job,
                    # tagged by kernel family (bounded cardinality).
                    col.observe("campaign.job_s", time.perf_counter() - t_job,
                                kernel=job.kernel)
                    if res.best_objective > 0 and res.default_objective > 0:
                        col.observe("campaign.speedup",
                                    res.default_objective / res.best_objective,
                                    kernel=job.kernel)
                    col.counter("campaign.jobs", status="done")
                log.info(
                    "job %s %s: %.3g -> %.3g (%d evals%s)",
                    job.kernel, job.arg_shapes, res.default_objective,
                    res.best_objective, res.evaluations,
                    ", seeded" if seeds else "",
                )
                break
            manifest.save()                  # resume point after every job
    except KeyboardInterrupt:
        # The in-flight job keeps status="pending" (status flips only on
        # completion) and its incremented attempt count — the finally block
        # persists both, so resume picks up exactly where the interrupt hit.
        interrupted = True
        log.warning(
            "campaign interrupted; manifest flushed with in-flight job "
            "pending (%d job(s) completed this invocation)", max(0, ran - 1),
        )
        raise
    finally:
        if prev_sigterm is not None:
            signal.signal(signal.SIGTERM, prev_sigterm)
        # Bank the campaign runtime's dispatch accounting in the manifest so
        # `campaign status` can show it alongside any deployment telemetry —
        # merged with earlier invocations' counts, so a resumed (or
        # interrupted) campaign keeps the whole run's accounting.
        manifest.meta["telemetry"] = _merge_snapshots(
            manifest.meta.get("telemetry"), campaign_rt.telemetry.snapshot()
        )
        if interrupted:
            manifest.meta["interrupted"] = time.time()
        manifest.save()
    return manifest.summary()


def _merge_snapshots(prev: Optional[Dict], new: Dict) -> Dict:
    """Accumulate two Telemetry snapshots (counts add; rates recomputed)."""
    if not prev:
        return new
    out = dict(new)
    for field in ("calls", "cache_hits", "cache_evictions"):
        out[field] = prev.get(field, 0) + new.get(field, 0)
    out["cache_hit_rate"] = (
        out["cache_hits"] / out["calls"] if out.get("calls") else 0.0
    )
    tiers: Dict[str, int] = dict(prev.get("tiers", {}))
    for t, n in new.get("tiers", {}).items():
        tiers[t] = tiers.get(t, 0) + n
    out["tiers"] = tiers
    total = out.get("calls") or 1
    out["tier_rates"] = {t: n / total for t, n in tiers.items()}
    by_key = {k: dict(v) for k, v in prev.get("by_key", {}).items()}
    for k, per in new.get("by_key", {}).items():
        agg = by_key.setdefault(k, {})
        for t, n in per.items():
            agg[t] = agg.get(t, 0) + n
    out["by_key"] = by_key
    phases = {p: dict(v) for p, v in prev.get("phases", {}).items()}
    for p, per in new.get("phases", {}).items():
        agg = phases.setdefault(p, {})
        for t, n in per.items():
            agg[t] = agg.get(t, 0) + n
    out["phases"] = phases
    by_kp = {
        p: {k: dict(v) for k, v in per.items()}
        for p, per in prev.get("by_key_phase", {}).items()
    }
    for p, per in new.get("by_key_phase", {}).items():
        for k, tiers in per.items():
            agg = by_kp.setdefault(p, {}).setdefault(k, {})
            for t, n in tiers.items():
                agg[t] = agg.get(t, 0) + n
    out["by_key_phase"] = by_kp
    return out


def summarize_telemetry(snap: Dict) -> Dict:
    """Aggregate a runtime Telemetry snapshot for sustained-performance
    reporting: overall per-tier hit rates plus per-kernel tier counts and
    the exact-hit share (the fraction of dispatches served by tuned
    records — the paper's headline accounting).
    """
    calls = snap.get("calls", 0)
    tiers = dict(snap.get("tiers", {}))
    per_kernel: Dict[str, Dict[str, int]] = {}
    for key, per in snap.get("by_key", {}).items():
        agg = per_kernel.setdefault(key.split("|")[0], {})
        for tier, n in per.items():
            agg[tier] = agg.get(tier, 0) + n
    kernels = {}
    for kernel, agg in sorted(per_kernel.items()):
        total = sum(agg.values()) or 1
        kernels[kernel] = {
            "calls": sum(agg.values()),
            "tiers": dict(agg),
            "exact_share": agg.get("exact", 0) / total,
            "measured_share": sum(
                agg.get(t, 0) for t in ("exact", "tune", "cover", "override")
            ) / total,
        }
    phases = {}
    for phase, per in snap.get("phases", {}).items():
        total = sum(per.values()) or 1
        phases[phase] = {
            "calls": sum(per.values()),
            "tiers": dict(per),
            "exact_share": per.get("exact", 0) / total,
        }
    return {
        "calls": calls,
        "tier_rates": {t: n / calls for t, n in tiers.items()} if calls else {},
        "cache_hit_rate": snap.get("cache_hit_rate", 0.0),
        "cache_evictions": snap.get("cache_evictions", 0),
        "kernels": kernels,
        "phases": phases,
    }


def load_telemetry(path: str) -> Dict:
    """Load + summarize an exported snapshot (``Telemetry.write`` artifact).

    The one loader behind every ``--telemetry`` flag (campaign status,
    benchmarks/campaign_report.py); exits cleanly on a typo'd path instead
    of a traceback.
    """
    import json
    import os

    if not os.path.exists(path):
        raise SystemExit(f"error: --telemetry {path}: no such file")
    with open(path) as f:
        return summarize_telemetry(json.load(f))


def format_telemetry(summary: Dict, label: str) -> str:
    """Render a :func:`summarize_telemetry` summary (one formatter shared by
    `campaign status` and benchmarks/campaign_report.py)."""
    rates = ", ".join(
        f"{t}={100 * r:.0f}%" for t, r in sorted(summary["tier_rates"].items())
    )
    lines = [
        f"sustained performance [{label}]: {summary['calls']} dispatches "
        f"({rates}); cache hit {100 * summary['cache_hit_rate']:.0f}%, "
        f"{summary['cache_evictions']} evictions"
    ]
    for kernel, row in summary["kernels"].items():
        lines.append(f"  {kernel:<16} {row['calls']:>6} calls  "
                     f"exact {100 * row['exact_share']:.0f}%  "
                     f"measured {100 * row['measured_share']:.0f}%")
    for phase, row in sorted(summary.get("phases", {}).items()):
        lines.append(f"  phase {phase:<10} {row['calls']:>6} calls  "
                     f"exact {100 * row['exact_share']:.0f}%")
    return "\n".join(lines)


def export_campaign_db(
    db: TuningDatabase,
    out_path: str,
    platform: str,
    cover_max_size: int = 4,
) -> TuningDatabase:
    """Cluster winners into cover sets, then write the per-platform artifact."""
    compute_covers(db, platform, max_size=cover_max_size, save=bool(db.path))
    return db.export(out_path, platform=platform)
