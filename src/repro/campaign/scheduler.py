"""Campaign scheduler: dedup → prioritize → budget → resumable manifest.

Evaluation budget is the scarce resource (each evaluation is a compile+run),
so the scheduler spends it where the analytic model says time actually goes:

* **dedup** — shape bucketing means many scenarios land on the same database
  key (the 0.5B FFN gemm at train and the 27B serving prefill can share a
  bucket); tuning it twice is pure waste. Duplicate jobs merge, their
  per-step weights add, provenance is unioned.
* **priority** — per job, a first-principles roofline time (max of FLOP time
  and HBM time on the detected platform profile, the same model
  ``tools/analytic.py`` builds its step estimates from) × how often the site
  runs per step = seconds-at-stake. Jobs are tuned best-first so an
  interrupted campaign has already banked the biggest wins.
* **budget** — a global evaluation budget splits across jobs proportionally
  to priority (with a floor, so tail jobs still get a usable search).
* **manifest** — the whole schedule plus per-job execution state persists as
  JSON after every job; rerunning `campaign run` picks up exactly where the
  interrupt hit.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence

from ..core.database import atomic_write_json
from ..core.platform import HardwareProfile, detect_platform
from .planner import TuningJob

def job_roofline_seconds(job: TuningJob, profile: HardwareProfile) -> float:
    """max(FLOP time, HBM time) of one execution of the job's kernel site.

    The per-kernel-family model lives in tools/analytic.py
    (:func:`repro.tools.analytic.site_roofline_seconds`) next to the
    whole-step model, so the scheduler's priorities and the drift detector's
    %-of-roofline attribution price a site identically.
    """
    from ..tools.analytic import site_roofline_seconds

    return site_roofline_seconds(
        job.kernel, job.arg_shapes, job.arg_dtypes[0], profile
    )


def dedupe_jobs(jobs: Sequence[TuningJob], platform: str) -> List[TuningJob]:
    """Merge jobs that share a database key; weights add, scenarios union."""
    merged: Dict[str, TuningJob] = {}
    for job in jobs:
        key = job.db_key(platform)
        prev = merged.get(key)
        if prev is None:
            merged[key] = dataclasses.replace(job)
        else:
            prev.weight += job.weight
            prev.scenarios = tuple(sorted(set(prev.scenarios) | set(job.scenarios)))
    return sorted(
        merged.values(), key=lambda j: (j.kernel, j.arg_shapes, j.key_extra)
    )


def analytic_scenario_seconds(
    arch_names: Sequence[str],
    train_shapes: Sequence[str] = ("train_4k",),
    reduced: bool = False,
    profile: Optional[HardwareProfile] = None,
    chips: int = 1,
) -> Dict[str, float]:
    """Analytic step seconds per train scenario (tools/analytic.py reuse).

    This is the cross-arch weighting: a kernel job from an arch whose step
    costs 10× more wall-time deserves proportionally more tuning budget, even
    when the per-site shapes look alike.
    """
    from ..configs.base import SHAPES, get_config
    from ..tools import analytic

    profile = profile or detect_platform()
    out: Dict[str, float] = {}
    for name in arch_names:
        cfg = get_config(name)
        if reduced:
            cfg = cfg.reduced()
        for shape_name in train_shapes:
            shape = SHAPES[shape_name]
            fl = analytic.step_flops(cfg, shape)
            hbm = analytic.step_hbm_bytes(cfg, shape, chips=chips, model_par=1)
            out[f"{cfg.name}/{shape.name}"] = max(
                fl["total"] / chips / profile.peak_flops_bf16,
                hbm["total"] / profile.hbm_bandwidth,
            )
    return out


def prioritize_jobs(
    jobs: Sequence[TuningJob],
    profile: Optional[HardwareProfile] = None,
    scenario_seconds: Optional[Dict[str, float]] = None,
) -> List[TuningJob]:
    """Rank by seconds-at-stake: per-site roofline time × per-step weight.

    With `scenario_seconds` (see :func:`analytic_scenario_seconds`), each
    job's stake is additionally scaled by the share of total analytic step
    time its scenarios account for, so budget flows toward the archs where
    wall-time actually goes.
    """
    profile = profile or detect_platform()
    total_scen = sum(scenario_seconds.values()) if scenario_seconds else 0.0
    out = []
    for job in jobs:
        j = dataclasses.replace(job)
        j.priority = job_roofline_seconds(j, profile) * max(j.weight, 1e-9)
        if scenario_seconds and total_scen > 0:
            known = [scenario_seconds[s] for s in j.scenarios if s in scenario_seconds]
            if known:
                j.priority *= sum(known) / total_scen * len(scenario_seconds)
        out.append(j)
    out.sort(key=lambda j: (-j.priority, j.kernel, j.arg_shapes, j.key_extra))
    return out


def allocate_budget(
    jobs: Sequence[TuningJob],
    total_budget: int,
    min_budget: int = 6,
    max_budget: int = 128,
) -> List[TuningJob]:
    """Split a global evaluation budget across jobs proportionally to priority.

    Every funded job gets at least `min_budget` evaluations (a search below
    that cannot even sweep one knob); if the total cannot fund all jobs at
    the floor, the lowest-priority tail is deferred (budget 0, skipped by the
    runner but kept in the manifest so a bigger budget can revive them).
    """
    jobs = list(jobs)
    n_funded = max(0, min(len(jobs), total_budget // min_budget))
    funded, deferred = jobs[:n_funded], jobs[n_funded:]
    total_pri = sum(j.priority for j in funded) or 1.0
    remaining = total_budget - min_budget * len(funded)
    for j in funded:
        extra = int(remaining * (j.priority / total_pri))
        j.budget = min(max_budget, min_budget + extra)
    # Redistribute what the max_budget clamp (and int truncation) stranded:
    # fill best-first so the requested global budget is actually spent.
    leftover = total_budget - sum(j.budget for j in funded)
    for j in funded:
        if leftover <= 0:
            break
        add = min(max_budget - j.budget, leftover)
        j.budget += add
        leftover -= add
    for j in deferred:
        j.budget = 0
    return funded + deferred


@dataclasses.dataclass
class CampaignManifest:
    """The persisted campaign: schedule + execution state, atomic on disk."""

    path: Optional[str]
    platform: str
    jobs: List[TuningJob]
    created: float = dataclasses.field(default_factory=time.time)
    total_budget: int = 0
    meta: Dict = dataclasses.field(default_factory=dict)

    def save(self) -> None:
        if not self.path:
            return
        blob = {
            "version": 1,
            "platform": self.platform,
            "created": self.created,
            "total_budget": self.total_budget,
            "meta": self.meta,
            "jobs": [j.to_json() for j in self.jobs],
        }
        atomic_write_json(self.path, blob)

    @staticmethod
    def load(path: str) -> "CampaignManifest":
        with open(path) as f:
            blob = json.load(f)
        return CampaignManifest(
            path=path,
            platform=blob["platform"],
            jobs=[TuningJob.from_json(j) for j in blob["jobs"]],
            created=blob.get("created", 0.0),
            total_budget=blob.get("total_budget", 0),
            meta=blob.get("meta", {}),
        )

    # -- queries --------------------------------------------------------------
    def pending(self) -> List[TuningJob]:
        """Runnable jobs, best-first (priority already baked into order)."""
        out = [j for j in self.jobs if j.status == "pending" and j.budget > 0]
        out.sort(key=lambda j: -j.priority)
        return out

    def counts(self) -> Dict[str, int]:
        # "poisoned": exhausted its retry budget (runner max_attempts) —
        # quarantined; pending() skips it, `campaign status` reports it.
        # "failed" survives for manifests written before retry support.
        out: Dict[str, int] = {
            "pending": 0, "done": 0, "failed": 0, "poisoned": 0, "deferred": 0,
        }
        for j in self.jobs:
            if j.status == "pending" and j.budget == 0:
                out["deferred"] += 1
            else:
                out[j.status] = out.get(j.status, 0) + 1
        return out

    def summary(self) -> Dict:
        done = [j for j in self.jobs if j.status == "done"]
        spent = sum(j.evaluations for j in self.jobs)
        speedups = [
            j.default_objective / j.best_objective
            for j in done
            if j.best_objective > 0 and j.default_objective > 0
        ]
        legality = self.meta.get("legality") or {}
        return {
            "platform": self.platform,
            "jobs": len(self.jobs),
            **self.counts(),
            "evaluations_spent": spent,
            "total_budget": self.total_budget,
            "mean_speedup": (sum(speedups) / len(speedups)) if speedups else 0.0,
            "seeded_jobs": sum(1 for j in done if j.seeded),
            "configs_pruned": sum(v.get("pruned", 0) for v in legality.values()),
        }


def plan_legality(
    jobs: Sequence[TuningJob], profile: Optional[HardwareProfile] = None
) -> Dict[str, Dict[str, int]]:
    """Per-kernel static-legality counts for the plan's config spaces.

    For every distinct kernel in the plan that declares an abstract grid
    model (:mod:`repro.core.gridmodel`), count how many of its space's
    configs are statically illegal on this platform — those never reach
    compile+run (the tuner's pre-pass prunes them), so the budget the
    scheduler allocates is effectively spread over ``legal`` configs only.
    ``campaign status`` surfaces these counts.
    """
    from ..core.gridmodel import registered_models, space_report

    profile = profile or detect_platform()
    models = registered_models()
    out: Dict[str, Dict[str, int]] = {}
    for kernel in sorted({j.kernel for j in jobs}):
        if kernel not in models:
            continue
        r = space_report(kernel, profile)
        out[kernel] = {
            "total": r["total"],
            "legal": r["legal"],
            "pruned": r["illegal"],
        }
    return out


def manifest_missing_bwd(manifest: CampaignManifest) -> bool:
    """True when a sharding-aware training manifest predates the tuned
    backward plane: it carries ``@dp`` training scenarios (the
    ``plan_training_jobs`` marker) but not a single ``*_bwd`` kernel row.

    Such manifests were planned when the roster stopped at the forward
    pass — running one banks a forward-only database, so the train step's
    gradient sites resolve at warm-start/cover/heuristic tiers and never
    ExactHit. ``campaign run`` refuses them with a re-plan instruction
    unless ``--allow-missing-bwd`` is passed. Shape-level (no-mesh) and
    serving manifests are forward-only by design and are not flagged.
    """
    has_train_mesh = any(
        any("@dp" in s for s in j.scenarios) for j in manifest.jobs
    )
    if not has_train_mesh or manifest.meta.get("bwd_roster"):
        return False
    return not any(j.kernel.endswith("_bwd") for j in manifest.jobs)


def build_manifest(
    jobs: Sequence[TuningJob],
    total_budget: int,
    path: Optional[str] = None,
    platform: Optional[str] = None,
    profile: Optional[HardwareProfile] = None,
    min_budget: int = 6,
    max_budget: int = 128,
    scenario_seconds: Optional[Dict[str, float]] = None,
) -> CampaignManifest:
    """plan output → deduped, prioritized, budgeted, persisted schedule."""
    profile = profile or detect_platform()
    platform = platform or profile.name
    scheduled = allocate_budget(
        prioritize_jobs(dedupe_jobs(jobs, platform), profile, scenario_seconds),
        total_budget, min_budget=min_budget, max_budget=max_budget,
    )
    m = CampaignManifest(
        path=path, platform=platform, jobs=list(scheduled), total_budget=total_budget
    )
    # Stamp whether this plan carries the tuned backward roster, so resume
    # can tell a deliberately forward-only plan from a stale pre-bwd one.
    m.meta["bwd_roster"] = any(j.kernel.endswith("_bwd") for j in scheduled)
    # Stamp per-kernel static-legality counts (configs the tuner will prune
    # before measurement), so `campaign status` can report them offline.
    try:
        m.meta["legality"] = plan_legality(scheduled, profile)
    except Exception:                                 # pragma: no cover
        pass                          # legality stamping must never block a plan
    m.save()
    return m
