"""Transfer layer: warm starts from neighbours + 'few fit most' cover sets.

Two observations make tuning campaigns cheap and their databases general:

* **warm starts** — the winning config for a kernel varies smoothly with the
  shape bucket (Figure 1 of the paper shows dependence, not chaos), so the
  nearest tuned neighbour — same kernel on the closest bucket, or the same
  bucket on a sibling platform — is an excellent first evaluation. Seeded
  local search converges in a fraction of a cold search's evaluations.
* **cover sets** — after a campaign, the distinct winners per kernel are few
  ("A Few Fit Most", Hochgraf & Pai 2025): clustering records by winning
  config yields a handful of entries that cover most tuned buckets. Shipping
  that cover set inside the database gives *unseen* shapes a measured
  fallback that beats the analytical heuristic, with zero serve-time tuning.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.database import (
    Record,
    TuningDatabase,
    shape_bucket,
    shape_distance,
    split_key,
)
from ..core.params import Config, ParamSpace


def warm_start_configs(
    db: TuningDatabase,
    kernel: str,
    platform: str,
    arg_shapes: Sequence[Sequence[int]],
    dtype: str,
    key_extra: str = "",
    space: Optional[ParamSpace] = None,
    k: int = 3,
) -> List[Config]:
    """Up to `k` seed configs from the nearest existing records.

    Ranking: same (platform, dtype, extra) by shape distance first, then
    same-platform records regardless of dtype/extra, then sibling platforms
    (a TPU winner is still a far better guess on a new TPU generation than
    the space default). The exact target key is excluded — that case is a
    plain database hit, not a transfer.

    `dtype` must be the *promoted* dtype of the call's array args (see
    :func:`repro.core.tuner.promoted_dtype`) — database keys are stored
    under it, so passing a single argument's dtype would silently demote
    every tier-0 candidate to tier-1. Pre-promotion records (keyed by the
    last arg's dtype) still rank as tier-1 neighbours, which is exactly the
    migration path: an old database warm-starts the re-tune that rebuilds
    its records under the new keys.
    """
    target_shapes = tuple(shape_bucket(s) for s in arg_shapes)
    scored: List[Tuple[Tuple[int, float, float], Config]] = []
    for rec in db.records():
        r_kernel, r_platform, r_shapes, r_dtype, r_extra = split_key(rec.key)
        if r_kernel != kernel:
            continue
        dist = shape_distance(target_shapes, r_shapes)
        if r_platform == platform and r_dtype == dtype and r_extra == key_extra:
            if dist == 0.0:
                continue                      # exact key = db hit, not transfer
            tier = 0
        elif r_platform == platform:
            tier = 1
        else:
            tier = 2
        if math.isinf(dist):
            continue
        scored.append(((tier, dist, rec.objective), dict(rec.config)))
    scored.sort(key=lambda t: t[0])

    out: List[Config] = []
    seen = set()
    for _, cfg in scored:
        if space is not None and not space.is_valid(cfg):
            continue
        key = ParamSpace.config_key(cfg)
        if key in seen:
            continue
        seen.add(key)
        out.append(cfg)
        if len(out) >= k:
            break
    return out


def cluster_winners(
    records: Sequence[Record],
    max_size: int = 4,
    coverage: float = 0.95,
) -> List[Dict]:
    """Cluster records by winning config into a ranked cover set.

    Greedy set cover on exact config identity: take the config that wins the
    most buckets, then the next, until `coverage` of the records are covered
    or `max_size` entries exist. Each entry carries its supporting shape
    buckets so lookup can route an unseen shape to its nearest cluster.
    """
    if not records:
        return []
    groups: Dict[str, Dict] = {}
    for rec in records:
        ck = ParamSpace.config_key(rec.config)
        g = groups.setdefault(ck, {"config": dict(rec.config), "support": []})
        g["support"].append([list(s) for s in split_key(rec.key)[2]])
    ranked = sorted(groups.values(), key=lambda g: -len(g["support"]))
    total = len(records)
    out: List[Dict] = []
    covered = 0
    for g in ranked:
        if len(out) >= max_size or covered / total >= coverage:
            break
        covered += len(g["support"])
        out.append({
            "config": g["config"],
            "support": g["support"],
            "share": len(g["support"]) / total,
        })
    return out


def compute_covers(
    db: TuningDatabase,
    platform: str,
    max_size: int = 4,
    save: bool = True,
) -> Dict[str, List[Dict]]:
    """Cluster every kernel's winners on `platform` and store the cover sets."""
    by_kernel: Dict[str, List[Record]] = {}
    for rec in db.records():
        kernel, r_platform, _, _, _ = split_key(rec.key)
        if r_platform == platform:
            by_kernel.setdefault(kernel, []).append(rec)
    covers: Dict[str, List[Dict]] = {}
    for kernel, recs in sorted(by_kernel.items()):
        entries = cluster_winners(recs, max_size=max_size)
        if entries:
            db.put_cover(kernel, platform, entries, save=False)
            covers[kernel] = entries
    if save:
        db.save()
    return covers
