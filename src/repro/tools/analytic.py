"""First-principles FLOP / HBM-traffic model per (arch × shape × layout).

Why analytic: XLA's `cost_analysis()` visits while-loop bodies ONCE, so a
scanned 62-layer model reports ~1/62 of its real FLOPs — useless for
roofline. The collective term is recovered from the HLO with the trip-aware
parser (core.evaluate.collective_stats); compute and memory terms come from
this model. All coefficients are explicit and documented inline; the model
is validated against the HLO counters on an *unscanned* single-layer lower
in tests/test_analytic.py (agreement to within a few % on FLOPs).

Conventions: FLOPs count multiply-adds as 2; all byte counts are per chip;
`T` denotes processed tokens (B·S for train/prefill, B for one decode step).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from ..configs.base import ArchConfig, LayerSpec, ShapeSpec
from ..core.platform import TPU_V5E, HardwareProfile

# Backward pass costs 2× forward (grad wrt activations + weights); remat
# adds recompute of the forward inside backward.
_BWD_MULT = {"none": 3.0, "dots": 3.3, "full": 4.0}

# Activation HBM-traffic coefficient: bytes moved per (token × d_model) per
# layer, in units of activation dtype bytes. Counts residual read/write (4),
# norm read/write (2), mixer in/out (2), ffn in/out (2) ≈ 10; MoE adds the
# dispatch/combine buffers (+4); SSM mixers stream state chunks (+2).
_ACT_COEFF = {"dense": 10.0, "moe": 14.0, "ssm": 12.0}


def _ffn_mats(kind: str) -> int:
    return 3 if kind in ("swiglu", "geglu") else 2


def _layer_fwd_flops(cfg: ArchConfig, spec: LayerSpec, T: float, ctx: float) -> float:
    """Forward FLOPs of one layer over T tokens with ctx effective context."""
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.num_heads, cfg.num_kv_heads
    f = 0.0
    if spec.mixer == "attn":
        f += 2 * T * d * 2 * hd * (H + KV)            # qkvo projections
        eff = min(spec.window, ctx) if spec.window else ctx
        f += 2 * T * eff * H * hd * 2                  # qk^T + p@v
    elif spec.mixer == "mamba":
        di = cfg.mamba_expand * d
        ds = cfg.mamba_d_state
        dtr = max(1, math.ceil(d / 16))
        f += 2 * T * d * 2 * di                        # in_proj
        f += 2 * T * di * 4                            # conv (k=4 taps)
        f += 2 * T * di * (dtr + 2 * ds)               # x_proj
        f += 2 * T * dtr * di                          # dt_proj
        f += 12 * T * di * ds                          # scan + C reduce
        f += 2 * T * di * d                            # out_proj
    elif spec.mixer == "mlstm":
        di = 2 * d
        hdm = di // cfg.num_heads
        c = 64                                          # chunk (run default)
        f += 2 * T * d * 2 * di + 3 * 2 * T * di * di  # in_proj + qkv
        f += 4 * T * c * di                             # intra-chunk
        f += 8 * T * di * hdm                           # inter + state update
        f += 2 * T * di * d                             # out_proj
    elif spec.mixer == "slstm":
        hd_s = d // cfg.num_heads
        ff_s = ((4 * d // 3 + 63) // 64) * 64
        f += 2 * T * d * 4 * d                          # gate projections
        f += 2 * T * d * 4 * hd_s                       # block-diag recurrence
        f += 20 * T * d                                 # cell element-wise
        f += 2 * T * d * ff_s * 3                       # post-GeGLU MLP
    # FFN
    if spec.ffn != "none":
        mats = _ffn_mats(cfg.ffn_kind)
        if "moe" in spec.ffn:
            f += 2 * T * d * cfg.num_experts              # router
            f += (2 * T * d * cfg.d_ff * mats
                  * cfg.experts_per_token * cfg.capacity_factor)
        if spec.ffn in ("dense", "moe+dense"):
            f += 2 * T * d * cfg.d_ff * mats
    return f


def _all_layers(cfg: ArchConfig):
    for seg in cfg.segments():
        for _ in range(seg.repeats):
            for spec in seg.pattern:
                yield spec


def step_flops(cfg: ArchConfig, shape: ShapeSpec, remat: str = "dots") -> Dict[str, float]:
    """Total math FLOPs of one step (all chips)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        T, ctx = float(B), float(S)
    else:
        T, ctx = float(B) * S, (S + 1) / 2.0
    fwd = sum(_layer_fwd_flops(cfg, spec, T, ctx) for spec in _all_layers(cfg))
    if shape.kind == "train":
        fwd += 2 * T * cfg.d_model * cfg.vocab_size       # lm head
        total = fwd * _BWD_MULT[remat]
    elif shape.kind == "prefill":
        fwd += 2 * B * cfg.d_model * cfg.vocab_size       # last-position logits
        total = fwd
    else:
        fwd += 2 * T * cfg.d_model * cfg.vocab_size
        total = fwd
    return {"fwd": fwd, "total": total}


def step_hbm_bytes(
    cfg: ArchConfig,
    shape: ShapeSpec,
    chips: int,
    model_par: int = 16,
    fsdp: bool = False,
    remat: str = "dots",
    fused_xent: bool = False,
    params: Optional[int] = None,
    dtype_bytes: int = 2,
) -> Dict[str, float]:
    """Per-chip HBM traffic of one step (bytes)."""
    from ..models import lm as lm_mod

    P = params if params is not None else lm_mod.param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    data_par = max(1, chips // model_par)
    p_local = P / model_par * dtype_bytes          # weights touched per chip
    n_opt_local = P / model_par / (data_par if fsdp else 1)

    if shape.kind == "train":
        T_local = B * S / data_par
        w_reads = {"none": 2, "dots": 2, "full": 3}[remat]
        weights = w_reads * p_local
        grads = 2 * 4 * n_opt_local                 # fp32 write + read
        opt = 6 * 4 * n_opt_local + 2 * n_opt_local  # m,v,master r/w + param w
        kind = "moe" if cfg.num_experts else ("ssm" if cfg.family in ("ssm", "hybrid") else "dense")
        acts = (
            cfg.num_layers * T_local * cfg.d_model * dtype_bytes * _ACT_COEFF[kind]
        )
        # logits are vocab-sharded over the model axis (lm_head P(None,model))
        logits = (
            0.0 if fused_xent
            else 4 * T_local * cfg.vocab_size / model_par * dtype_bytes
        )
        total = weights + grads + opt + acts + logits
        return {
            "weights": weights, "grads": grads, "opt": opt,
            "activations": acts, "logits": logits, "total": total,
        }

    if shape.kind == "prefill":
        T_local = B * S / data_par
        kind = "moe" if cfg.num_experts else ("ssm" if cfg.family in ("ssm", "hybrid") else "dense")
        weights = p_local
        acts = cfg.num_layers * T_local * cfg.d_model * dtype_bytes * (
            _ACT_COEFF[kind] * 0.6  # no backward traffic
        )
        cache = _cache_bytes(cfg, B, S, chips, model_par)
        total = weights + acts + cache
        return {"weights": weights, "activations": acts, "cache": cache, "total": total}

    # decode: weight streaming + cache read/write dominate
    frac_experts = 1.0
    if cfg.num_experts:
        frac_experts = min(1.0, B * cfg.experts_per_token / cfg.num_experts)
    # split params into expert vs non-expert for the read fraction
    from ..models import lm as _lm
    total_p = P
    active_share = 1.0
    if cfg.num_experts:
        expert_p = total_p - _lm.active_param_count(cfg)
        expert_p = expert_p / (1 - cfg.experts_per_token / cfg.num_experts)
        non_expert = total_p - expert_p
        read_p = non_expert + expert_p * frac_experts
    else:
        read_p = total_p
    weights = read_p / model_par * dtype_bytes
    cache = 2 * _cache_bytes(cfg, B, S, chips, model_par)   # read + write slot
    total = weights + cache
    return {"weights": weights, "cache": cache, "total": total}


def _cache_bytes(cfg: ArchConfig, B: int, S: int, chips: int, model_par: int,
                 dtype_bytes: int = 2) -> float:
    """Per-chip bytes of the full KV/state cache."""
    total = 0.0
    for spec in _all_layers(cfg):
        if spec.mixer == "attn":
            clen = min(spec.window, S) if spec.window else S
            total += 2 * B * clen * cfg.num_kv_heads * cfg.hd * dtype_bytes
        elif spec.mixer == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            total += B * di * (cfg.mamba_d_state + 3) * 4
        elif spec.mixer == "mlstm":
            di = 2 * cfg.d_model
            hd = di // cfg.num_heads
            total += B * cfg.num_heads * (hd * hd + hd + 1) * 4
        elif spec.mixer == "slstm":
            total += 4 * B * cfg.d_model * 4
    # cache shards over batch (data axes) and kv/feature (model axis) dims —
    # i.e. over all chips (see distributed.sharding.cache_shardings)
    return total / chips


_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4, "int64": 8}


def _prod(seq) -> float:
    out = 1.0
    for x in seq:
        out *= x
    return out


def site_roofline_seconds(
    kernel: str,
    arg_shapes: Tuple[Tuple[int, ...], ...],
    dtype: str,
    profile: HardwareProfile,
) -> float:
    """max(FLOP time, HBM time) of one execution of a single kernel site.

    The per-site counterpart of the whole-step model above (same modelling
    discipline: multiply-add = 2 FLOPs, explicit byte counts), specialized
    to the tuned kernel families. The campaign scheduler prices jobs with it
    (seconds-at-stake ordering) and the drift detector uses it as the
    hardware bound a tuned record is attributed against (%-of-roofline).
    """
    sh = arg_shapes
    dt = _DTYPE_BYTES.get(dtype, 4)
    if kernel == "matmul" and len(sh) >= 2 and len(sh[0]) == 2:
        m, k = sh[0]
        n = sh[1][1]
        flops = 2.0 * m * k * n
        mem = (m * k + k * n + m * n) * dt
    elif kernel == "rmsnorm":
        rows, d = sh[0]
        flops = 4.0 * rows * d                       # square, mean, rsqrt-mul, scale
        mem = 2.0 * rows * d * dt                    # one read + one write
    elif kernel == "rmsnorm_bwd":
        rows, d = sh[0]                              # ct leads, x-shaped
        # saved inv-rms residual: no norm recompute, one reduction + dx combine
        flops = 6.0 * rows * d
        mem = 3.0 * rows * d * dt                    # ct + x read, dx write
    elif kernel == "softmax_xent":
        rows, vocab = sh[0]
        flops = 6.0 * rows * vocab                   # max/exp/sum + label gather
        mem = rows * vocab * dt                      # single streamed read
    elif kernel == "softmax_xent_bwd":
        rows, vocab = sh[1]                          # ct[rows] leads; logits 2nd
        # saved lse residual: (p − onehot)·ct in a single logits pass
        flops = 5.0 * rows * vocab
        mem = 2.0 * rows * vocab * dt                # one logits read + dl write
    elif kernel in ("flash_attention", "attn_chunks"):
        b, h, s, hd = sh[0]
        flops = 2.0 * 2.0 * b * h * s * (s / 2.0) * hd   # qk^T + p@v, causal half
        mem = (sum(_prod(x) for x in sh) + _prod(sh[0])) * dt  # q,k,v read + o write
    elif kernel == "flash_attention_bwd":
        b, h, s, hd = sh[0]                          # ct leads, q-shaped
        # residual-threaded: dq + dkv passes rebuild p from the saved lse —
        # the forward-recompute pass is gone: ~2× fwd
        flops = 4.0 * 2.0 * b * h * s * (s / 2.0) * hd
        mem = (2.0 * sum(_prod(x) for x in sh[1:4]) + 4.0 * _prod(sh[0])) * dt
    elif kernel == "matmul_bias_act" and len(sh) >= 2 and len(sh[0]) == 2:
        m, k = sh[0]                                 # gemm + fused epilogue:
        n = sh[1][1]                                 # bias add + activation
        flops = 2.0 * m * k * n + 4.0 * m * n
        mem = (m * k + k * n + n + m * n) * dt       # no [m, n] round-trip
    elif kernel == "rmsnorm_matmul" and len(sh) >= 3 and len(sh[2]) == 2:
        rows, d = sh[0]                              # fused norm epilogue on
        n = sh[2][1]                                 # the gemm's x operand
        flops = 2.0 * rows * d * n + 4.0 * rows * d
        mem = (rows * d + d + d * n + rows * n) * dt  # x read once, no xn trip
    elif kernel == "expert_gemm" and len(sh) >= 2 and len(sh[0]) == 3:
        e, c, k = sh[0]                              # grouped matmul roofline
        n = sh[1][2]
        flops = 2.0 * e * c * k * n
        mem = e * (c * k + k * n + c * n) * dt
    elif kernel in ("ssm_scan", "ssm_scan_bwd"):
        # Selective scan: per step, one dA/dBx coefficient build + one
        # state update + one C-contraction over [di, ds] — bandwidth-bound
        # (state streams through VMEM; ~6 fp32 ops per h element).
        off = 2 if kernel == "ssm_scan_bwd" else 0   # ct_y, ct_h lead in bwd
        b, s, di = sh[off]
        ds_ = sh[off + 2][2]
        flops = 6.0 * b * s * di * ds_
        mem = (sum(_prod(x) for x in sh) + 2.0 * _prod(sh[off])) * 4
        if kernel == "ssm_scan_bwd":                 # fwd recompute + grads
            flops *= 3.0
            mem *= 2.0
    elif kernel in ("ssm_update", "ssm_update_bwd"):
        off = 2 if kernel == "ssm_update_bwd" else 0
        b, di = sh[off]
        ds_ = sh[off + 2][1]
        flops = 6.0 * b * di * ds_
        mem = (sum(_prod(x) for x in sh) + _prod(sh[-1])) * 4
        if kernel == "ssm_update_bwd":
            flops *= 3.0
            mem *= 2.0
    else:
        elems = sum(_prod(s) for s in sh)
        flops = 2.0 * elems
        mem = elems * dt * 2
    return max(flops / profile.peak_flops_bf16, mem / profile.hbm_bandwidth)


@dataclasses.dataclass
class AnalyticRoofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / compiled-compute FLOPs (per brief §Roofline)."""
        tot = self.flops_per_chip * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOP time / bound step time, ≤ 1."""
        ideal = self.model_flops / self.chips / TPU_V5E.peak_flops_bf16
        return min(1.0, ideal / self.step_time_s) if self.step_time_s else 0.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self) | {
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# Wire-byte factor per collective kind (ring schedules): an all-reduce moves
# ~2× the payload per device; gather/scatter kinds ~1×.
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def analytic_roofline(
    cfg: ArchConfig,
    shape: ShapeSpec,
    chips: int,
    collective_bytes_by_kind: Dict[str, float],
    model_par: int = 16,
    fsdp: bool = False,
    remat: str = "dots",
    fused_xent: bool = False,
    profile: HardwareProfile = TPU_V5E,
    params: Optional[int] = None,
    active_params: Optional[int] = None,
) -> AnalyticRoofline:
    from ..models import lm as lm_mod

    n_active = active_params if active_params is not None else lm_mod.active_param_count(cfg)
    fl = step_flops(cfg, shape, remat)
    hbm = step_hbm_bytes(cfg, shape, chips, model_par, fsdp, remat, fused_xent,
                         params=params)
    wire = sum(
        v * _WIRE_FACTOR.get(k, 1.0) for k, v in collective_bytes_by_kind.items()
    )
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    return AnalyticRoofline(
        compute_s=fl["total"] / chips / profile.peak_flops_bf16,
        memory_s=hbm["total"] / profile.hbm_bandwidth,
        collective_s=wire / profile.ici_bandwidth,
        flops_per_chip=fl["total"] / chips,
        hbm_bytes_per_chip=hbm["total"],
        collective_bytes_per_chip=wire,
        model_flops=model_flops,
        chips=chips,
    )
