from . import analytic
