"""`python -m repro.obs` — render, diff, and drift-check observability data.

    repro.obs report --metrics metrics.json [--events 10]
    repro.obs report --drift --db tuning.json [--platform cpu]
                     [--threshold 1.5] [--live live.json]
    repro.obs diff a.json b.json

`report` renders a `--metrics-out` snapshot; with `--drift` it runs the
replay probe against a tuning database (or consumes `--live` key→seconds
timings) and prints the ranked `campaign drift` report. `diff` compares two
snapshots — canary vs suspect — and names the shifted histograms.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .export import (
    diff_snapshots,
    format_diff,
    format_snapshot,
    load_snapshot,
)


def cmd_report(ns: argparse.Namespace) -> int:
    if not ns.drift and not ns.metrics:
        print("error: report needs --metrics and/or --drift", file=sys.stderr)
        return 2
    if ns.metrics:
        snap = load_snapshot(ns.metrics)
        print(format_snapshot(snap, max_events=ns.events))
    if ns.drift:
        if not ns.db:
            print("error: --drift needs --db tuning.json", file=sys.stderr)
            return 2
        from ..core.database import TuningDatabase
        from .drift import drift_report, format_drift

        db = TuningDatabase(ns.db)
        live = None
        if ns.live:
            with open(ns.live) as f:
                live = {k: float(v) for k, v in json.load(f).items()}
        entries = drift_report(
            db, platform=ns.platform, threshold=ns.threshold, live=live,
            seed=ns.seed,
        )
        print(format_drift(entries, threshold=ns.threshold))
        if ns.json_out:
            from ..core.database import atomic_write_json

            atomic_write_json(ns.json_out, {
                "threshold": ns.threshold,
                "entries": [e.to_json() for e in entries],
            })
        if ns.fail_on_drift and any(e.regressed for e in entries):
            return 1
    return 0


def cmd_diff(ns: argparse.Namespace) -> int:
    a = load_snapshot(ns.a)
    b = load_snapshot(ns.b)
    print(format_diff(diff_snapshots(a, b)))
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.obs", description="observability reports over snapshots"
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="render a metrics snapshot / drift check")
    rep.add_argument("--metrics", help="metrics snapshot (a --metrics-out file)")
    rep.add_argument("--events", type=int, default=0,
                     help="also print the last N span events")
    rep.add_argument("--drift", action="store_true",
                     help="run the drift detector against a tuning db")
    rep.add_argument("--db", help="tuning database for --drift")
    rep.add_argument("--platform", help="restrict drift to one platform key")
    rep.add_argument("--threshold", type=float, default=1.5,
                     help="slowdown factor that flags a site as regressed")
    rep.add_argument("--live", help="JSON {key: seconds} instead of replaying")
    rep.add_argument("--seed", type=int, default=0,
                     help="replay-probe tensor seed")
    rep.add_argument("--json-out", help="also write the drift entries as JSON")
    rep.add_argument("--fail-on-drift", action="store_true",
                     help="exit 1 when any site is flagged regressed")
    rep.set_defaults(fn=cmd_report)

    dif = sub.add_parser("diff", help="compare two metrics snapshots (b - a)")
    dif.add_argument("a")
    dif.add_argument("b")
    dif.set_defaults(fn=cmd_diff)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    ns = build_parser().parse_args(argv)
    return ns.fn(ns)


if __name__ == "__main__":
    raise SystemExit(main())
