import os
import sys

from .cli import main

if __name__ == "__main__":
    try:
        rc = main()
        sys.stdout.flush()
    except BrokenPipeError:
        # Downstream pager/`head` closed the pipe — the unix-conventional
        # exit, not a traceback. Dup devnull over stdout so the interpreter
        # shutdown flush doesn't raise again.
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        rc = 141  # 128 + SIGPIPE
    raise SystemExit(rc)
