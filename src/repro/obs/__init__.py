"""repro.obs — the sustained-performance observability plane.

The tuning campaign proves *peak* performance at tune time; this package
proves it is *sustained* under live traffic — the other half of the paper's
claim. Three coordinated layers, all scoped/thread-isolated the same way the
dispatch runtime is:

* **tracing** (:mod:`.trace`) — ``obs.span("train.step")`` context managers
  build a contextvar-scoped span tree; each span lands in a log-bucketed
  latency histogram plus a bounded ring buffer of structured events, and may
  opt into ``jax.profiler.TraceAnnotation`` so spans appear in XLA profiles.
* **metrics** (:mod:`.metrics` via :class:`.ObsCollector`) — counters,
  gauges, and log-bucketed histograms (p50/p95/p99 without unbounded
  memory), recorded at the hot paths: dispatch resolution (per-tier latency,
  cache hit/miss), trainer step phases, serving engine ticks, and campaign
  jobs.
* **drift** (:mod:`.drift`) — compares live per-site timings against the
  database's measured records and the per-site roofline model
  (``tools/analytic.py``), attributing every dispatch site to
  %%-of-tuned-best and %%-of-roofline and ranking the regressions: the
  re-tune trigger input for the future ``BackgroundTune`` tier.

Overhead contract: the *default* collector is disabled, and every recording
path begins with a single ``enabled`` check — a kernel-mode train step under
a disabled (or default-sampled) collector regresses by <2%% (<5%%), enforced
by ``benchmarks/obs_overhead.py`` in CI.

Scoping mirrors ``repro.runtime``::

    import repro.obs as obs

    with obs.collect(name="serve") as col:
        with obs.span("serve.drain"):
            engine.serve()
    col.write("metrics.json")                   # python -m repro.obs report

Exports: JSON snapshot (``write``), JSONL event sink (``write_jsonl``),
Prometheus textfile (``write_prom``); ``python -m repro.obs report/diff``
renders and compares snapshots, ``report --drift`` runs the drift detector.
"""
from .collect import (  # noqa: F401
    Event,
    ObsCollector,
    collect,
    counter,
    current_collector,
    enabled,
    event,
    gauge,
    observe,
    warn_once,
)
from .metrics import Counter, Gauge, Histogram  # noqa: F401
from .trace import current_span, span  # noqa: F401

__all__ = [
    "Counter",
    "Event",
    "Gauge",
    "Histogram",
    "ObsCollector",
    "collect",
    "counter",
    "current_collector",
    "current_span",
    "enabled",
    "event",
    "gauge",
    "observe",
    "span",
    "warn_once",
]
