"""Metric primitives: counters, gauges, log-bucketed latency histograms.

The histogram is the piece that earns its keep: serving latencies and
dispatch-resolution times need p50/p95/p99 over unbounded streams, but an
engine serving millions of requests cannot keep every sample. Log-spaced
buckets (4 per octave, ~9% relative error at the bucket midpoint) give
quantiles in O(buckets) memory regardless of stream length — the standard
HDR/Prometheus trade, sized for microseconds-to-minutes latencies.

These classes are deliberately lock-free: the owning
:class:`repro.obs.collect.ObsCollector` serializes mutation under its own
lock, so the primitives stay cheap enough for hot paths.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

# 4 buckets per octave: bucket i covers [GROWTH**i, GROWTH**(i+1)).
_GROWTH = 2.0 ** 0.25
_LOG_GROWTH = math.log(_GROWTH)
# Values at or below this floor share one underflow bucket (index _MIN_IDX):
# nothing we time is meaningfully below a nanosecond.
_MIN_IDX = -120


class Counter:
    """Monotonic count (events, tokens, dispatches)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def add(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-written value (queue depth, slot occupancy, tokens/s)."""

    __slots__ = ("value", "updates")
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0
        self.updates = 0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.updates += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"value": self.value, "updates": self.updates}


class Histogram:
    """Log-bucketed distribution with O(buckets) memory quantiles."""

    __slots__ = ("count", "sum", "min", "max", "_buckets")
    kind = "histogram"

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}

    @staticmethod
    def _index(v: float) -> int:
        if v <= 0.0:
            return _MIN_IDX
        return max(_MIN_IDX, int(math.floor(math.log(v) / _LOG_GROWTH)))

    @staticmethod
    def _midpoint(idx: int) -> float:
        if idx <= _MIN_IDX:
            return 0.0
        return _GROWTH ** (idx + 0.5)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        idx = self._index(v)
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (bucket geometric midpoint, clamped to the
        observed min/max so tiny samples don't report beyond the data)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if seen >= target:
                return min(max(self._midpoint(idx), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram in (snapshot merging across resumed runs)."""
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for idx, n in other._buckets.items():
            self._buckets[idx] = self._buckets.get(idx, 0) + n

    def snapshot(self) -> Dict[str, Any]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


def tags_key(tags: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    """Canonical (sorted, stringified) form of a tag set — the registry key."""
    return tuple(sorted((k, str(v)) for k, v in tags.items()))


def render_tags(key: Tuple[Tuple[str, str], ...]) -> Dict[str, str]:
    return dict(key)


def percentile_row(snapshot: Dict[str, Any], name: str,
                   tags: Optional[Dict[str, str]] = None) -> Optional[Dict[str, Any]]:
    """Pull one histogram row (matching `tags`, or the only row) out of an
    :meth:`ObsCollector.snapshot` dict — the helper the launchers' stats
    reports use to print p50/p95/p99 without re-walking the schema."""
    rows: List[Dict[str, Any]] = snapshot.get("histograms", {}).get(name, [])
    if not rows:
        return None
    if tags is None:
        return rows[0]
    want = {k: str(v) for k, v in tags.items()}
    for row in rows:
        if all(row.get("tags", {}).get(k) == v for k, v in want.items()):
            return row
    return None
