"""The observability collector: scoped metrics registry + bounded event log.

One :class:`ObsCollector` owns a tagged-metric registry (counters / gauges /
histograms from :mod:`.metrics`) and a bounded ring buffer of structured
events. Collectors live on a contextvar stack exactly like
:class:`repro.core.runtime.TunedRuntime` — ``with obs.collect(...)`` scopes
one over a region, nested scopes win, threads and asyncio tasks are
isolated, and a fresh thread falls back to the process-default collector.

The process-default collector is **disabled**: every module-level recording
helper (``counter`` / ``gauge`` / ``observe`` / ``event`` / ``span``) starts
with one ``enabled`` check and returns immediately, so instrumented hot
paths cost a contextvar read + a branch when nobody is collecting — the
overhead contract ``benchmarks/obs_overhead.py`` enforces. Warnings are the
one exception: :func:`warn_once` is for rare structural hazards (e.g. the
non-divisible-microbatch key approximation) and records + logs exactly once
per (collector, name, key) even when metric collection is off, so the
hazard is never silently dropped.

Sampling: high-frequency call sites (per-token serving paths) gate on
:meth:`ObsCollector.sample`, a deterministic 1-in-N tick driven by
``sample_rate`` — the "default sampling" configuration is ``1.0`` (record
everything); a loaded fleet dials it down without touching call sites.
"""
from __future__ import annotations

import collections
import contextvars
import itertools
import logging
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

from .metrics import Counter, Gauge, Histogram, render_tags, tags_key

log = logging.getLogger("repro.obs")

_EVENT_KINDS = ("event", "span", "warning")

_span_ids = itertools.count(1)


class Event(dict):
    """One structured event: a plain dict (JSONL-friendly) with a schema.

    Keys: ``ts`` (unix seconds), ``kind`` (``event | span | warning``),
    ``name``, plus free-form fields; span events carry ``span_id`` /
    ``parent_id`` / ``dur_s`` so a tree can be rebuilt offline.
    """


class ObsCollector:
    """Scoped metrics registry + bounded event ring buffer."""

    def __init__(
        self,
        name: str = "obs",
        enabled: bool = True,
        sample_rate: float = 1.0,
        max_events: int = 4096,
        xla_annotations: bool = False,
    ):
        self.name = name
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self.xla_annotations = bool(xla_annotations)
        self.max_events = int(max_events)
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, Tuple[Tuple[str, str], ...]], Any] = {}
        self._events: "collections.deque[Event]" = collections.deque(
            maxlen=self.max_events
        )
        self._warned: set = set()
        self._tick = 0
        self.created = time.time()

    # -- scoping (token-free, mirroring TunedRuntime) -------------------------
    def __enter__(self) -> "ObsCollector":
        _stack.set(_stack.get() + (self,))
        return self

    def __exit__(self, *exc) -> None:
        s = _stack.get()
        for i in range(len(s) - 1, -1, -1):
            if s[i] is self:
                _stack.set(s[:i] + s[i + 1:])
                return

    # -- sampling -------------------------------------------------------------
    def sample(self) -> bool:
        """Deterministic 1-in-N gate for high-frequency sites (per-token
        paths). ``sample_rate >= 1`` always records; ``0`` never does."""
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        n = max(1, int(round(1.0 / self.sample_rate)))
        self._tick += 1
        return self._tick % n == 0

    # -- metrics --------------------------------------------------------------
    def _metric(self, cls, name: str, tags: Dict[str, Any]):
        key = (cls.kind, name, tags_key(tags))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics.setdefault(key, cls())
        return m

    def counter(self, name: str, n: float = 1.0, **tags: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._metric(Counter, name, tags).add(n)

    def gauge(self, name: str, value: float, **tags: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._metric(Gauge, name, tags).set(value)

    def observe(self, name: str, value: float, **tags: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._metric(Histogram, name, tags).observe(value)

    # -- events ---------------------------------------------------------------
    def event(self, name: str, kind: str = "event", **fields: Any) -> None:
        if not self.enabled and kind != "warning":
            return
        self.record_event(name, kind, **fields)

    def record_event(self, name: str, kind: str = "event", **fields: Any) -> None:
        if kind not in _EVENT_KINDS:
            raise ValueError(f"event kind {kind!r} not in {_EVENT_KINDS}")
        ev = Event(ts=time.time(), kind=kind, name=name, **fields)
        with self._lock:
            self._events.append(ev)

    def warn_once(self, name: str, key: str = "", **fields: Any) -> bool:
        """Structured one-time warning: ring-buffer event (kind="warning") +
        one ``logging`` line, deduped per (name, key) on this collector.
        Fires even when metric collection is disabled — hazards must not
        vanish just because nobody asked for metrics. Returns True when this
        call was the one that fired."""
        dedup = (name, key)
        with self._lock:
            if dedup in self._warned:
                return False
            self._warned.add(dedup)
        self.record_event(name, kind="warning", key=key, **fields)
        detail = " ".join(f"{k}={v}" for k, v in fields.items())
        log.warning("%s [%s] %s", name, key, detail)
        return True

    def events(self, kind: Optional[str] = None) -> List[Event]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.get("kind") == kind]
        return evs

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Serializable state: tagged metric rows + the event ring buffer."""
        out: Dict[str, Any] = {
            "meta": {
                "name": self.name,
                "enabled": self.enabled,
                "sample_rate": self.sample_rate,
                "created": self.created,
                "exported": time.time(),
            },
            "counters": {},
            "gauges": {},
            "histograms": {},
        }
        section = {"counter": "counters", "gauge": "gauges",
                   "histogram": "histograms"}
        with self._lock:
            for (kind, name, tkey), m in sorted(
                self._metrics.items(), key=lambda kv: (kv[0][0], kv[0][1], kv[0][2])
            ):
                row = {"tags": render_tags(tkey), **m.snapshot()}
                out[section[kind]].setdefault(name, []).append(row)
            out["events"] = [dict(e) for e in self._events]
        out["warnings"] = [e for e in out["events"] if e.get("kind") == "warning"]
        return out

    def write(self, path: str) -> None:
        """JSON snapshot — the ``--metrics-out`` artifact that
        ``python -m repro.obs report`` renders."""
        from .export import write_snapshot

        write_snapshot(self.snapshot(), path)

    def write_jsonl(self, path: str) -> None:
        from .export import write_jsonl

        write_jsonl(self.events(), path)

    def write_prom(self, path: str) -> None:
        from .export import write_prom

        write_prom(self.snapshot(), path)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._events.clear()
            self._warned.clear()
            self._tick = 0

    def __repr__(self) -> str:
        state = "enabled" if self.enabled else "disabled"
        return (f"<ObsCollector {self.name} {state} "
                f"sample={self.sample_rate} metrics={len(self._metrics)}>")


# ---------------------------------------------------------------------------
# Context-local stack + process default
# ---------------------------------------------------------------------------

_stack: "contextvars.ContextVar[Tuple[ObsCollector, ...]]" = contextvars.ContextVar(
    "repro_obs_stack", default=()
)

_default_lock = threading.Lock()
_default: Optional[ObsCollector] = None


def _default_collector() -> ObsCollector:
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                # Disabled by default: instrumentation must be free unless
                # somebody scopes an enabled collector (the overhead
                # contract). warn_once still records through it.
                _default = ObsCollector(name="default", enabled=False)
    return _default


def current_collector() -> ObsCollector:
    """The innermost active collector, or the (disabled) process default."""
    s = _stack.get()
    return s[-1] if s else _default_collector()


def collect(
    name: str = "obs",
    enabled: bool = True,
    sample_rate: float = 1.0,
    max_events: int = 4096,
    xla_annotations: bool = False,
) -> ObsCollector:
    """Create a scoped collector (use as ``with obs.collect(...) as col``)."""
    return ObsCollector(
        name=name, enabled=enabled, sample_rate=sample_rate,
        max_events=max_events, xla_annotations=xla_annotations,
    )


def enabled() -> bool:
    """Fast ambient check: is anything collecting here?"""
    return current_collector().enabled


# Module-level conveniences: record on whatever collector is ambient.
def counter(name: str, n: float = 1.0, **tags: Any) -> None:
    current_collector().counter(name, n, **tags)


def gauge(name: str, value: float, **tags: Any) -> None:
    current_collector().gauge(name, value, **tags)


def observe(name: str, value: float, **tags: Any) -> None:
    current_collector().observe(name, value, **tags)


def event(name: str, **fields: Any) -> None:
    current_collector().event(name, **fields)


def warn_once(name: str, key: str = "", **fields: Any) -> bool:
    return current_collector().warn_once(name, key=key, **fields)
