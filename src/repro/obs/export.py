"""Exporters: JSON snapshot, JSONL event sink, Prometheus textfile.

One snapshot schema (``ObsCollector.snapshot``) feeds every consumer:
``--metrics-out`` writes it, ``python -m repro.obs report/diff`` renders and
compares it, and :func:`write_prom` reshapes it into the Prometheus textfile
exposition format (node_exporter's textfile-collector contract) so a fleet
scraper ingests the same numbers with zero extra plumbing.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Iterable, List, Optional

from ..core.database import atomic_write_json


def write_snapshot(snapshot: Dict[str, Any], path: str) -> None:
    atomic_write_json(path, snapshot)


def load_snapshot(path: str) -> Dict[str, Any]:
    if not os.path.exists(path):
        raise SystemExit(f"error: metrics snapshot {path}: no such file")
    with open(path) as f:
        return json.load(f)


def write_jsonl(events: Iterable[Dict[str, Any]], path: str) -> None:
    """Append-friendly structured event sink: one JSON object per line."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        for ev in events:
            f.write(json.dumps(dict(ev), sort_keys=True) + "\n")


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _prom_tags(tags: Dict[str, str]) -> str:
    if not tags:
        return ""
    body = ",".join(
        f'{_NAME_RE.sub("_", k)}="{str(v).replace(chr(92), "")}"'
        for k, v in sorted(tags.items())
    )
    return "{" + body + "}"


def write_prom(snapshot: Dict[str, Any], path: str) -> None:
    """Prometheus textfile exposition of one snapshot.

    Counters/gauges map 1:1; histograms export ``_count`` / ``_sum`` plus
    quantile gauges (``quantile="0.5|0.95|0.99"``) — summary-style, since the
    log buckets are an internal representation.
    """
    lines: List[str] = []
    for name, rows in snapshot.get("counters", {}).items():
        lines.append(f"# TYPE {_prom_name(name)} counter")
        for row in rows:
            lines.append(
                f"{_prom_name(name)}{_prom_tags(row.get('tags', {}))} {row['value']:g}"
            )
    for name, rows in snapshot.get("gauges", {}).items():
        lines.append(f"# TYPE {_prom_name(name)} gauge")
        for row in rows:
            lines.append(
                f"{_prom_name(name)}{_prom_tags(row.get('tags', {}))} {row['value']:g}"
            )
    for name, rows in snapshot.get("histograms", {}).items():
        base = _prom_name(name)
        lines.append(f"# TYPE {base} summary")
        for row in rows:
            tags = dict(row.get("tags", {}))
            for q, field in (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")):
                lines.append(
                    f"{base}{_prom_tags({**tags, 'quantile': q})} {row[field]:g}"
                )
            lines.append(f"{base}_count{_prom_tags(tags)} {row['count']:g}")
            lines.append(f"{base}_sum{_prom_tags(tags)} {row['sum']:g}")
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


# ---------------------------------------------------------------------------
# Rendering + diffing (the CLI's meat, kept importable for tests)
# ---------------------------------------------------------------------------


def format_snapshot(snap: Dict[str, Any], max_events: int = 0) -> str:
    meta = snap.get("meta", {})
    lines = [
        f"obs snapshot [{meta.get('name', '?')}] "
        f"sample_rate={meta.get('sample_rate', 1.0)}"
    ]
    counters = snap.get("counters", {})
    if counters:
        lines.append("counters:")
        for name, rows in counters.items():
            for row in rows:
                lines.append(f"  {name}{_fmt_tags(row)} = {row['value']:g}")
    gauges = snap.get("gauges", {})
    if gauges:
        lines.append("gauges:")
        for name, rows in gauges.items():
            for row in rows:
                lines.append(f"  {name}{_fmt_tags(row)} = {row['value']:g}")
    hists = snap.get("histograms", {})
    if hists:
        lines.append("histograms (s):")
        for name, rows in hists.items():
            for row in rows:
                lines.append(
                    f"  {name}{_fmt_tags(row)}: n={row['count']} "
                    f"p50={row['p50']:.3g} p95={row['p95']:.3g} "
                    f"p99={row['p99']:.3g} mean={row['mean']:.3g}"
                )
    warnings = snap.get("warnings", [])
    for w in warnings:
        extra = {k: v for k, v in w.items()
                 if k not in ("ts", "kind", "name", "key")}
        lines.append(f"  WARNING {w.get('name')} [{w.get('key', '')}] {extra}")
    if max_events:
        spans = [e for e in snap.get("events", []) if e.get("kind") == "span"]
        for ev in spans[-max_events:]:
            lines.append(
                f"  span {ev.get('name')}#{ev.get('span_id')} "
                f"parent={ev.get('parent_id')} dur={ev.get('dur_s', 0):.4g}s"
            )
    return "\n".join(lines)


def _fmt_tags(row: Dict[str, Any]) -> str:
    tags = row.get("tags", {})
    if not tags:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(tags.items())) + "}"


def diff_snapshots(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    """Structured b-minus-a: counter deltas, gauge moves, percentile shifts.

    The drift-adjacent workflow: export a snapshot after the canary run and
    after the suspect run, diff them, and the shifted histograms name where
    the time went.
    """
    out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}

    def rows_by_tags(section, name):
        return {
            tuple(sorted(r.get("tags", {}).items())): r
            for r in section.get(name, [])
        }

    for name in sorted(set(a.get("counters", {})) | set(b.get("counters", {}))):
        ra, rb = rows_by_tags(a.get("counters", {}), name), rows_by_tags(
            b.get("counters", {}), name)
        for tkey in sorted(set(ra) | set(rb)):
            va = ra.get(tkey, {}).get("value", 0.0)
            vb = rb.get(tkey, {}).get("value", 0.0)
            if va != vb:
                out["counters"].setdefault(name, []).append(
                    {"tags": dict(tkey), "a": va, "b": vb, "delta": vb - va}
                )
    for name in sorted(set(a.get("gauges", {})) | set(b.get("gauges", {}))):
        ra, rb = rows_by_tags(a.get("gauges", {}), name), rows_by_tags(
            b.get("gauges", {}), name)
        for tkey in sorted(set(ra) | set(rb)):
            va = ra.get(tkey, {}).get("value", 0.0)
            vb = rb.get(tkey, {}).get("value", 0.0)
            if va != vb:
                out["gauges"].setdefault(name, []).append(
                    {"tags": dict(tkey), "a": va, "b": vb, "delta": vb - va}
                )
    for name in sorted(set(a.get("histograms", {})) | set(b.get("histograms", {}))):
        ra, rb = rows_by_tags(a.get("histograms", {}), name), rows_by_tags(
            b.get("histograms", {}), name)
        for tkey in sorted(set(ra) | set(rb)):
            pa, pb = ra.get(tkey), rb.get(tkey)
            row = {"tags": dict(tkey)}
            changed = False
            for field in ("count", "p50", "p95", "p99", "mean"):
                va = pa.get(field, 0.0) if pa else 0.0
                vb = pb.get(field, 0.0) if pb else 0.0
                row[field] = {"a": va, "b": vb, "delta": vb - va}
                changed = changed or va != vb
                if field != "count" and va > 0:
                    row[field]["ratio"] = vb / va
            if changed:
                out["histograms"].setdefault(name, []).append(row)
    return out


def format_diff(diff: Dict[str, Any]) -> str:
    lines = ["obs diff (b - a):"]
    for name, rows in diff.get("counters", {}).items():
        for row in rows:
            lines.append(
                f"  {name}{_fmt_tags(row)}: {row['a']:g} -> {row['b']:g} "
                f"({row['delta']:+g})"
            )
    for name, rows in diff.get("gauges", {}).items():
        for row in rows:
            lines.append(
                f"  {name}{_fmt_tags(row)}: {row['a']:g} -> {row['b']:g} "
                f"({row['delta']:+g})"
            )
    for name, rows in diff.get("histograms", {}).items():
        for row in rows:
            p50 = row["p50"]
            ratio = p50.get("ratio")
            shift = f" ({ratio:.2f}x)" if ratio else ""
            lines.append(
                f"  {name}{_fmt_tags(row)}: p50 {p50['a']:.3g} -> "
                f"{p50['b']:.3g}{shift}, p99 {row['p99']['a']:.3g} -> "
                f"{row['p99']['b']:.3g}"
            )
    if len(lines) == 1:
        lines.append("  (no differences)")
    return "\n".join(lines)
