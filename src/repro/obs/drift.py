"""Drift detection: is tuned performance *sustained*, or has it rotted?

A tuning database is a set of promises: "config C hit `objective` seconds on
key K on this platform". Those promises decay — driver/runtime upgrades,
thermal backoff, noisy neighbours, a re-sharded deployment shifting local
shapes. This module re-checks them:

1. **replay probe** (:func:`measure_sites`) — for each stored record,
   rebuild representative arguments from the key (the same seeded-tensor
   recipe the campaign runner measured with) and re-time the stored winning
   config through the same wall-clock evaluator.
2. **attribution** (:func:`detect_drift`) — compare live seconds against the
   record's measured `objective` (%-of-tuned-best) and against the
   first-principles hardware bound from
   :func:`repro.tools.analytic.site_roofline_seconds` (%-of-roofline). The
   roofline column separates "the site regressed" from "the site was never
   close to the hardware anyway" — a 1.5× slowdown at 80% of roofline is a
   machine problem; at 3% of roofline it's a tuning problem.
3. **ranked report** (:func:`format_drift`) — worst slowdown first, the
   `campaign drift` artifact. Sites flagged `regressed` are exactly the
   re-tune queue a future BackgroundTune tier would consume (ROADMAP item
   2); until that lands, `python -m repro.obs report --drift` is the human
   trigger.

Live timings can also come from a metrics snapshot instead of the replay
probe (``--live``): any mapping of db key → seconds works, so a fleet can
feed per-site timings scraped from production collectors.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Any, Dict, List, Optional, Sequence

# Lazy-import discipline: repro.core.runtime imports repro.obs, so this
# module must not be imported from the package __init__; it pulls core/
# campaign modules only when actually called.


@dataclasses.dataclass
class DriftEntry:
    """One dispatch site's sustained-performance attribution."""

    key: str
    kernel: str
    tuned_s: float            # the database record's measured objective
    live_s: float             # what the same config costs right now
    roofline_s: float         # first-principles hardware bound for the site
    slowdown: float           # live_s / tuned_s (>1 = slower than tuned)
    pct_of_tuned_best: float  # 100 * tuned_s / live_s (100 = promise holds)
    pct_of_roofline: float    # 100 * roofline_s / live_s
    regressed: bool

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _arg_dtypes_for(kernel: str, shapes: Sequence[Sequence[int]], dtype: str) -> List[str]:
    """Reconstruct per-arg dtypes from a key's promoted dtype.

    Keys store only the promoted float dtype; the integer label args of the
    xent family (the planner's only int args) are re-marked here so the
    replay tensors match what the campaign measured.
    """
    dtypes = [dtype] * len(shapes)
    if kernel == "softmax_xent" and len(shapes) >= 2:
        dtypes[1] = "int32"                      # (T,) labels
    elif kernel == "softmax_xent_bwd" and len(shapes) >= 3:
        dtypes[2] = "int32"                      # ct, logits, labels
    return dtypes


def measure_sites(
    db,
    platform: Optional[str] = None,
    evaluator=None,
    keys: Optional[Sequence[str]] = None,
    seed: int = 0,
) -> Dict[str, float]:
    """Replay probe: re-time each stored record's winning config *now*.

    Returns {db key: live seconds}. Sites whose kernel is not registered or
    whose replay fails are skipped (a probe must degrade, not crash) —
    failures land as +inf so the report still surfaces them.
    """
    import math

    from ..campaign.planner import _register_tunables
    from ..campaign.runner import materialize_args
    from ..core.annotate import get_tunable, registered
    from ..core.database import split_key
    from ..core.evaluate import WallClockEvaluator

    _register_tunables()
    evaluator = evaluator or WallClockEvaluator(repeats=3, warmup=1)
    want = set(keys) if keys is not None else None
    live: Dict[str, float] = {}
    for record in db.records():
        if want is not None and record.key not in want:
            continue
        kernel, plat, shapes, dtype, _extra = split_key(record.key)
        if platform is not None and plat != platform:
            continue
        if kernel not in registered():
            continue
        tunable = get_tunable(kernel)
        # materialize_args only reads .kernel/.arg_shapes/.arg_dtypes, so a
        # namespace stands in for a TuningJob — same seeded recipe, same
        # tensors the campaign originally measured.
        job = types.SimpleNamespace(
            kernel=kernel,
            arg_shapes=tuple(tuple(s) for s in shapes),
            arg_dtypes=tuple(_arg_dtypes_for(kernel, shapes, dtype or "float32")),
        )
        try:
            args = materialize_args(job, seed=seed)
            variant = tunable.variant(**record.config)
            m = evaluator.evaluate(variant, args)
            live[record.key] = m.objective if m.ok else math.inf
        except Exception:
            live[record.key] = math.inf
    return live


def detect_drift(
    db,
    live: Dict[str, float],
    threshold: float = 1.5,
    profile=None,
    platform: Optional[str] = None,
) -> List[DriftEntry]:
    """Attribute live per-site seconds against tuned-best and roofline.

    `live` maps db keys to current seconds — from :func:`measure_sites`, or
    from any external source (a production metrics snapshot). A site is
    `regressed` when live exceeds `threshold` × the record's tuned
    objective. Entries come back ranked worst-slowdown-first.
    """
    from ..core.database import split_key
    from ..core.platform import detect_platform
    from ..tools.analytic import site_roofline_seconds

    profile = profile or detect_platform()
    out: List[DriftEntry] = []
    for record in db.records():
        live_s = live.get(record.key)
        if live_s is None:
            continue
        kernel, plat, shapes, dtype, _extra = split_key(record.key)
        if platform is not None and plat != platform:
            continue
        tuned_s = record.objective
        roof_s = site_roofline_seconds(kernel, shapes, dtype or "float32", profile)
        slow = (live_s / tuned_s) if tuned_s > 0 else float("inf")
        out.append(
            DriftEntry(
                key=record.key,
                kernel=kernel,
                tuned_s=tuned_s,
                live_s=live_s,
                roofline_s=roof_s,
                slowdown=slow,
                pct_of_tuned_best=(100.0 * tuned_s / live_s) if live_s > 0 else 0.0,
                pct_of_roofline=(100.0 * roof_s / live_s) if live_s > 0 else 0.0,
                regressed=slow > threshold,
            )
        )
    out.sort(key=lambda e: -e.slowdown)
    return out


def drift_report(
    db,
    platform: Optional[str] = None,
    threshold: float = 1.5,
    evaluator=None,
    profile=None,
    live: Optional[Dict[str, float]] = None,
    seed: int = 0,
) -> List[DriftEntry]:
    """measure (unless `live` is supplied) + attribute, ranked worst-first."""
    if live is None:
        live = measure_sites(db, platform=platform, evaluator=evaluator, seed=seed)
    return detect_drift(db, live, threshold=threshold, profile=profile,
                        platform=platform)


def format_drift(entries: Sequence[DriftEntry], threshold: float = 1.5) -> str:
    """The `campaign drift` report: ranked table + re-tune queue."""
    if not entries:
        return "drift: no measured sites (empty db or no live timings)"
    lines = [
        f"campaign drift report ({len(entries)} sites, "
        f"regression threshold {threshold:.2f}x)",
        f"  {'slowdown':>9}  {'%tuned':>7}  {'%roof':>6}  "
        f"{'tuned_s':>10}  {'live_s':>10}  key",
    ]
    for e in entries:
        flag = " <-- REGRESSED" if e.regressed else ""
        lines.append(
            f"  {e.slowdown:>8.2f}x  {e.pct_of_tuned_best:>6.1f}%  "
            f"{e.pct_of_roofline:>5.1f}%  {e.tuned_s:>10.3e}  "
            f"{e.live_s:>10.3e}  {e.key}{flag}"
        )
    n_reg = sum(1 for e in entries if e.regressed)
    if n_reg:
        lines.append(
            f"  {n_reg} site(s) regressed — re-tune queue "
            f"(future BackgroundTune input):"
        )
        for e in entries:
            if e.regressed:
                lines.append(f"    campaign re-tune candidate: {e.key}")
    else:
        lines.append("  all sites within threshold — tuned performance sustained")
    return "\n".join(lines)
