"""Tracing spans: a contextvar-scoped span tree over the hot paths.

``obs.span("train.step")`` opens one node; nested spans (including across
``await`` points and never across threads — contextvars give the same
isolation the dispatch runtime relies on) record their parent, so the ring
buffer's span events rebuild into a tree offline (``python -m repro.obs
report`` renders the top names).

Each completed span lands twice on the ambient collector:

* histogram ``span.<name>`` — duration distribution (p50/p95/p99). Tags are
  deliberately NOT attached to the histogram: span callers pass per-call
  fields (step numbers, request ids) whose cardinality would explode the
  registry; those go on the event instead.
* event ``kind="span"`` — ``{name, dur_s, span_id, parent_id, **tags}`` in
  the bounded ring buffer.

Opt-in XLA visibility: a collector created with ``xla_annotations=True``
wraps every span in ``jax.profiler.TraceAnnotation``, so spans show up on
the host timeline of an XLA profile next to the device ops they enclose.
Failure to import/enter the annotation is swallowed — tracing must never
take down the workload.

A disabled collector short-circuits before any allocation: the span body
runs bare, and ``yield`` sees ``None``.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import time
from typing import Any, Dict, Iterator, Optional

from .collect import current_collector

_ids = itertools.count(1)

_span_ctx: "contextvars.ContextVar[Optional[Span]]" = contextvars.ContextVar(
    "repro_obs_span", default=None
)


@dataclasses.dataclass
class Span:
    """One live span node (exposed so callers can attach fields mid-span)."""

    name: str
    span_id: int
    parent_id: Optional[int]
    tags: Dict[str, Any]
    t0: float = 0.0

    def set(self, **fields: Any) -> None:
        """Attach fields to the span's completion event."""
        self.tags.update(fields)


def current_span() -> Optional[Span]:
    return _span_ctx.get()


@contextlib.contextmanager
def span(name: str, **tags: Any) -> Iterator[Optional[Span]]:
    """Open one span on the ambient collector (no-op when disabled)."""
    col = current_collector()
    if not col.enabled:
        yield None
        return
    parent = _span_ctx.get()
    sp = Span(
        name=name,
        span_id=next(_ids),
        parent_id=parent.span_id if parent is not None else None,
        tags=dict(tags),
    )
    tok = _span_ctx.set(sp)
    ann = None
    if col.xla_annotations:
        try:
            from jax.profiler import TraceAnnotation

            ann = TraceAnnotation(name)
            ann.__enter__()
        except Exception:
            ann = None
    sp.t0 = time.perf_counter()
    try:
        yield sp
    finally:
        dur = time.perf_counter() - sp.t0
        if ann is not None:
            try:
                ann.__exit__(None, None, None)
            except Exception:
                pass
        _span_ctx.reset(tok)
        col.observe(f"span.{name}", dur)
        col.event(
            name, kind="span", dur_s=dur, span_id=sp.span_id,
            parent_id=sp.parent_id, **sp.tags,
        )


def span_tree(events) -> Dict[Optional[int], list]:
    """Group span events by parent_id — the offline tree view the CLI
    renders (children keyed under their parent's span_id; roots under
    ``None``)."""
    tree: Dict[Optional[int], list] = {}
    for ev in events:
        if ev.get("kind") != "span":
            continue
        tree.setdefault(ev.get("parent_id"), []).append(ev)
    return tree
