"""AdamW with fp32 master weights + moments, global-norm clipping.

No optax dependency — explicit pytrees so optimizer state inherits the
parameter shardings verbatim (m/v/master mirror params; that's ZeRO-style
state sharding for free when params are FSDP-sharded).

Gradient compression hooks (the cross-pod all-reduce cost reducer) live in
``distributed/collectives.py`` and wrap `update` — see CompressedOptimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_fp32: bool = True     # keep fp32 master copy for bf16 params


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(cfg: AdamWConfig, params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = _schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    new_m = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], grads
    )
    new_v = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], grads
    )

    def step_one(p32, m, v):
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        return p32 - lr * (upd + cfg.weight_decay * p32)

    if cfg.master_fp32 and "master" in state:
        new_master = jax.tree_util.tree_map(step_one, state["master"], new_m, new_v)
        new_params = jax.tree_util.tree_map(
            lambda nm, p: nm.astype(p.dtype), new_master, params
        )
        new_state = {"step": step, "m": new_m, "v": new_v, "master": new_master}
    else:
        new_params = jax.tree_util.tree_map(
            lambda p, m, v: step_one(p.astype(jnp.float32), m, v).astype(p.dtype),
            params, new_m, new_v,
        )
        new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_shardings(param_shardings, master_fp32: bool, replicated_sharding):
    """Optimizer-state shardings mirroring the params tree."""
    out = {
        "step": replicated_sharding,
        "m": param_shardings,
        "v": param_shardings,
    }
    if master_fp32:
        out["master"] = param_shardings
    return out
