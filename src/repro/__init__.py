"""repro — annotation-based autotuning for sustainable performance
portability (Mametjanov & Norris, 2013) rebuilt as a production JAX/Pallas
training + serving framework for TPU pods."""
__version__ = "1.0.0"
