"""repro — annotation-based autotuning for sustainable performance
portability (Mametjanov & Norris, 2013) rebuilt as a production JAX/Pallas
training + serving framework for TPU pods.

Deployment API (the dispatch runtime)::

    import repro

    with repro.runtime(db=serve_db, mode="kernel") as rt:
        ...                      # all kernel dispatch pinned to serve_db
    print(rt.telemetry.report())

See :mod:`repro.core.runtime` for scoped contexts, the pluggable
ResolutionPolicy pipeline, and telemetry.
"""
from .core.runtime import (  # noqa: F401
    TunedRuntime,
    current_runtime,
    dispatch,
    runtime,
)

__version__ = "1.2.0"
