"""Selective-scan (Mamba S6) tunables: chunked scan + fused decode update.

The recurrence is h_t = exp(dt_t·A)·h_{t-1} + (dt_t·xc_t)·B_t, y_t = h_t·C_t
with an fp32 carry. Two dispatch sites:

  * ``ssm_scan`` — training/prefill over [b, s, di]. The Pallas kernel
    streams length-``chunk`` time slices through VMEM per (batch, d_inner
    block) grid cell, carrying the [block_d, d_state] state in scratch; the
    reference is the chunked associative-scan form (the math previously
    inlined in ``models/ssm.py``), whose peak live tensor is
    [b, chunk, di, ds] — never the full [b, s, di, ds].
  * ``ssm_update`` — one fused decode step over [b, di].

Padding is identity-safe by construction: a zero-padded tail has dt = 0, so
dA = exp(0) = 1 and dBx = 0 — pad steps carry the state through unchanged.
(The old inline chunking padded *pre-coefficient* activations instead, so
``softplus(dt_bias) > 0`` kept stepping the recurrence across the pad and
corrupted the prefill→decode handoff state.)

Backwards are dispatch sites too (``ssm_scan_bwd`` / ``ssm_update_bwd``,
``vjp="dispatch"``): jnp variants whose chunk/block knob bounds the VJP's
rematerialization window, gated against the sequential ``ref.py`` oracles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref


# ---------------------------------------------------------------------------
# Chunked associative-scan form — the reference plane of the ssm_scan
# tunable AND the remat-windowed body of the bwd variants.
# ---------------------------------------------------------------------------


def ssm_scan_chunked(xc: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                     A: jax.Array, h0: jax.Array, *, chunk: int = 32):
    """Outer `lax.scan` over chunks, inner `associative_scan` within.

    Same signature/semantics as :func:`ref.ssm_scan`; peak live tensor is
    [b, chunk, di, ds].
    """
    b, s, di = xc.shape
    ds = A.shape[1]
    chunk = max(1, min(chunk, s))
    pad = (-s) % chunk
    xf = xc.astype(jnp.float32)
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xf, dt, B, C = zpad(xf), zpad(dt), zpad(B), zpad(C)
    sp = s + pad
    nc = sp // chunk
    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xcs, dts, Bs, Cs = resh(xf), resh(dt), resh(B), resh(C)

    def chunk_step(h, inp):
        xc_c, dt_c, B_c, C_c = inp              # [b,c,di], [b,c,di], [b,c,ds]x2
        dA = jnp.exp(dt_c[..., None] * A)       # [b,c,di,ds]
        dBx = (dt_c * xc_c)[..., None] * B_c[:, :, None, :]
        # prepend the carry as a pseudo-step: h_0's contribution
        a_all = jnp.concatenate([jnp.ones_like(dA[:, :1]), dA], axis=1)
        b_all = jnp.concatenate([h[:, None], dBx], axis=1)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
        hs = hs[:, 1:]                          # [b,c,di,ds]
        y = jnp.einsum("bcds,bcs->bcd", hs, C_c)
        return hs[:, -1], y

    hN, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), (xcs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(b, sp, di)[:, :s]
    return y, hN


# ---------------------------------------------------------------------------
# Pallas chunked scan
# ---------------------------------------------------------------------------


def _ssm_scan_kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                     y_ref, hn_ref, h_scr, *, chunk: int, s_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    xc = xc_ref[0].astype(jnp.float32)          # [chunk, bd]
    dt = dt_ref[0]                              # [chunk, bd] fp32
    bb = b_ref[0]                               # [chunk, ds] fp32
    cc = c_ref[0]
    a = a_ref[...]                              # [bd, ds]
    da = jnp.exp(dt[:, :, None] * a[None])      # [chunk, bd, ds]
    dbx = (dt * xc)[:, :, None] * bb[:, None, :]

    def step(t, carry):
        h, ys = carry
        h = da[t] * h + dbx[t]
        y_t = jnp.sum(h * cc[t][None, :], axis=-1)          # [bd]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], jnp.zeros_like(dt)))
    h_scr[...] = h
    y_ref[0] = ys

    @pl.when(pl.program_id(2) == s_steps - 1)
    def _done():
        hn_ref[0] = h


def ssm_scan_pallas(
    xc: jax.Array,   # [b, s, di] model dtype
    dt: jax.Array,   # [b, s, di] fp32, post-softplus (>= 0)
    B: jax.Array,    # [b, s, ds] fp32
    C: jax.Array,    # [b, s, ds] fp32
    A: jax.Array,    # [di, ds] fp32 (negative)
    h0: jax.Array,   # [b, di, ds] fp32 carry-in
    *,
    chunk: int,
    block_d: int,
    interpret: bool = False,
):
    b, s, di = xc.shape
    ds = A.shape[1]
    chunk = min(chunk, s)
    block_d = min(block_d, di)
    sp = s + (-s) % chunk
    dip = di + (-di) % block_d
    # zero padding is identity-safe: dt = 0 => dA = 1, dBx = 0
    pad_sd = lambda t: jnp.pad(t, ((0, 0), (0, sp - s), (0, dip - di)))
    pad_s = lambda t: jnp.pad(t, ((0, 0), (0, sp - s), (0, 0)))
    xcp, dtp = pad_sd(xc), pad_sd(dt)
    Bp, Cp = pad_s(B), pad_s(C)
    Ap = jnp.pad(A, ((0, dip - di), (0, 0)))
    h0p = jnp.pad(h0, ((0, 0), (0, dip - di), (0, 0)))
    s_steps = sp // chunk
    grid = (b, dip // block_d, s_steps)

    y, hn = pl.pallas_call(
        functools.partial(_ssm_scan_kernel, chunk=chunk, s_steps=s_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, ds), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((block_d, ds), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((1, block_d, ds), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, block_d, ds), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, dip), jnp.float32),
            jax.ShapeDtypeStruct((b, dip, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        # the time grid dim carries the state scratch: sequential
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xcp, dtp, Bp, Cp, Ap, h0p)
    return y[:, :s, :di], hn[:, :di]


def _scan_vmem_bytes(cfg, ds: int = 16) -> int:
    c, bd = cfg["chunk"], cfg["block_d"]
    # da + dbx intermediates dominate; xc/dt/y tiles + state scratch ride along
    return c * bd * ds * 8 + c * bd * 12 + bd * ds * 8


SSM_SCAN_SPACE = ParamSpace(
    [
        PowerOfTwoParam("chunk", 8, 512),
        PowerOfTwoParam("block_d", 8, 512),
    ],
    [
        Constraint(
            lambda c: _scan_vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "chunk x d_inner working set exceeds VMEM budget",
        )
    ],
)


def _pick_pow2(d: int, lo: int, cap: int) -> int:
    return min(cap, max(lo, 1 << (int(max(d, 1)) - 1).bit_length()))


def _ssm_scan_heuristic(xc, dt, B, C, A, h0):
    b, s, di = xc.shape
    return {"chunk": _pick_pow2(s, 8, 128), "block_d": _pick_pow2(di, 8, 256)}


def _ssm_scan_example():
    import numpy as np

    rs = np.random.RandomState(0)
    b, s, di, ds = 2, 12, 8, 4   # s not a chunk multiple: exercises padding
    return (
        jnp.asarray(rs.randn(b, s, di) * 0.5, jnp.float32),        # xc
        jnp.asarray(np.abs(rs.randn(b, s, di)) * 0.1 + 0.01, jnp.float32),
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),        # B
        jnp.asarray(rs.randn(b, s, ds) * 0.5, jnp.float32),        # C
        jnp.asarray(-np.abs(rs.randn(di, ds)) - 0.1, jnp.float32),  # A
        jnp.asarray(rs.randn(b, di, ds) * 0.3, jnp.float32),       # h0
    ), {}


def _ssm_scan_bwd_plan(ct, xc, dt, B, C, A, h0, **kwargs):
    """Backward plan: one fused bwd dispatch site (its own tunable/records)."""
    from ..core.runtime import dispatch

    ct_y, ct_h = ct
    return dispatch(
        "ssm_scan_bwd", ct_y.astype(jnp.float32), ct_h.astype(jnp.float32),
        xc, dt, B, C, A, h0, **kwargs,
    )


@tunable(
    "ssm_scan",
    space=SSM_SCAN_SPACE,
    reference=ssm_scan_chunked,
    heuristic=_ssm_scan_heuristic,
    # A is the [di, ds] state matrix (a weight, never batch-sharded).
    dispatch=DispatchSpec(example=_ssm_scan_example,
                          data_parallel_args=(0, 1, 2, 3, 5),
                          vjp="dispatch", bwd=_ssm_scan_bwd_plan),
)
def ssm_scan(xc, dt, B, C, A, h0, *, chunk: int, block_d: int,
             interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return ssm_scan_pallas(xc, dt, B, C, A, h0, chunk=chunk, block_d=block_d,
                           interpret=interpret)


# ---------------------------------------------------------------------------
# Backward: chunk-windowed VJP of the chunked scan
# ---------------------------------------------------------------------------


SSM_SCAN_BWD_SPACE = ParamSpace([PowerOfTwoParam("chunk", 8, 512)])


def _ssm_scan_bwd_heuristic(ct_y, ct_h, xc, dt, B, C, A, h0):
    return {"chunk": _pick_pow2(xc.shape[1], 8, 64)}


def _ssm_scan_bwd_example():
    (xc, dt, B, C, A, h0), _ = _ssm_scan_example()
    import numpy as np

    rs = np.random.RandomState(1)
    ct_y = jnp.asarray(rs.randn(*xc.shape) * 0.5, jnp.float32)
    ct_h = jnp.asarray(rs.randn(*h0.shape) * 0.5, jnp.float32)
    return (ct_y, ct_h, xc, dt, B, C, A, h0), {}


@tunable(
    "ssm_scan_bwd",
    space=SSM_SCAN_BWD_SPACE,
    reference=ref.ssm_scan_bwd,
    heuristic=_ssm_scan_bwd_heuristic,
    dispatch=DispatchSpec(example=_ssm_scan_bwd_example,
                          data_parallel_args=(0, 1, 2, 3, 4, 5, 7),
                          # Reference VJP: grad-of-grad differentiates through.
                          vjp="reference"),
)
def ssm_scan_bwd(ct_y, ct_h, xc, dt, B, C, A, h0, *, chunk: int):
    """VJP of the scan with the remat window as the knob: differentiates the
    chunked form, so only [b, chunk, di, ds] coefficient slabs go live."""
    _, vjp = jax.vjp(
        lambda *a: ssm_scan_chunked(*a, chunk=chunk), xc, dt, B, C, A, h0
    )
    return vjp((ct_y, ct_h))


# ---------------------------------------------------------------------------
# Fused single-step decode update
# ---------------------------------------------------------------------------


def _ssm_update_kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, h_ref,
                       y_ref, hn_ref):
    dt = dt_ref[...]                              # [bb, bd] fp32
    xc = xc_ref[...].astype(jnp.float32)
    da = jnp.exp(dt[:, :, None] * a_ref[...][None])
    hn = da * h_ref[...] + (dt * xc)[:, :, None] * b_ref[...][:, None, :]
    y_ref[...] = jnp.sum(hn * c_ref[...][:, None, :], axis=-1)
    hn_ref[...] = hn


def ssm_update_pallas(
    xc: jax.Array,   # [b, di] model dtype
    dt: jax.Array,   # [b, di] fp32
    B: jax.Array,    # [b, ds] fp32
    C: jax.Array,    # [b, ds] fp32
    A: jax.Array,    # [di, ds] fp32
    h: jax.Array,    # [b, di, ds] fp32
    *,
    block_b: int,
    block_d: int,
    interpret: bool = False,
):
    b, di = xc.shape
    ds = A.shape[1]
    block_b = min(block_b, b)
    block_d = min(block_d, di)
    bp = b + (-b) % block_b
    dip = di + (-di) % block_d
    pad2 = lambda t: jnp.pad(t, ((0, bp - b), (0, dip - di)))
    xcp, dtp = pad2(xc), pad2(dt)
    Bp = jnp.pad(B, ((0, bp - b), (0, 0)))
    Cp = jnp.pad(C, ((0, bp - b), (0, 0)))
    Ap = jnp.pad(A, ((0, dip - di), (0, 0)))
    hp = jnp.pad(h, ((0, bp - b), (0, dip - di), (0, 0)))
    grid = (bp // block_b, dip // block_d)

    y, hn = pl.pallas_call(
        _ssm_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, ds), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, ds), lambda i, j: (i, 0)),
            pl.BlockSpec((block_d, ds), lambda i, j: (j, 0)),
            pl.BlockSpec((block_b, block_d, ds), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_d, ds), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, dip), jnp.float32),
            jax.ShapeDtypeStruct((bp, dip, ds), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(xcp, dtp, Bp, Cp, Ap, hp)
    return y[:b, :di], hn[:b, :di]


SSM_UPDATE_SPACE = ParamSpace(
    [
        PowerOfTwoParam("block_b", 8, 512),
        PowerOfTwoParam("block_d", 8, 512),
    ],
    [
        Constraint(
            lambda c: c["block_b"] * c["block_d"] * 16 * 8 + c["block_b"]
            * c["block_d"] * 12 <= TPU_V5E.vmem_bytes // 2,
            "decode-state tile exceeds VMEM budget",
        )
    ],
)


def _ssm_update_heuristic(xc, dt, B, C, A, h):
    b, di = xc.shape
    return {"block_b": _pick_pow2(b, 8, 256), "block_d": _pick_pow2(di, 8, 256)}


def _ssm_update_example():
    import numpy as np

    rs = np.random.RandomState(2)
    b, di, ds = 3, 8, 4
    return (
        jnp.asarray(rs.randn(b, di) * 0.5, jnp.float32),
        jnp.asarray(np.abs(rs.randn(b, di)) * 0.1 + 0.01, jnp.float32),
        jnp.asarray(rs.randn(b, ds) * 0.5, jnp.float32),
        jnp.asarray(rs.randn(b, ds) * 0.5, jnp.float32),
        jnp.asarray(-np.abs(rs.randn(di, ds)) - 0.1, jnp.float32),
        jnp.asarray(rs.randn(b, di, ds) * 0.3, jnp.float32),
    ), {}


def _ssm_update_bwd_plan(ct, xc, dt, B, C, A, h, **kwargs):
    from ..core.runtime import dispatch

    ct_y, ct_h = ct
    return dispatch(
        "ssm_update_bwd", ct_y.astype(jnp.float32), ct_h.astype(jnp.float32),
        xc, dt, B, C, A, h, **kwargs,
    )


@tunable(
    "ssm_update",
    space=SSM_UPDATE_SPACE,
    reference=ref.ssm_update,
    heuristic=_ssm_update_heuristic,
    dispatch=DispatchSpec(example=_ssm_update_example,
                          data_parallel_args=(0, 1, 2, 3, 5),
                          vjp="dispatch", bwd=_ssm_update_bwd_plan),
)
def ssm_update(xc, dt, B, C, A, h, *, block_b: int, block_d: int,
               interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return ssm_update_pallas(xc, dt, B, C, A, h, block_b=block_b,
                             block_d=block_d, interpret=interpret)


SSM_UPDATE_BWD_SPACE = ParamSpace([PowerOfTwoParam("block_d", 8, 512)])


def _ssm_update_bwd_heuristic(ct_y, ct_h, xc, dt, B, C, A, h):
    return {"block_d": _pick_pow2(xc.shape[1], 8, 256)}


def _ssm_update_bwd_example():
    (xc, dt, B, C, A, h), _ = _ssm_update_example()
    import numpy as np

    rs = np.random.RandomState(3)
    ct_y = jnp.asarray(rs.randn(*xc.shape) * 0.5, jnp.float32)
    ct_h = jnp.asarray(rs.randn(*h.shape) * 0.5, jnp.float32)
    return (ct_y, ct_h, xc, dt, B, C, A, h), {}


@tunable(
    "ssm_update_bwd",
    space=SSM_UPDATE_BWD_SPACE,
    reference=ref.ssm_update_bwd,
    heuristic=_ssm_update_bwd_heuristic,
    dispatch=DispatchSpec(example=_ssm_update_bwd_example,
                          data_parallel_args=(0, 1, 2, 3, 4, 5, 7),
                          # Reference VJP: grad-of-grad differentiates through.
                          vjp="reference"),
)
def ssm_update_bwd(ct_y, ct_h, xc, dt, B, C, A, h, *, block_d: int):
    """Blocked VJP of the decode update: d_inner is sliced into block_d
    strips (the working-set knob), B/C/state grads summed across strips."""
    di = xc.shape[1]
    bd = max(1, min(block_d, di))
    gx, gdt, gA, gh = [], [], [], []
    gB = gC = None
    for lo in range(0, di, bd):
        hi = min(lo + bd, di)
        _, vjp = jax.vjp(
            ref.ssm_update,
            xc[:, lo:hi], dt[:, lo:hi], B, C, A[lo:hi], h[:, lo:hi],
        )
        dxi, ddti, dBi, dCi, dAi, dhi = vjp((ct_y[:, lo:hi], ct_h[:, lo:hi]))
        gx.append(dxi)
        gdt.append(ddti)
        gA.append(dAi)
        gh.append(dhi)
        gB = dBi if gB is None else gB + dBi
        gC = dCi if gC is None else gC + dCi
    return (
        jnp.concatenate(gx, axis=1),
        jnp.concatenate(gdt, axis=1),
        gB,
        gC,
        jnp.concatenate(gA, axis=0),
        jnp.concatenate(gh, axis=1),
    )


# ---------------------------------------------------------------------------
# Abstract grid models (static legality; see core/gridmodel.py). The scan's
# sequential chunk axis carries the state scratch — the model declares it
# "arbitrary", which is what keeps the hn carry race-free. The *_bwd spaces
# are jnp-only (no pallas_call), so they register no model and
# legal_configs() returns their full enumeration. Nominal shapes use a
# production d_inner (2048), where the d-strip axis is genuinely tiled —
# that is where TPU lane alignment prunes block_d below 128 (ROADMAP item
# 1's "chosen for CPU interpret correctness, not lane alignment").
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _ssm_scan_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((4, 2048, 2048), (4, 2048, 2048), (4, 2048, 16),
                  (4, 2048, 16), (2048, 16), (4, 2048, 16))
    b, s, di = shapes[0]
    ds = shapes[4][1]
    chunk = min(config["chunk"], s)
    block_d = min(config["block_d"], di)
    sp = s + (-s) % chunk
    dip = di + (-di) % block_d
    grid = (b, dip // block_d, sp // chunk)
    xmap = lambda ib, id_, ic: (ib, ic, id_)
    bmap = lambda ib, id_, ic: (ib, ic, 0)
    amap = lambda ib, id_, ic: (id_, 0)
    hmap = lambda ib, id_, ic: (ib, id_, 0)
    return GridModel(
        "ssm_scan", grid, ("parallel", "parallel", "arbitrary"),
        (
            RefModel("xc", (1, chunk, block_d), xmap, (b, sp, dip)),
            RefModel("dt", (1, chunk, block_d), xmap, (b, sp, dip)),
            RefModel("B", (1, chunk, ds), bmap, (b, sp, ds)),
            RefModel("C", (1, chunk, ds), bmap, (b, sp, ds)),
            RefModel("A", (block_d, ds), amap, (dip, ds)),
            RefModel("h0", (1, block_d, ds), hmap, (b, dip, ds)),
            RefModel("y", (1, chunk, block_d), xmap, (b, sp, dip),
                     role="out"),
            RefModel("hn", (1, block_d, ds), hmap, (b, dip, ds),
                     role="out"),
        ),
    )


def _ssm_update_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((1024, 2048), (1024, 2048), (1024, 16), (1024, 16),
                  (2048, 16), (1024, 2048, 16))
    b, di = shapes[0]
    ds = shapes[4][1]
    block_b = min(config["block_b"], b)
    block_d = min(config["block_d"], di)
    bp = b + (-b) % block_b
    dip = di + (-di) % block_d
    grid = (bp // block_b, dip // block_d)
    xy = lambda i, j: (i, j)
    bmap = lambda i, j: (i, 0)
    amap = lambda i, j: (j, 0)
    hmap = lambda i, j: (i, j, 0)
    return GridModel(
        "ssm_update", grid, ("parallel", "parallel"),
        (
            RefModel("xc", (block_b, block_d), xy, (bp, dip)),
            RefModel("dt", (block_b, block_d), xy, (bp, dip)),
            RefModel("B", (block_b, ds), bmap, (bp, ds)),
            RefModel("C", (block_b, ds), bmap, (bp, ds)),
            RefModel("A", (block_d, ds), amap, (dip, ds)),
            RefModel("h", (block_b, block_d, ds), hmap, (bp, dip, ds)),
            RefModel("y", (block_b, block_d), xy, (bp, dip), role="out"),
            RefModel("hn", (block_b, block_d, ds), hmap, (bp, dip, ds),
                     role="out"),
        ),
    )


register_grid_model("ssm_scan", _ssm_scan_grid_model, space=SSM_SCAN_SPACE)
register_grid_model("ssm_update", _ssm_update_grid_model,
                    space=SSM_UPDATE_SPACE)
