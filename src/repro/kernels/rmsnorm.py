"""Fused RMSNorm — Pallas kernel with a tunable row-block.

Memory-bound: one read + one write of x. The knob is how many rows ride
through VMEM per grid step (block_rows); too small wastes grid overhead, too
large overflows VMEM for wide d_model. Fusing the reduction with the scale
multiply avoids the extra HBM round-trip XLA sometimes emits for the
mean-of-squares intermediate.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref


def _rmsnorm_kernel(x_ref, w_ref, o_ref, r_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    o_ref[...] = ((x * r) * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)
    r_ref[...] = r


def rmsnorm_pallas(
    x: jax.Array,       # [rows, d]
    weight: jax.Array,  # [d]
    *,
    block_rows: int,
    eps: float = 1e-6,
    interpret: bool = False,
    return_residuals: bool = False,
):
    """Fused rmsnorm; ``return_residuals=True`` additionally yields the
    per-row inverse rms ([rows] fp32) — the residual the backward kernel
    consumes instead of re-deriving it (see the dispatch residual contract).
    """
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // block_rows,)
    out, invrms = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, x.dtype),
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp, weight[None, :])
    if pad:
        out = out[:rows]
    if return_residuals:
        return out, invrms[:rows, 0]
    return out


RMSNORM_SPACE = ParamSpace(
    [PowerOfTwoParam("block_rows", 8, 4096)],
    [
        Constraint(
            # x tile + out tile (dtype) + fp32 intermediate, d up to 8192
            lambda c: c["block_rows"] * 8192 * 8 <= TPU_V5E.vmem_bytes // 2,
            "row block exceeds VMEM budget at max d_model",
        )
    ],
)


def _rmsnorm_heuristic(x, w):
    rows, d = x.shape
    target = max(8, min(1024, TPU_V5E.vmem_bytes // (2 * 8 * max(d, 1))))
    p = 8
    while p * 2 <= target:
        p *= 2
    return {"block_rows": p}


def _rmsnorm_canon(x, weight):
    """Flatten [..., d] -> [rows, d] for the kernel; reshape the output back.

    Callers (the norm layer) hand over activations of any rank; the db key
    and the kernel both want the 2D row view. The reference path is rank-
    generic and never sees this.
    """
    shape = x.shape
    return (x.reshape(-1, shape[-1]), weight), lambda out: out.reshape(shape)


def _rmsnorm_example():
    import numpy as np

    rs = np.random.RandomState(0)
    # 3D on purpose: exercises the flatten/reshape canonicalization.
    return (
        jnp.asarray(rs.randn(2, 16, 32), jnp.float32),
        jnp.asarray(rs.randn(32), jnp.float32),
    ), {}


def _rmsnorm_bwd_plan(ct, x, weight, y, invrms, **kwargs):
    """Backward plan for the fwd tunable: one fused bwd dispatch site.

    Residual contract: called with the forward's canonical args, the primal
    output and the saved inverse-rms rows — the bwd kernel consumes invrms
    instead of re-deriving it (one fewer reduction over x).
    """
    from ..core.runtime import dispatch

    del y  # the rmsnorm gradient needs x and invrms, not the output
    return dispatch("rmsnorm_bwd", ct, x, weight, invrms, **kwargs)


@tunable(
    "rmsnorm",
    space=RMSNORM_SPACE,
    reference=ref.rmsnorm_res,
    heuristic=_rmsnorm_heuristic,
    dispatch=DispatchSpec(reference=ref.rmsnorm,
                          canonicalize=_rmsnorm_canon, example=_rmsnorm_example,
                          vjp="dispatch", bwd=_rmsnorm_bwd_plan, residuals=1),
)
def rmsnorm(x, weight, *, block_rows: int, eps: float = 1e-6, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return rmsnorm_pallas(x, weight, block_rows=block_rows, eps=eps,
                          interpret=interpret, return_residuals=True)


# ---------------------------------------------------------------------------
# Fused backward: d_x and d_weight in one row-streamed pass
# ---------------------------------------------------------------------------


def _rmsnorm_bwd_kernel(ct_ref, x_ref, w_ref, r_ref, dx_ref, dw_ref, dw_scr,
                        *, d: int, r_steps: int):
    ri = pl.program_id(0)

    @pl.when(ri == 0)
    def _init():
        dw_scr[...] = jnp.zeros_like(dw_scr)

    x = x_ref[...].astype(jnp.float32)             # [block_rows, d]
    ct = ct_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)             # [1, d]
    r = r_ref[...]                                 # [block_rows, 1] fp32
    g = ct * w                                     # upstream × scale
    # dx_j = g_j·r − x_j·r³·mean_i(g_i·x_i); padded rows carry r = 0 (the
    # residual pad value), so their g·r and dw contribution vanish.
    dot = jnp.sum(g * x, axis=-1, keepdims=True)
    dx_ref[...] = (g * r - x * (r ** 3) * (dot / d)).astype(dx_ref.dtype)
    dw_scr[...] += jnp.sum(ct * (x * r), axis=0, keepdims=True)

    @pl.when(ri == r_steps - 1)
    def _done():
        dw_ref[...] = dw_scr[...].astype(dw_ref.dtype)


def rmsnorm_bwd_pallas(
    ct: jax.Array,      # [rows, d] — cotangent of the rmsnorm output
    x: jax.Array,       # [rows, d]
    weight: jax.Array,  # [d]
    invrms: jax.Array,  # [rows] fp32 — the forward's saved inverse rms
    *,
    block_rows: int,
    eps: float = 1e-6,
    interpret: bool = False,
):
    """Fused (d_x, d_weight) given the residual-threaded inverse rms.

    Pre-residual-contract, this kernel re-derived ``rsqrt(mean(x²)+eps)``
    per row; the forward now hands it over, so the bwd pass is pure
    elementwise+reduction work on (ct, x, invrms). ``eps`` is accepted for
    key/reference symmetry but unused — the residual already encodes it.
    """
    del eps
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        ct = jnp.pad(ct, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
        invrms = jnp.pad(invrms, (0, pad))
    r_steps = x.shape[0] // block_rows
    dx, dw = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, d=d, r_steps=r_steps),
        grid=(r_steps,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((1, d), weight.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        # the row grid carries the d_weight accumulator: sequential
        compiler_params=_compat.CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ct, x, weight[None, :], invrms.astype(jnp.float32)[:, None])
    return (dx[:rows] if pad else dx), dw[0]


def _rmsnorm_bwd_heuristic(ct, x, weight, invrms):
    return _rmsnorm_heuristic(x, weight)


def _rmsnorm_bwd_example():
    import numpy as np

    rs = np.random.RandomState(1)
    x = jnp.asarray(rs.randn(16, 32), jnp.float32)
    # The invrms residual must be consistent with x — the oracle recomputes
    # it from x while the kernel trusts the handed-in rows.
    invrms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1) + 1e-6)
    return (
        jnp.asarray(rs.randn(16, 32), jnp.float32),   # ct
        x,                                            # x
        jnp.asarray(rs.randn(32), jnp.float32),       # weight
        invrms,                                       # invrms residual
    ), {}


@tunable(
    "rmsnorm_bwd",
    space=RMSNORM_SPACE,
    reference=ref.rmsnorm_bwd,
    heuristic=_rmsnorm_bwd_heuristic,
    # ct, x and invrms are token-row-sharded. vjp="reference" (not "none"):
    # the oracle is plain differentiable jnp, so grad-of-grad can
    # differentiate *through* this gradient site.
    dispatch=DispatchSpec(example=_rmsnorm_bwd_example,
                          data_parallel_args=(0, 1, 3), vjp="reference"),
)
def rmsnorm_bwd(ct, x, weight, invrms, *, block_rows: int, eps: float = 1e-6,
                interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return rmsnorm_bwd_pallas(ct, x, weight, invrms, block_rows=block_rows,
                              eps=eps, interpret=interpret)


# ---------------------------------------------------------------------------
# Abstract grid models (static legality; see core/gridmodel.py). Both kernels
# tune over RMSNORM_SPACE, so a config is legal only if legal under both —
# the bwd model is also the race detector's shipped ground truth: dw maps
# every grid point to block (0, 0), which is only safe because the row axis
# is declared "arbitrary" (sequential).
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _rmsnorm_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((8192, 4096), (4096,))
    rows, d = shapes[0]
    br = min(config["block_rows"], rows)
    rp = rows + (-rows) % br
    row = lambda i: (i, 0)
    w0 = lambda i: (0, 0)
    return GridModel(
        "rmsnorm", (rp // br,), ("parallel",),
        (
            RefModel("x", (br, d), row, (rp, d)),
            RefModel("w", (1, d), w0, (1, d)),
            RefModel("out", (br, d), row, (rp, d), role="out"),
            RefModel("invrms", (br, 1), row, (rp, 1), role="out"),
        ),
    )


def _rmsnorm_bwd_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((8192, 4096), (8192, 4096), (4096,), (8192,))
    rows, d = shapes[1]
    br = min(config["block_rows"], rows)
    rp = rows + (-rows) % br
    row = lambda i: (i, 0)
    w0 = lambda i: (0, 0)
    return GridModel(
        "rmsnorm_bwd", (rp // br,), ("arbitrary",),
        (
            RefModel("ct", (br, d), row, (rp, d)),
            RefModel("x", (br, d), row, (rp, d)),
            RefModel("w", (1, d), w0, (1, d)),
            RefModel("invrms", (br, 1), row, (rp, 1)),
            RefModel("dx", (br, d), row, (rp, d), role="out"),
            RefModel("dw", (1, d), w0, (1, d), role="out"),
        ),
    )


register_grid_model("rmsnorm", _rmsnorm_grid_model, space=RMSNORM_SPACE)
register_grid_model("rmsnorm_bwd", _rmsnorm_bwd_grid_model, space=RMSNORM_SPACE)
