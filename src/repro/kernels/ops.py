"""DEPRECATED deployment shims — migration guide from the global-mode API.

This module used to *be* the deployment surface: a hand-written wrapper per
kernel, a process-global ``_STATE`` mode dict, and a hard-coded
exact→cover→heuristic chain inside each wrapper. All of that now lives in
the dispatch runtime (:mod:`repro.core.runtime`); what remains here is a
thin back-compat veneer generated from the tunable registry.

Old API (still works, discouraged)           New API
-----------------------------------------    ----------------------------------
``ops.set_kernel_mode(True)``                ``with repro.runtime(mode="kernel"): ...``
``ops.kernels_enabled()``                    ``repro.current_runtime().kernel_mode_active``
``set_default_db(db); ops.matmul(x, w)``     ``with repro.runtime(db=db): repro.dispatch("matmul", x, w)``
``ops.matmul(x, w, config={...})``           unchanged (``config=`` bypasses resolution)
hand-written wrapper per new kernel          none: ``@tunable(..., dispatch=DispatchSpec(...))``
                                             auto-generates the entry point; this module
                                             picks it up via ``__getattr__`` with zero edits

Why migrate:

* **Scoped, nestable, thread-isolated** — serving, campaign evaluation, and
  tests each pin their own db/mode on a context-local stack instead of
  fighting over one global flag (``set_kernel_mode`` now mutates only the
  process-*default* runtime and cannot see scoped ones).
* **Pluggable resolution** — the tier chain (ExactHit → TuneNow → CoverSet
  → Heuristic → Reference) is a policy pipeline you can reorder or extend.
* **Observable** — per-call telemetry counts which tier served each
  kernel×shape-bucket, and a per-runtime resolution cache keeps repeated
  jit traces from re-hitting the database.

Semantics are unchanged: ``ops.matmul`` et al. resolve through the *active*
runtime, whose default policy reproduces the old precedence exactly —
stored best variant for (platform, kernel, shape-bucket, dtype), else the
campaign's 'few fit most' cover entry, else the shape heuristic, with the
pure-jnp reference path when kernels are disabled (``REPRO_USE_PALLAS=0``
or ``mode="reference"``).
"""
from __future__ import annotations

from ..core import runtime as _rt

# Importing the kernel modules is what populates the tunable registry —
# `from repro.kernels import ops` must keep working as a one-stop import.
from . import ref  # noqa: F401  (re-exported: the reference oracles)
from .attention import flash_attention as _flash_tunable  # noqa: F401
from .matmul import matmul as _matmul_tunable  # noqa: F401
from .rmsnorm import rmsnorm as _rmsnorm_tunable  # noqa: F401
from .xent import softmax_xent as _xent_tunable  # noqa: F401

# Deprecated: prefer `with repro.runtime(mode=...)` scopes.
set_kernel_mode = _rt.set_kernel_mode
kernels_enabled = _rt.kernels_enabled

# Auto-generated entry points for the in-tree kernels (kept as real module
# attributes so tooling and `from repro.kernels.ops import matmul` work).
matmul = _rt.entry_point("matmul")
flash_attention = _rt.entry_point("flash_attention")
rmsnorm = _rt.entry_point("rmsnorm")
softmax_xent = _rt.entry_point("softmax_xent")


def __getattr__(name: str):
    """Any *other* registered tunable dispatches with zero edits here."""
    if name.startswith("_"):
        raise AttributeError(name)
    try:
        _rt._as_tunable(name)
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r} "
            "(and no tunable of that name is registered)"
        ) from None
    return _rt.entry_point(name)
