"""Deployment wrappers: tuning-database dispatch + reference fallback.

This is where the paper's 'sustainable performance portability' is cashed
out at runtime: callers use `ops.matmul(x, w)` and get

  1. the stored best variant for (platform, kernel, shape-bucket, dtype) if
     the tuning database has one (zero-cost specialization — a campaign-
     exported database makes this the common case),
  2. else the nearest 'few fit most' cover-set entry the campaign clustered
     from its winners — a measured config from the closest tuned bucket,
     still zero tuning at serve time,
  3. else the shape heuristic default (the 'vendor baseline'),
  4. or the pure-jnp reference path when Pallas is disabled
     (`REPRO_USE_PALLAS=0`, or during multi-pod dry-runs, where Pallas
     cannot lower for TPU from a CPU host).

Populate the database offline with ``python -m repro.campaign`` (plan →
run → export); `ServingEngine.warmup` pre-resolves every slot-pool bucket
through this same chain. Serving dispatch sees two shape families: batch-1
admission prefills at power-of-two seq buckets, and decode-pool calls at
`max_batch` rows (gemm/norm x-shapes of [max_batch, d], attention lookups
with a single query row against an s-deep cache). `shape_bucket` keeps
dims ≤ 8 exact, so small decode batches hit their own records rather than
aliasing a prefill bucket. `set_kernel_mode` flips the whole model stack
between kernel and reference paths; both compute identical math (enforced
by tests/test_kernels_*).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from ..core import default_db, tune_or_lookup
from . import ref
from .attention import flash_attention as _flash_tunable
from .matmul import matmul as _matmul_tunable
from .rmsnorm import rmsnorm as _rmsnorm_tunable
from .xent import softmax_xent as _xent_tunable

_STATE = {"use_pallas": os.environ.get("REPRO_USE_PALLAS", "0") == "1"}


def set_kernel_mode(use_pallas: bool) -> None:
    _STATE["use_pallas"] = bool(use_pallas)


def kernels_enabled() -> bool:
    return _STATE["use_pallas"]


def matmul(x, w, *, config: Optional[dict] = None):
    if not _STATE["use_pallas"]:
        return ref.matmul(x, w)
    cfg = config or tune_or_lookup(_matmul_tunable, (x, w))
    return _matmul_tunable.variant(**cfg)(x, w)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None, config=None):
    if not _STATE["use_pallas"]:
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    cfg = config or tune_or_lookup(_flash_tunable, (q, k, v), key_extra=f"c{causal}w{window}")
    return _flash_tunable.variant(**cfg)(q, k, v, causal=causal, window=window, scale=scale)


def rmsnorm(x, weight, *, eps=1e-6, config=None):
    if not _STATE["use_pallas"]:
        return ref.rmsnorm(x, weight, eps)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    cfg = config or tune_or_lookup(_rmsnorm_tunable, (x2, weight))
    return _rmsnorm_tunable.variant(**cfg)(x2, weight, eps=eps).reshape(shape)


def softmax_xent(logits, labels, *, config=None):
    if not _STATE["use_pallas"]:
        return ref.softmax_xent(logits, labels)
    cfg = config or tune_or_lookup(_xent_tunable, (logits, labels))
    return _xent_tunable.variant(**cfg)(logits, labels)
