"""Migration guide: the old deployment surfaces and where they went.

This module used to *be* the deployment surface: a hand-written wrapper per
kernel, a process-global ``_STATE`` mode dict, and a hard-coded
exact→cover→heuristic chain inside each wrapper. All of that lives in the
dispatch runtime now (:mod:`repro.core.runtime`), and the deprecated shims
(``ops.set_kernel_mode`` / ``ops.kernels_enabled`` / ``ops.<kernel>``,
DeprecationWarning since the runtime redesign) have completed their cycle
and are **removed**. What remains here is the migration guide plus the
registry-populating imports (``from repro.kernels import ops`` keeps working
as a one-stop import for the kernel tunables).

Old API (removed)                            New API
-----------------------------------------    ----------------------------------
``ops.set_kernel_mode(True)``                ``with repro.runtime(mode="kernel"): ...``
``ops.kernels_enabled()``                    ``repro.current_runtime().kernel_mode_active``
``set_default_db(db); ops.matmul(x, w)``     ``with repro.runtime(db=db): repro.dispatch("matmul", x, w)``
``ops.matmul(x, w, config={...})``           ``repro.dispatch("matmul", x, w, config={...})``
hand-written wrapper per new kernel          none: ``@tunable(..., dispatch=DispatchSpec(...))``
                                             auto-generates the entry point

Database-key semantics (what a record must look like to hit):

* **Platform namespace** — keys carry the *detected* platform
  (``tpu-v4`` / ``tpu-v5e`` / ``cpu-host``, fingerprinted from
  ``jax.devices()``). Override with ``REPRO_PLATFORM``,
  ``repro.core.set_platform_override(...)``, or a per-runtime
  ``repro.runtime(platform=...)`` — an unknown name clones the fingerprinted
  profile under the new name, fully isolating the namespace.
* **Promoted dtype** — the dtype field is the JAX promotion of *all* array
  args (order-independent). Records for mixed-dtype calls keyed on a single
  argument's dtype (notably softmax_xent, once keyed ``int32``) no longer
  exact-hit; they still warm-start re-tunes as transfer neighbours.
* **Local shard shapes** — inside an active ``mesh_context`` (training, any
  jit-sharded trace), batch-sharded args (``DispatchSpec.data_parallel_args``,
  or a per-call ``dp_dims`` override for transposed backward operands) are
  keyed on their per-device *local* shard shape: a record tuned at
  ``(batch/dp, seq, d)`` is the record dispatch finds. Unsharded call sites
  are unchanged. Records tuned for sharded sites *before* local-shape
  keying were keyed on global shapes — they only warm-start; re-plan with
  ``campaign plan --train-mesh ...`` and re-run the campaign.
* **Backward keys** — gradients are dispatch sites too (``DispatchSpec.bwd``
  + ``vjp="dispatch"``): matmul's dL/dx and dL/dw resolve as
  transposed-operand ``matmul`` keys, and flash attention / rmsnorm /
  softmax-xent resolve dedicated ``flash_attention_bwd`` / ``rmsnorm_bwd``
  / ``softmax_xent_bwd`` tunables with their own records. The training
  planner (``plan_training_jobs``) emits this backward roster at local
  shard shapes, so ``campaign plan --train-mesh`` pre-tunes it.
  **Migration hazard**: campaigns exported before the tuned backward plane
  have NO backward records — a kernel-mode train step against such a
  database resolves its gradient sites at warm-start/cover/heuristic tiers,
  never ExactHit. Re-plan and re-run the campaign to bank them; or pin
  ``repro.runtime(bwd_dispatch=False)`` to restore the old reference-VJP
  recompute (fwd-only tuning) while you do.

Residual contract (``DispatchSpec.residuals``)
----------------------------------------------

Forward tunables may return auxiliary outputs alongside the primal —
forward intermediates the backward pass would otherwise recompute:

===================  ==============================  =======================
tunable              residual                        consumed by
===================  ==============================  =======================
``flash_attention``  per-query logsumexp             ``flash_attention_bwd``
                     ``[b, h, s_q]`` f32             (with the primal ``o``
                                                     for delta rows)
``rmsnorm``          per-row inverse rms ``[rows]``  ``rmsnorm_bwd``
``softmax_xent``     per-row logsumexp ``[rows]``    ``softmax_xent_bwd``
===================  ==============================  =======================

With ``residuals=N`` the bound variant (and the *tuning* reference — the
``ref.*_res`` oracles) returns ``(primal, *aux)``; dispatch saves the
canonical args, the primal, and the aux into the ``custom_vjp`` residuals
and calls the backward plan as ``bwd(ct, *args, primal, *aux, **kwargs)``.
Callers only ever see the primal; the *deployment* reference stays
primal-only. The payoff is structural: ``flash_attention_bwd`` dropped its
(o, lse) recompute pass — two Pallas calls instead of three — and the
rmsnorm/xent backward kernels consume their residual instead of a
re-reduction over the inputs.

**Migration hazard (residual keys)**: the residual args are *part of the
backward db key* (an extra shape, and f32 residuals promote the key dtype
of a bf16 site). ``*_bwd`` records banked before the residual contract are
keyed on the old pre-residual signature — they never ExactHit a
residual-threaded gradient site, only warm-start re-tunes.
``python -m repro.campaign check`` flags such records as warm-start-only;
re-plan (``campaign plan --train-mesh ...``) and re-run to bank current
keys.

Fusion opt-in (``runtime.fusion_wins``)
---------------------------------------

The fused-epilogue tunables (``matmul_bias_act``, ``rmsnorm_matmul``)
extend the database-key story with a *resolution-policy hook*: model sites
call ``repro.core.runtime.fusion_wins("matmul_bias_act", x, w, b, ...)``
and route through the fused kernel only when kernel mode is active AND the
database holds a valid record for that exact fused key — i.e. a campaign
measured the fusion and banked it. No record, no fusion: the site keeps
its unfused ``matmul``/``rmsnorm`` dispatches, so exact-hit coverage is
invariant under the routing and fusion can never *introduce* a
heuristic-tier site. Their gradients decompose onto plain ``matmul`` /
``rmsnorm`` / ``rmsnorm_bwd`` records (``DispatchSpec.bwd_via`` declares
the decomposition; the contracts pass verifies it).

Arch coverage — which tunables each model family dispatches
------------------------------------------------------------

Every registered arch family now routes its hot contractions through the
registry; the planners (``plan_train_jobs`` / ``plan_training_jobs`` /
``plan_serving_jobs``) emit roster rows for every cell below, so a planned
campaign can take ANY config to 100% ExactHit, fwd and bwd:

===========  =================================================================
family       dispatch sites (beyond the shared matmul/rmsnorm/softmax_xent)
===========  =================================================================
attention    ``flash_attention`` (+ ``flash_attention_bwd``); QKV/out/FFN
             projections as ``matmul``
fused        ``matmul_bias_act`` (dense-with-bias; ffn gelu/silu epilogues)
             and ``rmsnorm_matmul`` (final-norm → unembed) — *opt-in* per
             site via ``fusion_wins`` (tuned record required); gradients
             decompose onto matmul/rmsnorm/rmsnorm_bwd records (bwd_via)
mamba (SSM)  ``ssm_scan`` chunked selective scan for train/prefill
             (+ ``ssm_scan_bwd``), ``ssm_update`` fused single-step state
             update for decode (+ ``ssm_update_bwd``); in/x/dt/out
             projections as ``matmul`` (dt_proj and out_proj run f32)
moe          ``expert_gemm`` grouped (experts × capacity × hidden) gemm for
             all three expert-FFN contractions; backward resolves
             transposed-operand ``expert_gemm`` keys (dL/dx, dL/dw). The
             router matmul stays plain jnp (below the tile floor).
mlstm        q/k/v/in/out projections and the post-cell gemms as ``matmul``;
             the inner score matmuls carry fused decay masks and are NOT
             substitutable by plain matmul records (kept in-model)
slstm        input projection + the three GeGLU MLP gemms as ``matmul``
===========  =================================================================

Hybrid configs (jamba = attention + mamba + moe, arctic = attention + moe)
compose rows per segment. SSM jobs key dt/A-conditioned arguments (see
``campaign.runner.materialize_args``); expert_gemm jobs are not
batch-sharded (capacity derives from the *global* traced token count).

Semantics are otherwise unchanged: dispatch resolves through the *active*
runtime, whose default policy reproduces the old precedence exactly —
stored best variant for (platform, kernel, shape-bucket, dtype), else the
campaign's 'few fit most' cover entry, else the shape heuristic, with the
pure-jnp reference path when kernels are disabled (``REPRO_USE_PALLAS=0``
or ``mode="reference"``).

Observability (``repro.obs``)
-----------------------------

The dispatch plane is instrumented: every resolve/dispatch site, trainer
step phase, serving tick, and campaign job reports into the *ambient
collector* — ``repro.obs.collect(...)`` scoped the same contextvar way as
``repro.runtime`` (thread/async isolated, nestable).

* **Spans** — ``with obs.span("train.step", step=i): ...`` builds a
  contextvar-scoped span tree; each span lands as a structured event in a
  bounded ring buffer and as a ``span.<name>`` latency histogram. Pass
  ``xla_annotations=True`` to ``collect`` to mirror spans into
  ``jax.profiler.TraceAnnotation`` so they show up in XLA profiles.
* **Metrics** — counters / gauges / log-bucketed histograms (p50/p95/p99
  in bounded memory). Built-in hot-path series: ``dispatch.resolve_s``
  (per-tier, cache hit/miss), ``dispatch.calls``, ``train.step_s`` /
  ``train.tokens_per_s``, ``serve.admission_s`` / ``serve.per_token_s`` /
  ``serve.queue_depth``, ``campaign.job_s`` / ``campaign.speedup``.
* **Drift** — ``python -m repro.obs report --drift --db <db>`` (or
  ``python -m repro.campaign drift``) replays each stored record's winning
  config, attributes live seconds to %-of-tuned-best and %-of-roofline
  (``tools/analytic.site_roofline_seconds``), and ranks regressions — the
  re-tune queue.
* **Export** — ``--metrics-out`` on ``launch.train`` / ``launch.serve`` /
  ``campaign run`` writes a snapshot JSON; render with
  ``python -m repro.obs report --metrics``, compare runs with
  ``python -m repro.obs diff``; ``write_prom`` emits a Prometheus textfile
  and ``write_jsonl`` an event log.

**Overhead guarantee**: the process-default collector is *disabled*; every
instrumentation site starts with one ``if not collector.enabled`` branch,
so a tuned kernel-mode step pays no measurable cost (<2%, asserted by
``benchmarks/obs_overhead.py`` in CI; <5% with default sampling enabled).

Static analysis (``repro.analysis``)
------------------------------------

The dispatch contract is now *machine-checked* without compiling anything
— ``python -m repro.analysis check --strict`` runs in CI and fails the
build on violations:

* **Dispatch-completeness lint** — raw FLOP sites in ``repro.models``
  (``jnp.einsum`` / ``@`` / ``jax.nn.softmax`` / ``jax.lax.scan``) must
  either route through the registry or carry an explicit pragma::

      # repro: allow-raw(<reason — single line, no parentheses>)

  Same-line covers that line; a pragma on its own line covers the whole
  statement that starts below it (so one above a ``def`` blesses the
  function body). Adding a new model? Either ``repro.dispatch(...)`` the
  contraction or annotate *why* it stays raw — the lint makes "forgot to
  dispatch" a CI failure instead of a silent heuristic-tier fallback.
* **Kernel legality** — every Pallas tunable registers an abstract grid
  model (``repro.core.gridmodel.register_grid_model``): grid shape,
  BlockSpec blocks, index maps, and dimension semantics as pure functions
  of the config. The checker abstractly evaluates the FULL config space
  per platform fingerprint for write-write races across parallel grid
  axes, index-map out-of-bounds, and TPU sublane/lane tiling (dtype-aware:
  8 rows f32, 16 bf16, 128 lanes). Adding a new kernel without a model is
  a contracts warning; adding one WITH a model gets static pruning for
  free: ``ParamSpace.legal_configs(platform)`` feeds the tuner's pre-pass
  (illegal configs marked pruned, zero measurement budget spent) and
  ``campaign plan`` stamps per-kernel pruned counts into the manifest
  (``campaign status`` prints them).
* **Registry contracts + artifact checks** — ``vjp="dispatch"`` tunables
  must dispatch a registered ``*_bwd`` sibling (or the forward kernel for
  transposed-operand gradients) with an oracle — or declare their
  decomposition via ``DispatchSpec.bwd_via``, verified against the plan's
  source; planner rosters must be registry-covered. ``python -m repro.campaign check --db ... --manifest
  ...`` extends this to shipped artifacts: the stale single-arg-dtype keys
  and pre-backward-plane manifests described above are now *detected*, not
  just documented (stale ``int32`` softmax_xent keys are an error; missing
  backward rosters and expert-capacity bucket drift are flagged).

Fault isolation (guarded dispatch, ``BackgroundTune``)
------------------------------------------------------

The ops-era wrappers executed the chosen variant bare: a record that
miscompiled on a new driver, or a kernel that faulted on one host,
raised straight through the train/serve step. Kernel-mode dispatch is
now **guarded by default** — a variant that throws (at trace time or
concretely) quarantines its database key in the runtime's
:class:`~repro.core.runtime.HealthBook` and the call falls through the
remaining tiers (heuristic config if it differs from the faulting one,
reference terminally), so one poisoned record degrades one bucket
instead of taking down the run. Quarantine has two levels: ``record``
(the stored config is bad — db tiers are skipped for that key) and
``kernel`` (the kernel itself cannot execute — straight to reference);
entries back off exponentially and re-probe when the backoff lapses, so
a fixed driver heals without a restart. Observability:
``dispatch.quarantine`` counter + a ``warn_once`` event per (key, level),
both exercised by ``tests/test_chaos.py``.

Migration notes:

* ``repro.runtime(guard=False)`` restores the old raise-through
  behavior (real tracebacks — debugging, benchmarks). An explicit
  ``config=`` override is always unguarded: the caller pinned a variant
  by hand and wants the traceback.
* ``repro.runtime(guard_nonfinite=True)`` additionally validates each
  bucket's FIRST resolution for NaN/Inf output (then caches a plain
  resolution) — the poisoned-record drill for silent corruption.
* The old "miss tunes inline" serving posture
  (``allow_tune=True`` + TuneNow) blocks a request on a full search.
  Use :func:`repro.core.background_policy` instead: misses answer with
  the heuristic config immediately (tier ``"bgtune"``, uncached) while
  a :class:`~repro.core.BackgroundTuner` worker tunes off-path and
  ``db.put``s the winner under the request's own key — the next resolve
  ExactHits, converging live traffic to 100% ExactHit with zero
  request-path stalls (ROADMAP item 2; ``tests/test_bgtune.py`` gates
  the convergence and the never-blocks latency bound).
* Deterministic failure drills live in :mod:`repro.testing.faults`
  (``FaultPlan`` / ``fault_point``) — the named sites
  (``dispatch.kernel:*``, ``bgtune.worker:*``, ``campaign.job:*``,
  ``db.load:*``, ``checkpoint.write:*``, ``train.step:*``) are compiled
  into the shipped library so staging environments can run the same
  seeded chaos scenarios CI does.
"""
from __future__ import annotations

# Importing the kernel modules is what populates the tunable registry —
# `from repro.kernels import ops` must keep working as a one-stop import.
from . import ref  # noqa: F401  (re-exported: the reference oracles)
from .attention import flash_attention as _flash_tunable  # noqa: F401
from .attention import flash_attention_bwd as _flash_bwd_tunable  # noqa: F401
from .fused import matmul_bias_act as _mba_tunable  # noqa: F401
from .fused import rmsnorm_matmul as _rmm_tunable  # noqa: F401
from .matmul import matmul as _matmul_tunable  # noqa: F401
from .moe_gemm import expert_gemm as _expert_gemm_tunable  # noqa: F401
from .rmsnorm import rmsnorm as _rmsnorm_tunable  # noqa: F401
from .ssm_scan import ssm_scan as _ssm_scan_tunable  # noqa: F401
from .ssm_scan import ssm_scan_bwd as _ssm_scan_bwd_tunable  # noqa: F401
from .ssm_scan import ssm_update as _ssm_update_tunable  # noqa: F401
from .ssm_scan import ssm_update_bwd as _ssm_update_bwd_tunable  # noqa: F401
from .rmsnorm import rmsnorm_bwd as _rmsnorm_bwd_tunable  # noqa: F401
from .xent import softmax_xent as _xent_tunable  # noqa: F401
from .xent import softmax_xent_bwd as _xent_bwd_tunable  # noqa: F401
