"""DEPRECATED deployment shims — migration guide from the global-mode API.

This module used to *be* the deployment surface: a hand-written wrapper per
kernel, a process-global ``_STATE`` mode dict, and a hard-coded
exact→cover→heuristic chain inside each wrapper. All of that now lives in
the dispatch runtime (:mod:`repro.core.runtime`); what remains here is a
thin back-compat veneer generated from the tunable registry. Every name in
this module now emits a :class:`DeprecationWarning` — this is the last stop
of the deprecation cycle before removal.

Old API (works, warns)                       New API
-----------------------------------------    ----------------------------------
``ops.set_kernel_mode(True)``                ``with repro.runtime(mode="kernel"): ...``
``ops.kernels_enabled()``                    ``repro.current_runtime().kernel_mode_active``
``set_default_db(db); ops.matmul(x, w)``     ``with repro.runtime(db=db): repro.dispatch("matmul", x, w)``
``ops.matmul(x, w, config={...})``           ``repro.dispatch("matmul", x, w, config={...})``
hand-written wrapper per new kernel          none: ``@tunable(..., dispatch=DispatchSpec(...))``
                                             auto-generates the entry point; this module
                                             picks it up via ``__getattr__`` with zero edits

Why migrate:

* **Scoped, nestable, thread-isolated** — serving, training, campaign
  evaluation, and tests each pin their own db/mode on a context-local stack
  instead of fighting over one global flag (``set_kernel_mode`` now mutates
  only the process-*default* runtime and cannot see scoped ones).
* **Pluggable resolution** — the tier chain (ExactHit → TuneNow → CoverSet
  → Heuristic → Reference) is a policy pipeline you can reorder or extend.
* **Observable** — per-call telemetry counts which tier served each
  kernel×shape-bucket (exportable to the campaign report via
  ``--telemetry``), and a bounded per-runtime LRU resolution cache keeps
  repeated jit traces from re-hitting the database.
* **Trainable** — kernel-mode dispatch wraps variants in a reference-VJP
  (``DispatchSpec.vjp``), so ``jax.grad`` through a tuned Pallas kernel
  works; the old wrappers could only run forward.

Database-key semantics (what a record must look like to hit):

* **Platform namespace** — keys carry the *detected* platform
  (``tpu-v4`` / ``tpu-v5e`` / ``cpu-host``, fingerprinted from
  ``jax.devices()``). Override with ``REPRO_PLATFORM``,
  ``repro.core.set_platform_override(...)``, or a per-runtime
  ``repro.runtime(platform=...)`` — an unknown name clones the fingerprinted
  profile under the new name, fully isolating the namespace.
* **Promoted dtype** — the dtype field is the JAX promotion of *all* array
  args (order-independent). Pre-PR-3 records for mixed-dtype calls (notably
  softmax_xent, keyed ``int32``) no longer exact-hit; they still warm-start
  re-tunes as transfer neighbours.
* **Local shard shapes** — inside an active ``mesh_context`` (training, any
  jit-sharded trace), batch-leading args (``DispatchSpec.data_parallel_args``)
  are keyed on their per-device *local* shard shape: a record tuned at
  ``(batch/dp, seq, d)`` is the record dispatch finds. Unsharded call sites
  are unchanged. **Migration hazard**: records tuned before this change for
  *sharded* call sites were keyed on global shapes — they no longer
  exact-hit under a mesh and only warm-start re-tunes; re-plan with
  ``campaign plan --train-mesh ...`` (which emits local-shape training jobs)
  and re-run the campaign to rebuild them.

Semantics are otherwise unchanged: ``ops.matmul`` et al. resolve through the
*active* runtime, whose default policy reproduces the old precedence exactly
— stored best variant for (platform, kernel, shape-bucket, dtype), else the
campaign's 'few fit most' cover entry, else the shape heuristic, with the
pure-jnp reference path when kernels are disabled (``REPRO_USE_PALLAS=0``
or ``mode="reference"``).
"""
from __future__ import annotations

import warnings

from ..core import runtime as _rt

# Importing the kernel modules is what populates the tunable registry —
# `from repro.kernels import ops` must keep working as a one-stop import.
from . import ref  # noqa: F401  (re-exported: the reference oracles)
from .attention import flash_attention as _flash_tunable  # noqa: F401
from .matmul import matmul as _matmul_tunable  # noqa: F401
from .rmsnorm import rmsnorm as _rmsnorm_tunable  # noqa: F401
from .xent import softmax_xent as _xent_tunable  # noqa: F401

# Deprecated: prefer `with repro.runtime(mode=...)` scopes. The warnings are
# emitted by the runtime shims themselves.
set_kernel_mode = _rt.set_kernel_mode
kernels_enabled = _rt.kernels_enabled


def _deprecated_entry(name: str):
    """An ``ops.<kernel>`` shim: warns, then dispatches through the runtime."""
    inner = _rt.entry_point(name)

    def call(*args, **kwargs):
        warnings.warn(
            f"repro.kernels.ops.{name} is deprecated; dispatch through the "
            f'runtime instead: repro.dispatch("{name}", ...) under a '
            "`with repro.runtime(...)` scope (see the repro.kernels.ops "
            "module docstring for the migration guide)",
            DeprecationWarning, stacklevel=2,
        )
        return inner(*args, **kwargs)

    call.__name__ = name
    call.__qualname__ = name
    call.__doc__ = inner.__doc__
    return call


# Deprecated entry points for the in-tree kernels (kept as real module
# attributes so tooling and `from repro.kernels.ops import matmul` work).
matmul = _deprecated_entry("matmul")
flash_attention = _deprecated_entry("flash_attention")
rmsnorm = _deprecated_entry("rmsnorm")
softmax_xent = _deprecated_entry("softmax_xent")


def __getattr__(name: str):
    """Any *other* registered tunable dispatches (with a warning) here."""
    if name.startswith("_"):
        raise AttributeError(name)
    try:
        _rt._as_tunable(name)
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r} "
            "(and no tunable of that name is registered)"
        ) from None
    return _deprecated_entry(name)
