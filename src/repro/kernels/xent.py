"""Fused softmax-cross-entropy over large vocabularies — Pallas kernel.

The assigned archs have vocabs up to 262144; materializing fp32 softmax for
[tokens, vocab] is the single largest activation in training. This kernel
streams vocab blocks through VMEM with an online logsumexp (the same running
(m, l) trick as flash attention) and extracts the label logit on the fly, so
HBM traffic is one read of the logits — never a [tokens, vocab] write.

Tunables: block_rows × block_v VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref

_NEG_INF = -1e30


def _xent_kernel(
    logits_ref, labels_ref, loss_ref, lse_ref,
    m_scr, l_scr, ll_scr,
    *,
    block_v: int,
    v_steps: int,
):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        ll_scr[...] = jnp.zeros_like(ll_scr)

    x = logits_ref[...].astype(jnp.float32)        # [block_rows, block_v]
    m_prev = m_scr[...]                            # [block_rows, 1]
    m_new = jnp.maximum(m_prev, x.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.exp(x - m_new).sum(axis=-1, keepdims=True)
    m_scr[...] = m_new

    # Gather the label logit if it falls inside this vocab block.
    labels = labels_ref[...]                       # [block_rows, 1] int32
    v_lo = vi * block_v
    cols = v_lo + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = cols == labels
    ll_scr[...] += jnp.sum(jnp.where(hit, x, 0.0), axis=-1, keepdims=True)

    @pl.when(vi == v_steps - 1)
    def _done():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        loss_ref[...] = (lse - ll_scr[...]).astype(loss_ref.dtype)
        lse_ref[...] = lse


def softmax_xent_pallas(
    logits: jax.Array,  # [rows, vocab]
    labels: jax.Array,  # [rows] int32
    *,
    block_rows: int,
    block_v: int,
    interpret: bool = False,
    return_residuals: bool = False,
):
    """Online-lse cross entropy; ``return_residuals=True`` additionally
    yields the per-row logsumexp ([rows] fp32) the backward kernel consumes
    instead of re-streaming the logits (the dispatch residual contract).
    """
    rows, vocab = logits.shape
    block_rows = min(block_rows, rows)
    block_v = min(block_v, vocab)
    pad_r = (-rows) % block_rows
    pad_v = (-vocab) % block_v
    if pad_r or pad_v:
        # Pad logits with -inf-ish so padded columns don't perturb logsumexp;
        # padded rows get label 0 and are sliced away.
        logits = jnp.pad(logits, ((0, pad_r), (0, pad_v)), constant_values=_NEG_INF)
        labels = jnp.pad(labels, (0, pad_r))
    rp, vp = logits.shape
    v_steps = vp // block_v
    grid = (rp // block_rows, v_steps)

    loss, lse = pl.pallas_call(
        functools.partial(_xent_kernel, block_v=block_v, v_steps=v_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_v), lambda ri, vi: (ri, vi)),
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(logits, labels.astype(jnp.int32)[:, None])
    if return_residuals:
        return loss[:rows, 0], lse[:rows, 0]
    return loss[:rows, 0]


XENT_SPACE = ParamSpace(
    [
        PowerOfTwoParam("block_rows", 8, 1024),
        PowerOfTwoParam("block_v", 512, 32768),
    ],
    [
        Constraint(
            lambda c: c["block_rows"] * c["block_v"] * 6 <= TPU_V5E.vmem_bytes // 2,
            "xent tile exceeds VMEM budget",
        )
    ],
)


def _xent_heuristic(logits, labels):
    rows, vocab = logits.shape
    return {"block_rows": min(256, max(8, 1 << (int(rows) - 1).bit_length() if rows < 256 else 256)),
            "block_v": min(8192, max(512, vocab if vocab < 512 else 8192))}


def _xent_example():
    import numpy as np

    rs = np.random.RandomState(0)
    return (
        jnp.asarray(rs.randn(16, 640) * 2, jnp.float32),
        jnp.asarray(rs.randint(0, 640, 16), jnp.int32),
    ), {}


def _xent_bwd_plan(ct, logits, labels, loss, lse, **kwargs):
    """Backward plan: d_logits is one fused bwd dispatch site; labels carry
    no gradient (None → float0 cotangent).

    Residual contract: the forward's per-row logsumexp rides in as ``lse``,
    so the bwd kernel skips the online-lse re-streaming pass entirely.
    """
    from ..core.runtime import dispatch

    del loss  # d_logits needs the lse residual, not the loss values
    return dispatch("softmax_xent_bwd", ct, logits, labels, lse, **kwargs), None


@tunable(
    "softmax_xent",
    space=XENT_SPACE,
    reference=ref.softmax_xent_res,
    heuristic=_xent_heuristic,
    # logits AND labels lead with the token-row dim (both batch-sharded).
    dispatch=DispatchSpec(reference=ref.softmax_xent,
                          example=_xent_example, data_parallel_args=(0, 1),
                          vjp="dispatch", bwd=_xent_bwd_plan, residuals=1),
)
def softmax_xent(logits, labels, *, block_rows: int, block_v: int, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return softmax_xent_pallas(
        logits, labels, block_rows=block_rows, block_v=block_v,
        interpret=interpret, return_residuals=True,
    )


# ---------------------------------------------------------------------------
# Backward: d_logits = (softmax − onehot(label)) · ct, vocab-streamed.
# The forward's residual contract threads its per-row logsumexp in, so the
# old online-lse re-streaming pass is gone: ONE pallas_call, one read of the
# logits + one write of d_logits.
# ---------------------------------------------------------------------------


def _xent_bwd_kernel(logits_ref, labels_ref, ct_ref, lse_ref, dl_ref, *, block_v: int):
    vi = pl.program_id(1)
    x = logits_ref[...].astype(jnp.float32)        # [block_rows, block_v]
    p = jnp.exp(x - lse_ref[...])                  # softmax given the lse
    cols = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = (cols == labels_ref[...]).astype(jnp.float32)
    dl_ref[...] = ((p - hit) * ct_ref[...]).astype(dl_ref.dtype)


def softmax_xent_bwd_pallas(
    ct: jax.Array,      # [rows] — per-row loss cotangent (fp32)
    logits: jax.Array,  # [rows, vocab]
    labels: jax.Array,  # [rows] int32
    lse: jax.Array,     # [rows] fp32 — the forward's saved logsumexp
    *,
    block_rows: int,
    block_v: int,
    interpret: bool = False,
) -> jax.Array:
    """One streamed pass over the logits given the residual-threaded lse —
    HBM traffic is one read + one write, never a [rows, vocab] fp32 softmax
    materialization and (post residual contract) never a second lse pass."""
    rows, vocab = logits.shape
    block_rows = min(block_rows, rows)
    block_v = min(block_v, vocab)
    pad_r = (-rows) % block_rows
    pad_v = (-vocab) % block_v
    if pad_r or pad_v:
        logits = jnp.pad(logits, ((0, pad_r), (0, pad_v)), constant_values=_NEG_INF)
        labels = jnp.pad(labels, (0, pad_r))
        ct = jnp.pad(ct, (0, pad_r))
        # Padded rows: lse = 0 with all-(-1e30) logits → p ≈ 0, ct = 0.
        lse = jnp.pad(lse, (0, pad_r))
    rp, vp = logits.shape
    v_steps = vp // block_v
    grid = (rp // block_rows, v_steps)
    labels2 = labels.astype(jnp.int32)[:, None]
    ct2 = ct.astype(jnp.float32)[:, None]
    lse2 = lse.astype(jnp.float32)[:, None]

    dl = pl.pallas_call(
        functools.partial(_xent_bwd_kernel, block_v=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_v), lambda ri, vi: (ri, vi)),
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((block_rows, 1), lambda ri, vi: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_v), lambda ri, vi: (ri, vi)),
        out_shape=jax.ShapeDtypeStruct((rp, vp), logits.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(logits, labels2, ct2, lse2)
    return dl[:rows, :vocab]


def _xent_bwd_heuristic(ct, logits, labels, lse):
    return _xent_heuristic(logits, labels)


def _xent_bwd_example():
    import numpy as np

    rs = np.random.RandomState(1)
    logits = jnp.asarray(rs.randn(16, 640) * 2, jnp.float32)
    # The lse residual must be consistent with the logits — the oracle
    # recomputes it while the kernel trusts the handed-in rows.
    lse = jax.nn.logsumexp(logits, axis=-1)
    return (
        jnp.asarray(rs.randn(16), jnp.float32),                 # ct
        logits,                                                 # logits
        jnp.asarray(rs.randint(0, 640, 16), jnp.int32),         # labels
        lse,                                                    # lse residual
    ), {}


@tunable(
    "softmax_xent_bwd",
    space=XENT_SPACE,
    reference=ref.softmax_xent_bwd,
    heuristic=_xent_bwd_heuristic,
    # ct, logits, labels, lse all lead with the token-row dim.
    # vjp="reference" (not "none"): the oracle is differentiable jnp, so
    # grad-of-grad can differentiate through this gradient site.
    dispatch=DispatchSpec(example=_xent_bwd_example,
                          data_parallel_args=(0, 1, 2, 3), vjp="reference"),
)
def softmax_xent_bwd(ct, logits, labels, lse, *, block_rows: int, block_v: int,
                     interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return softmax_xent_bwd_pallas(
        ct, logits, labels, lse, block_rows=block_rows, block_v=block_v,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Abstract grid models (static legality; see core/gridmodel.py). The
# backward realizes ONE pallas_call now that the forward's residual contract
# threads the lse in (the old online-lse re-streaming pass is gone); the
# forward carries the (m, l) scratch on its v axis ("arbitrary") and emits
# the lse residual alongside the loss. Both tune over the shared XENT_SPACE.
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _xent_blocks(config, rows, vocab):
    br = min(config["block_rows"], rows)
    bv = min(config["block_v"], vocab)
    return br, bv, rows + (-rows) % br, vocab + (-vocab) % bv


def _xent_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((2048, 65536), (2048,))
    rows, vocab = shapes[0]
    br, bv, rp, vp = _xent_blocks(config, rows, vocab)
    grid = (rp // br, vp // bv)
    tile = lambda ri, vi: (ri, vi)
    row = lambda ri, vi: (ri, 0)
    return GridModel(
        "softmax_xent", grid, ("parallel", "arbitrary"),
        (
            RefModel("logits", (br, bv), tile, (rp, vp)),
            RefModel("labels", (br, 1), row, (rp, 1), dtype="int32"),
            RefModel("loss", (br, 1), row, (rp, 1), role="out"),
            RefModel("lse", (br, 1), row, (rp, 1), role="out"),
        ),
    )


def _xent_bwd_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((2048,), (2048, 65536), (2048,), (2048,))
    rows, vocab = shapes[1]
    br, bv, rp, vp = _xent_blocks(config, rows, vocab)
    grid = (rp // br, vp // bv)
    tile = lambda ri, vi: (ri, vi)
    row = lambda ri, vi: (ri, 0)
    return GridModel(
        "softmax_xent_bwd", grid, ("parallel", "parallel"),
        (
            RefModel("logits", (br, bv), tile, (rp, vp)),
            RefModel("labels", (br, 1), row, (rp, 1), dtype="int32"),
            RefModel("ct", (br, 1), row, (rp, 1)),
            RefModel("lse", (br, 1), row, (rp, 1)),
            RefModel("dl", (br, bv), tile, (rp, vp), role="out"),
        ),
    )


register_grid_model("softmax_xent", _xent_grid_model, space=XENT_SPACE)
register_grid_model("softmax_xent_bwd", _xent_bwd_grid_model,
                    space=XENT_SPACE)
