"""Fused-epilogue tunables: gemm+bias+activation and rmsnorm+gemm.

The paper's loop-fusion pragma, applied to the two hottest producer→consumer
pairs in the model plane:

* ``matmul_bias_act`` — a blocked MXU gemm whose last k step adds the bias
  row and applies the activation in VMEM, so the [m, n] pre-activation
  never round-trips through HBM (dense-with-bias projections; the ffn
  up/gate matmuls with their gelu/silu epilogues).
* ``rmsnorm_matmul`` — normalizes each row block in VMEM and feeds it
  straight into the projection gemm, skipping the HBM-materialized
  normalized activation (final-norm → unembed).

Whether fusion *wins* is an empirical, platform-dependent question — the
epilogue lengthens the sequential k chain and the norm fusion re-normalizes
per n block — so model sites route through these kernels only where the
tuning database says so (``runtime.fusion_wins``): an exact tuned record is
the opt-in, everything else keeps the unfused dispatch path.

Backward plans decompose onto *other* kernels' dispatch sites (plain
``matmul`` / ``rmsnorm`` / ``rmsnorm_bwd`` records serve the gradients),
declared via ``DispatchSpec.bwd_via`` so the contracts pass can verify the
decomposition instead of expecting a ``*_bwd`` sibling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref
from .matmul import _pad_to


def _apply_act(h: jax.Array, act: str) -> jax.Array:
    if act == "gelu":
        return jax.nn.gelu(h)
    if act == "silu":
        return jax.nn.silu(h)
    if act == "none":
        return h
    raise ValueError(f"unknown fused activation {act!r}")


# ---------------------------------------------------------------------------
# matmul_bias_act: blocked gemm with a bias+activation epilogue
# ---------------------------------------------------------------------------


def _mba_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int, act: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        h = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(h, act).astype(o_ref.dtype)


def matmul_bias_act_pallas(
    x: jax.Array,  # [m, k]
    w: jax.Array,  # [k, n]
    b: jax.Array,  # [n]
    *,
    bm: int,
    bn: int,
    bk: int,
    act: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """act(x @ w + b) with explicit (bm, bn, bk) VMEM tiles; the epilogue
    runs on the fp32 accumulator at the last k step. Padding follows
    matmul_pallas (zero rows/cols are sliced back off before the caller
    sees them, so the epilogue on padded lanes is harmless)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    xp, wp = _pad_to(x, (bm, bk)), _pad_to(w, (bk, bn))
    bp = _pad_to(b.reshape(1, n), (1, bn))
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_mba_kernel, k_steps=k_steps, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def _mba_vmem_bytes(cfg, dtype_bytes: int = 2) -> int:
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    return (
        bm * bk * dtype_bytes + bk * bn * dtype_bytes
        + bn * dtype_bytes                     # bias row
        + bm * bn * (dtype_bytes + 4)          # out tile + fp32 acc
    )


FUSED_MATMUL_SPACE = ParamSpace(
    [
        PowerOfTwoParam("bm", 8, 1024),
        PowerOfTwoParam("bn", 128, 1024),
        PowerOfTwoParam("bk", 128, 2048),
    ],
    [
        Constraint(
            lambda c: _mba_vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "fused gemm tile working set exceeds VMEM budget",
        )
    ],
)


def _mba_heuristic(x, w, b):
    from .matmul import _matmul_heuristic

    return _matmul_heuristic(x, w)


def _mba_example():
    import numpy as np

    rs = np.random.RandomState(3)
    return (
        jnp.asarray(rs.randn(32, 64), jnp.float32),
        jnp.asarray(rs.randn(64, 16), jnp.float32),
        jnp.asarray(rs.randn(16) * 0.1, jnp.float32),
    ), {"act": "gelu"}


def _mba_canon(x, w, b):
    """Flatten leading (batch/seq) dims to rows, like matmul's canon."""
    if x.ndim == 2:
        return (x, w, b), lambda out: out
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    return (xr, w, b), lambda out: out.reshape(*lead, out.shape[-1])


def _mba_bwd(ct, x, w, b, act: str = "none", **kwargs):
    """Backward plan: decompose onto plain matmul dispatch sites (bwd_via).

    The epilogue cotangent g = act'(h)·ct needs the pre-activation h, which
    the fused forward deliberately never materialized — recompute it as one
    matmul dispatch (itself a tuned site), then dx/dw are the transposed-
    operand gemms and db the row reduction of g.
    """
    from ..core.runtime import dispatch

    if act == "none":
        g = ct
    else:
        h = dispatch("matmul", x, w) + b
        _, evjp = jax.vjp(lambda hh: _apply_act(hh.astype(jnp.float32), act), h)
        g = evjp(ct.astype(jnp.float32))[0].astype(ct.dtype)
    dx = dispatch("matmul", g, w.T, **kwargs)
    dw = dispatch("matmul", x.T, g, dp_dims={0: 1, 1: 0}, **kwargs)
    db = g.sum(axis=0).astype(b.dtype)
    return dx, dw, db


@tunable(
    "matmul_bias_act",
    space=FUSED_MATMUL_SPACE,
    reference=ref.matmul_bias_act,
    heuristic=_mba_heuristic,
    dispatch=DispatchSpec(
        # Same shapes, different epilogue => distinct db records.
        key_extra=lambda kw: f"a{kw.get('act', 'none')}",
        canonicalize=_mba_canon,
        example=_mba_example,
        vjp="dispatch",
        bwd=_mba_bwd,
        bwd_via=("matmul",),
    ),
)
def matmul_bias_act(
    x, w, b, *, bm: int, bn: int, bk: int,
    act: str = "none", interpret: Optional[bool] = None,
):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return matmul_bias_act_pallas(
        x, w, b, bm=bm, bn=bn, bk=bk, act=act, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# rmsnorm_matmul: per-row-block normalize in VMEM, feed the gemm directly
# ---------------------------------------------------------------------------


def _rmm_kernel(x_ref, s_ref, w_ref, o_ref, *, eps: float, d: int):
    # Mirrors ref.rmsnorm_matmul's cast placement exactly: normalize in
    # fp32, cast back to the activation dtype, scale, then fp32-accumulate.
    xf = x_ref[...].astype(jnp.float32)               # [bm, d]
    var = jnp.sum(xf * xf, axis=-1, keepdims=True) / d
    xn = (xf * jax.lax.rsqrt(var + eps)).astype(x_ref.dtype) * s_ref[...]
    o_ref[...] = jnp.dot(
        xn, w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def rmsnorm_matmul_pallas(
    x: jax.Array,      # [m, d]
    scale: jax.Array,  # [d]
    w: jax.Array,      # [d, n]
    *,
    bm: int,
    bn: int,
    eps: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:
    """rmsnorm(x, scale) @ w with the full d axis resident per tile: each
    (bm, d) row block is normalized once per n block and multiplied into a
    (d, bn) weight tile. The norm is recomputed per n block — the tuner
    decides whether that trade beats the unfused HBM round-trip. Row
    padding is sliced back off; the mean uses the *true* d (padded rows are
    all-zero, so their garbage outputs are dropped by the slice)."""
    m, d = x.shape
    d2, n = w.shape
    assert d == d2 and scale.shape == (d,), (x.shape, scale.shape, w.shape)
    bm, bn = min(bm, m), min(bn, n)
    xp = _pad_to(x, (bm, 1))
    wp = _pad_to(w, (1, bn))
    mp, np_ = xp.shape[0], wp.shape[1]
    grid = (mp // bm, np_ // bn)

    out = pl.pallas_call(
        functools.partial(_rmm_kernel, eps=eps, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(xp, scale.reshape(1, d), wp)
    return out[:m, :n]


def _rmm_vmem_bytes(cfg, d: int = 4096, dtype_bytes: int = 2) -> int:
    bm, bn = cfg["bm"], cfg["bn"]
    return (
        bm * d * (dtype_bytes + 4)    # x tile + fp32 normalized copy
        + d * dtype_bytes             # scale row
        + d * bn * dtype_bytes        # w tile
        + bm * bn * (dtype_bytes + 4)  # out tile + fp32 product
    )


RMSNORM_MATMUL_SPACE = ParamSpace(
    [
        PowerOfTwoParam("bm", 8, 512),
        PowerOfTwoParam("bn", 128, 1024),
    ],
    [
        Constraint(
            lambda c: _rmm_vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "fused norm+gemm tile working set exceeds VMEM budget",
        )
    ],
)


def _rmm_heuristic(x, scale, w):
    m = 1
    for s in x.shape[:-1]:
        m *= int(s)
    n = w.shape[1]
    pick = lambda dim, cap: min(cap, max(8, 1 << (int(dim) - 1).bit_length()))
    return {"bm": min(pick(m, 128), 512), "bn": max(128, min(pick(n, 512), 1024))}


def _rmm_example():
    import numpy as np

    rs = np.random.RandomState(4)
    return (
        jnp.asarray(rs.randn(32, 64) * 0.5, jnp.float32),
        jnp.asarray(1.0 + rs.randn(64) * 0.1, jnp.float32),
        jnp.asarray(rs.randn(64, 16), jnp.float32),
    ), {}


def _rmm_canon(x, scale, w):
    """Flatten leading (batch/seq) dims to rows: [..., d] -> [rows, d]."""
    if x.ndim == 2:
        return (x, scale, w), lambda out: out
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    return (xr, scale, w), lambda out: out.reshape(*lead, out.shape[-1])


def _rmm_bwd(ct, x, scale, w, eps: float = 1e-6, **kwargs):
    """Backward plan: decompose onto rmsnorm / matmul / rmsnorm_bwd sites.

    xn = rmsnorm(x, scale) is recomputed through its own dispatch site; the
    projection gradients are transposed-operand matmuls; the norm gradients
    route through the residual-threaded rmsnorm_bwd with inv-rms rebuilt
    from x (one cheap row reduction, not a kernel).
    """
    from ..core.runtime import dispatch

    xn = dispatch("rmsnorm", x, scale, eps=eps)
    d_xn = dispatch("matmul", ct, w.T, **kwargs)
    dw = dispatch("matmul", xn.T, ct, dp_dims={0: 1, 1: 0}, **kwargs)
    xf = x.astype(jnp.float32)
    invrms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1) + eps)
    dx, dscale = dispatch("rmsnorm_bwd", d_xn, x, scale, invrms, **kwargs)
    return dx, dscale, dw


@tunable(
    "rmsnorm_matmul",
    space=RMSNORM_MATMUL_SPACE,
    reference=ref.rmsnorm_matmul,
    heuristic=_rmm_heuristic,
    dispatch=DispatchSpec(
        canonicalize=_rmm_canon,
        example=_rmm_example,
        vjp="dispatch",
        bwd=_rmm_bwd,
        bwd_via=("rmsnorm", "matmul", "rmsnorm_bwd"),
    ),
)
def rmsnorm_matmul(
    x, scale, w, *, bm: int, bn: int,
    eps: float = 1e-6, interpret: Optional[bool] = None,
):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return rmsnorm_matmul_pallas(
        x, scale, w, bm=bm, bn=bn, eps=eps, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Abstract grid models (static legality; see core/gridmodel.py)
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _mba_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((4096, 4096), (4096, 4096), (4096,))
    (m, k), n = shapes[0], shapes[1][1]
    bm = min(config["bm"], m)
    bn = min(config["bn"], n)
    bk = min(config["bk"], k)
    mp, kp, np_ = m + (-m) % bm, k + (-k) % bk, n + (-n) % bn
    grid = (mp // bm, np_ // bn, kp // bk)
    return GridModel(
        "matmul_bias_act", grid, ("parallel", "parallel", "arbitrary"),
        (
            RefModel("x", (bm, bk), lambda i, j, kk: (i, kk), (mp, kp)),
            RefModel("w", (bk, bn), lambda i, j, kk: (kk, j), (kp, np_)),
            RefModel("b", (1, bn), lambda i, j, kk: (0, j), (1, np_)),
            RefModel("out", (bm, bn), lambda i, j, kk: (i, j), (mp, np_),
                     role="out"),
        ),
    )


def _rmm_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((8192, 4096), (4096,), (4096, 4096))
    (m, d), n = shapes[0], shapes[2][1]
    bm = min(config["bm"], m)
    bn = min(config["bn"], n)
    mp, np_ = m + (-m) % bm, n + (-n) % bn
    grid = (mp // bm, np_ // bn)
    return GridModel(
        "rmsnorm_matmul", grid, ("parallel", "parallel"),
        (
            RefModel("x", (bm, d), lambda i, j: (i, 0), (mp, d)),
            RefModel("scale", (1, d), lambda i, j: (0, 0), (1, d)),
            RefModel("w", (d, bn), lambda i, j: (0, j), (d, np_)),
            RefModel("out", (bm, bn), lambda i, j: (i, j), (mp, np_),
                     role="out"),
        ),
    )


register_grid_model("matmul_bias_act", _mba_grid_model,
                    space=FUSED_MATMUL_SPACE)
register_grid_model("rmsnorm_matmul", _rmm_grid_model,
                    space=RMSNORM_MATMUL_SPACE)
