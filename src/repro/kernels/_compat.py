"""Version shims for the Pallas TPU API.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` upstream;
this container may carry either vintage of jax, so resolve whichever name
exists once and let every kernel import the result.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - very old jax
    raise ImportError("pallas tpu has neither CompilerParams nor TPUCompilerParams")
