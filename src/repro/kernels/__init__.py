"""Pallas TPU kernels for the hot spots, each with a pure-jnp oracle.

Modules:
  matmul.py    — blocked MXU matmul, tunable (bm, bn, bk)
  attention.py — flash attention (causal/SWA/GQA), tunable (block_q, block_k)
  rmsnorm.py   — fused RMSNorm, tunable block_rows
  xent.py      — fused large-vocab cross entropy, tunable (block_rows, block_v)
  ops.py       — DEPRECATED shims over the dispatch runtime (repro.core.runtime)
  ref.py       — reference oracles (correctness gate + dry-run lowering path)
"""
from . import ops, ref
from .attention import ATTENTION_SPACE, flash_attention, flash_attention_pallas
from .matmul import MATMUL_SPACE, matmul, matmul_pallas
from .rmsnorm import RMSNORM_SPACE, rmsnorm, rmsnorm_pallas
from .xent import XENT_SPACE, softmax_xent, softmax_xent_pallas
