"""Pallas TPU kernels for the hot spots, each with a pure-jnp oracle.

Modules:
  matmul.py    — blocked MXU matmul, tunable (bm, bn, bk); backward =
                 transposed-operand matmul dispatches
  attention.py — flash attention (causal/SWA/GQA), tunable (block_q, block_k)
                 + flash_attention_bwd (recompute-(o,lse), blocked dq/dkv)
  rmsnorm.py   — fused RMSNorm, tunable block_rows + fused rmsnorm_bwd
  xent.py      — fused large-vocab cross entropy, tunable (block_rows,
                 block_v) + vocab-streamed softmax_xent_bwd
  ops.py       — migration guide from the removed global-mode API
  ref.py       — reference oracles, forward AND backward (correctness gate +
                 dry-run lowering path + Reference-tier gradient fallback)
"""
from . import ops, ref
from .attention import (
    ATTENTION_SPACE,
    flash_attention,
    flash_attention_bwd,
    flash_attention_bwd_pallas,
    flash_attention_pallas,
)
from .matmul import MATMUL_SPACE, matmul, matmul_pallas
from .rmsnorm import RMSNORM_SPACE, rmsnorm, rmsnorm_bwd, rmsnorm_bwd_pallas, rmsnorm_pallas
from .xent import XENT_SPACE, softmax_xent, softmax_xent_bwd, softmax_xent_bwd_pallas, softmax_xent_pallas
