"""Pallas TPU kernels for the hot spots, each with a pure-jnp oracle.

Modules:
  matmul.py    — blocked MXU matmul, tunable (bm, bn, bk); backward =
                 transposed-operand matmul dispatches
  attention.py — flash attention (causal/SWA/GQA), tunable (block_q, block_k)
                 + flash_attention_bwd (residual-threaded (o,lse), blocked
                 dq/dkv — two passes, no recompute)
  rmsnorm.py   — fused RMSNorm, tunable block_rows + fused rmsnorm_bwd
  xent.py      — fused large-vocab cross entropy, tunable (block_rows,
                 block_v) + vocab-streamed softmax_xent_bwd
  fused.py     — fused-epilogue tunables: matmul_bias_act (gemm+bias+
                 gelu/silu) and rmsnorm_matmul (norm+gemm); gradients
                 decompose onto matmul/rmsnorm records (bwd_via)
  ssm_scan.py  — Mamba selective scan: Pallas chunked scan (chunk, block_d)
                 + fused single-step decode update, each with a chunk/block-
                 windowed bwd tunable
  moe_gemm.py  — grouped expert GEMM [e,c,k]@[e,k,n], tunable (bc, bn, bk);
                 backward = transposed-operand expert_gemm dispatches
  ops.py       — migration guide from the removed global-mode API
  ref.py       — reference oracles, forward AND backward (correctness gate +
                 dry-run lowering path + Reference-tier gradient fallback)
"""
from . import ops, ref
from .attention import (
    ATTENTION_SPACE,
    flash_attention,
    flash_attention_bwd,
    flash_attention_bwd_pallas,
    flash_attention_pallas,
)
from .fused import (
    FUSED_MATMUL_SPACE,
    RMSNORM_MATMUL_SPACE,
    matmul_bias_act,
    matmul_bias_act_pallas,
    rmsnorm_matmul,
    rmsnorm_matmul_pallas,
)
from .matmul import MATMUL_SPACE, matmul, matmul_pallas
from .moe_gemm import EXPERT_GEMM_SPACE, expert_gemm, expert_gemm_pallas
from .rmsnorm import RMSNORM_SPACE, rmsnorm, rmsnorm_bwd, rmsnorm_bwd_pallas, rmsnorm_pallas
from .ssm_scan import (
    SSM_SCAN_SPACE,
    SSM_UPDATE_SPACE,
    ssm_scan,
    ssm_scan_bwd,
    ssm_scan_chunked,
    ssm_scan_pallas,
    ssm_update,
    ssm_update_bwd,
    ssm_update_pallas,
)
from .xent import XENT_SPACE, softmax_xent, softmax_xent_bwd, softmax_xent_bwd_pallas, softmax_xent_pallas
