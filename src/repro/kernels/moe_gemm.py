"""Grouped expert GEMM — the MoE dispatch site, keyed on (experts ×
capacity × hidden).

One [e, c, k] @ [e, k, n] batched contraction per expert-FFN matmul: the
expert dim is an outer grid axis (each expert's tile stream is independent),
c/n tile through VMEM like the dense matmul, and the k grid dim carries the
fp32 accumulator. Replaces the three ``ecd,edf`` einsums in
``moe._expert_ffn`` so Mixtral-style configs resolve through the tuned
runtime instead of XLA defaults.

The backward plan reuses this same tunable with transposed operands
(dL/dx = ct @ wᵀ, dL/dw = xᵀ @ ct per expert), so campaign records for the
transposed buckets serve the gradients — the matmul pattern, grouped.

Capacity derives from the *global* token count (``b·s`` of the traced,
unsharded shape) and the expert dim is a weight axis, so no argument is
batch-sharded: ``data_parallel_args=()``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref


def _expert_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_gemm_pallas(
    x: jax.Array,   # [e, c, k]
    w: jax.Array,   # [e, k, n]
    *,
    bc: int,
    bn: int,
    bk: int,
    interpret: bool = False,
) -> jax.Array:
    e, c, k = x.shape
    e2, k2, n = w.shape
    assert e == e2 and k == k2, (x.shape, w.shape)
    bc, bn, bk = min(bc, c), min(bn, n), min(bk, k)
    pad3 = lambda t, mc, mk: jnp.pad(
        t, ((0, 0), (0, (-t.shape[1]) % mc), (0, (-t.shape[2]) % mk))
    )
    xp, wp = pad3(x, bc, bk), pad3(w, bk, bn)
    cp, kp = xp.shape[1], xp.shape[2]
    np_ = wp.shape[2]
    k_steps = kp // bk
    grid = (e, cp // bc, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_expert_gemm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda ie, i, j, kk: (ie, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda ie, i, j, kk: (ie, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda ie, i, j, kk: (ie, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp)
    return out[:, :c, :n]


def _vmem_bytes(cfg, dtype_bytes: int = 2) -> int:
    bc, bn, bk = cfg["bc"], cfg["bn"], cfg["bk"]
    return bc * bk * dtype_bytes + bk * bn * dtype_bytes + bc * bn * (dtype_bytes + 4)


EXPERT_GEMM_SPACE = ParamSpace(
    [
        PowerOfTwoParam("bc", 8, 1024),
        PowerOfTwoParam("bn", 128, 1024),
        PowerOfTwoParam("bk", 128, 2048),
    ],
    [
        Constraint(
            lambda c: _vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "tile working set exceeds VMEM budget",
        )
    ],
)


def _expert_gemm_heuristic(x, w):
    e, c, k = x.shape
    n = w.shape[2]
    pick = lambda d, cap: min(cap, max(8, 1 << (int(d) - 1).bit_length()))
    return {
        "bc": min(pick(c, 256), 1024),
        "bn": max(128, min(pick(n, 256), 1024)),
        "bk": max(128, min(pick(k, 512), 2048)),
    }


def _expert_gemm_example():
    import numpy as np

    rs = np.random.RandomState(0)
    return (
        jnp.asarray(rs.randn(2, 12, 16), jnp.float32),
        jnp.asarray(rs.randn(2, 16, 8), jnp.float32),
    ), {}


def _expert_gemm_bwd(ct, x, w, **kwargs):
    """Backward plan: both grads are grouped-gemm dispatch sites themselves."""
    from ..core.runtime import dispatch

    dx = dispatch("expert_gemm", ct, jnp.swapaxes(w, 1, 2), **kwargs)
    dw = dispatch("expert_gemm", jnp.swapaxes(x, 1, 2), ct, **kwargs)
    return dx, dw


@tunable(
    "expert_gemm",
    space=EXPERT_GEMM_SPACE,
    reference=ref.expert_gemm,
    heuristic=_expert_gemm_heuristic,
    dispatch=DispatchSpec(example=_expert_gemm_example,
                          data_parallel_args=(),
                          vjp="dispatch", bwd=_expert_gemm_bwd),
)
def expert_gemm(x, w, *, bc: int, bn: int, bk: int,
                interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return expert_gemm_pallas(x, w, bc=bc, bn=bn, bk=bk, interpret=interpret)


# ---------------------------------------------------------------------------
# Abstract grid model (static legality; see core/gridmodel.py). Experts ride
# the outer grid axis; the k axis carries the accumulator scratch and is
# declared "arbitrary" — that is what makes the out ref (invariant along kk)
# race-free. Backward expert_gemm dispatches reuse this model with
# transposed operands, so one registration covers fwd and bwd keys.
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _expert_gemm_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((4, 4096, 4096), (4, 4096, 2048))
    e, c, k = shapes[0]
    n = shapes[1][2]
    bc = min(config["bc"], c)
    bn = min(config["bn"], n)
    bk = min(config["bk"], k)
    cp, kp, np_ = c + (-c) % bc, k + (-k) % bk, n + (-n) % bn
    grid = (e, cp // bc, np_ // bn, kp // bk)
    xmap = lambda ie, i, j, kk: (ie, i, kk)
    wmap = lambda ie, i, j, kk: (ie, kk, j)
    omap = lambda ie, i, j, kk: (ie, i, j)
    return GridModel(
        "expert_gemm", grid,
        ("parallel", "parallel", "parallel", "arbitrary"),
        (
            RefModel("x", (1, bc, bk), xmap, (e, cp, kp)),
            RefModel("w", (1, bk, bn), wmap, (e, kp, np_)),
            RefModel("out", (1, bc, bn), omap, (e, cp, np_), role="out"),
        ),
    )


register_grid_model("expert_gemm", _expert_gemm_grid_model,
                    space=EXPERT_GEMM_SPACE)
