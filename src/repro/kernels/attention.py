"""Flash attention (causal / sliding-window, GQA) — Pallas kernel.

TPU adaptation of the memory-hierarchy insight: never materialize the
[s_q, s_k] score matrix in HBM; stream K/V blocks through VMEM with an
online-softmax accumulator. Tunables are the (block_q, block_k) VMEM tiles —
the direct analogue of the paper's per-platform tile/pragma knobs (the best
blocks depend on seq_len and head_dim exactly as Figure 1's best variant
depends on input size).

Grid: (batch·heads, s_q/block_q, s_k/block_k); k-dim sequential (carries the
running max / denominator / output accumulator in VMEM scratch). Causal and
sliding-window masking prune fully-masked K/V blocks via `pl.when`, so SWA
cost scales with window, not seq_len.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref

_NEG_INF = -1e30  # avoid nan from (-inf) - (-inf) in fully-masked rows


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    k_steps: int,
    q_offset: int,
):
    """Forward flash kernel; emits per-row logsumexp alongside the output —
    the residual contract hands it to the backward plan, which no longer
    re-runs this schedule to recover it."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level pruning: with causality, K blocks strictly in the future of
    # the whole Q block contribute nothing; with SWA, K blocks entirely
    # before the window do not either.
    q_hi = (qi + 1) * block_q - 1 + q_offset    # last absolute q position
    q_lo = qi * block_q + q_offset              # first absolute q position
    k_lo = ki * block_k
    k_hi = (ki + 1) * block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # [block_q, d]
        k = k_ref[0].astype(jnp.float32)          # [block_k, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # [block_q, block_k]

        if causal or window > 0:
            q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jnp.bool_(True)
            if causal:
                mask &= q_ids >= k_ids
            if window > 0:
                mask &= (q_ids - k_ids) < window
            s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                        # [block_q, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # [block_q, block_k]
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(denom))[:, 0]


def flash_attention_pallas(
    q: jax.Array,  # [b, h, s_q, d]
    k: jax.Array,  # [b, kv, s_k, d]
    v: jax.Array,  # [b, kv, s_k, d]
    *,
    block_q: int,
    block_k: int,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    interpret: bool = False,
    return_residuals: bool = False,
):
    b, h, s_q, d = q.shape
    _, kv, s_k, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)
    assert s_q % block_q == 0 and s_k % block_k == 0, (s_q, s_k, block_q, block_k)
    k_steps = s_k // block_k
    grid = (b * h, s_q // block_q, k_steps)
    # Decode/suffix alignment: q positions occupy the *end* of the k axis.
    q_offset = s_k - s_q

    qr = q.reshape(b * h, s_q, d)
    # GQA: map flattened (b*h) program index to its kv head.
    def kv_index(bh, qi, ki):
        bb = bh // h
        hh = bh % h
        return (bb * kv + hh // group, ki, 0)

    kr = k.reshape(b * kv, s_k, d)
    vr = v.reshape(b * kv, s_k, d)

    out, lse = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            k_steps=k_steps,
            q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s_q, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, s_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    if return_residuals:
        return out.reshape(b, h, s_q, d), lse.reshape(b, h, s_q)
    return out.reshape(b, h, s_q, d)


def _attn_vmem_bytes(cfg, d: int = 128, dtype_bytes: int = 2) -> int:
    bq, bk = cfg["block_q"], cfg["block_k"]
    return (
        bq * d * dtype_bytes            # q tile
        + 2 * bk * d * dtype_bytes      # k, v tiles
        + bq * bk * 4                   # scores
        + bq * (d + 2) * 4              # acc + m + l scratch
    )


ATTENTION_SPACE = ParamSpace(
    [
        PowerOfTwoParam("block_q", 128, 2048),
        PowerOfTwoParam("block_k", 128, 2048),
    ],
    [
        Constraint(
            lambda c: _attn_vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "attention tile working set exceeds VMEM budget",
        )
    ],
)


def _attn_heuristic(q, k, v):
    s_q, s_k = q.shape[2], k.shape[2]
    blk = lambda s: min(512, max(128, 1 << (int(s) - 1).bit_length() if s < 128 else 128))
    return {"block_q": min(512, max(128, min(s_q, 512))) if s_q >= 128 else 128,
            "block_k": 512 if s_k >= 512 else 128}


def _attn_example():
    import numpy as np

    rs = np.random.RandomState(0)
    mk = lambda *s: jnp.asarray(rs.randn(*s) * 0.3, jnp.float32)
    return (mk(1, 4, 128, 16), mk(1, 2, 128, 16), mk(1, 2, 128, 16)), {"causal": True}


def _flash_bwd_plan(ct, q, k, v, o, lse, **kwargs):
    """Backward plan for the fwd tunable: one fused bwd dispatch site
    (dq/dk/dv together). The residual contract hands it the forward output
    and per-query logsumexp, so no recompute pass is needed."""
    from ..core.runtime import dispatch

    return dispatch("flash_attention_bwd", ct, q, k, v, o, lse, **kwargs)


@tunable(
    "flash_attention",
    space=ATTENTION_SPACE,
    # Tuning reference emits the same (out, lse) structure as the variant.
    reference=functools.partial(ref.attention_res, causal=True),
    heuristic=_attn_heuristic,
    dispatch=DispatchSpec(
        # Deployment reference is primal-only (same call kwargs).
        reference=ref.attention,
        # Same shapes, different masking semantics => distinct db records.
        key_extra=lambda kw: f"c{kw.get('causal', True)}w{kw.get('window', 0)}",
        example=_attn_example,
        # q, k, v all lead with the (data-parallel) batch dim.
        data_parallel_args=(0, 1, 2),
        vjp="dispatch",
        bwd=_flash_bwd_plan,
        residuals=1,  # per-query logsumexp, threaded to the bwd plan
    ),
)
def flash_attention(
    q, k, v, *, block_q: int, block_k: int,
    causal: bool = True, window: int = 0,
    scale: Optional[float] = None, interpret: Optional[bool] = None,
):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return flash_attention_pallas(
        q, k, v, block_q=block_q, block_k=block_k,
        causal=causal, window=window, scale=scale, interpret=interpret,
        return_residuals=True,
    )


# ---------------------------------------------------------------------------
# Flash attention backward: residual-threaded (o, lse) from the forward,
# then blocked dq and dk/dv — two Pallas passes, no recompute pass.
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
    dq_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    k_steps: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_hi = (qi + 1) * block_q - 1 + q_offset
    q_lo = qi * block_q + q_offset
    k_lo = ki * block_k
    k_hi = (ki + 1) * block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)         # [bq, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if causal or window > 0:
            q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jnp.bool_(True)
            if causal:
                mask &= q_ids >= k_ids
            if window > 0:
                mask &= (q_ids - k_ids) < window
            s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])       # exact softmax via lse
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                           # [bq, bk]
        ds = p * (dp - delta_ref[0][:, None])
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale

    @pl.when(ki == k_steps - 1)
    def _done():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    q_steps: int,
    q_offset: int,
):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_hi = (qi + 1) * block_q - 1 + q_offset
    q_lo = qi * block_q + q_offset
    k_lo = ki * block_k
    k_hi = (ki + 1) * block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)         # [bq, d]
        st = jax.lax.dot_general(
            k, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # [bk, bq]
        if causal or window > 0:
            k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, st.shape, 0)
            q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, st.shape, 1)
            mask = jnp.bool_(True)
            if causal:
                mask &= q_ids >= k_ids
            if window > 0:
                mask &= (q_ids - k_ids) < window
            st = jnp.where(mask, st, _NEG_INF)
        pt = jnp.exp(st - lse_ref[0][None, :])     # [bk, bq]
        dv_scr[...] += jax.lax.dot_general(
            pt, do, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dpt = jax.lax.dot_general(
            v, do, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                           # [bk, bq]
        dst = pt * (dpt - delta_ref[0][None, :])
        dk_scr[...] += jax.lax.dot_general(
            dst, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale

    @pl.when(qi == q_steps - 1)
    def _done():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(
    ct: jax.Array,   # [b, h, s_q, d] — cotangent of the attention output
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,    # [b, h, s_q, d] — residual: the forward output
    lse: jax.Array,  # [b, h, s_q]    — residual: per-query logsumexp
    *,
    block_q: int,
    block_k: int,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    interpret: bool = False,
):
    """(dq, dk, dv) via the residual-threaded flash backward: (o, lse) come
    from the forward pass (no recompute), delta = rowsum(do·o) is one cheap
    elementwise reduction, then one k-streaming pass for dq and one
    q-streaming pass for dk/dv — exactly two Pallas calls. Nothing
    [s_q, s_k]-sized ever touches HBM. GQA: dk/dv are computed per q-head
    and group-summed into the kv heads afterwards.
    """
    b, h, s_q, d = q.shape
    _, kv, s_k, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)
    assert s_q % block_q == 0 and s_k % block_k == 0, (s_q, s_k, block_q, block_k)
    k_steps = s_k // block_k
    q_steps = s_q // block_q
    q_offset = s_k - s_q

    qr = q.reshape(b * h, s_q, d)
    dor = ct.reshape(b * h, s_q, d)
    kr = k.reshape(b * kv, s_k, d)
    vr = v.reshape(b * kv, s_k, d)

    def kv_index_q(bh, qi, ki):
        bb = bh // h
        hh = bh % h
        return (bb * kv + hh // group, ki, 0)

    def kv_index_k(bh, ki, qi):
        bb = bh // h
        hh = bh % h
        return (bb * kv + hh // group, ki, 0)

    common = dict(
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
    )

    # delta = rowsum(do·o) from the residual-threaded forward output
    o_r = o.reshape(b * h, s_q, d)
    lse_r = lse.astype(jnp.float32).reshape(b * h, s_q)
    delta = jnp.sum(dor.astype(jnp.float32) * o_r.astype(jnp.float32), axis=-1)

    # 1. dq: stream K/V blocks per Q block (k grid dim carries the acc)
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, k_steps=k_steps, **common),
        grid=(b * h, q_steps, k_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index_q),
            pl.BlockSpec((1, block_k, d), kv_index_q),
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_q, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr, dor, lse_r, delta)

    # 2. dk/dv: stream Q blocks per K block (q grid dim carries the accs),
    # per q-head; group-sum into kv heads below.
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, q_steps=q_steps, **common),
        grid=(b * h, k_steps, q_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index_k),
            pl.BlockSpec((1, block_k, d), kv_index_k),
            pl.BlockSpec((1, block_q, d), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi)),
            pl.BlockSpec((1, block_q), lambda bh, ki, qi: (bh, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki, qi: (bh, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s_k, d), jnp.float32),
            jax.ShapeDtypeStruct((b * h, s_k, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr, dor, lse_r, delta)
    dk = dk_h.reshape(b, kv, group, s_k, d).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(b, kv, group, s_k, d).sum(axis=2).astype(v.dtype)
    return dq.reshape(b, h, s_q, d), dk, dv


def _attn_bwd_heuristic(ct, q, k, v, o, lse):
    return _attn_heuristic(q, k, v)


def _attn_bwd_example():
    import numpy as np

    rs = np.random.RandomState(1)
    mk = lambda *s: jnp.asarray(rs.randn(*s) * 0.3, jnp.float32)
    q, k, v = mk(1, 4, 128, 16), mk(1, 2, 128, 16), mk(1, 2, 128, 16)
    o, lse = ref.attention_res(q, k, v, causal=True)
    return (mk(1, 4, 128, 16), q, k, v, o, lse), {"causal": True}


@tunable(
    "flash_attention_bwd",
    space=ATTENTION_SPACE,
    reference=ref.attention_bwd,
    heuristic=_attn_bwd_heuristic,
    dispatch=DispatchSpec(
        key_extra=lambda kw: f"c{kw.get('causal', True)}w{kw.get('window', 0)}",
        example=_attn_bwd_example,
        # ct, q, k, v, o, lse all lead with the batch dim.
        data_parallel_args=(0, 1, 2, 3, 4, 5),
        # Reference VJP so grad-of-grad differentiates through this site.
        vjp="reference",
    ),
)
def flash_attention_bwd(
    ct, q, k, v, o, lse, *, block_q: int, block_k: int,
    causal: bool = True, window: int = 0,
    scale: Optional[float] = None, interpret: Optional[bool] = None,
):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return flash_attention_bwd_pallas(
        ct, q, k, v, o, lse, block_q=block_q, block_k=block_k,
        causal=causal, window=window, scale=scale, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Abstract grid models (static legality; see core/gridmodel.py). The
# forward asserts block divisibility instead of padding, so the builders
# return None (= kernel rejects the shapes) when s_q/s_k don't divide. The
# backward realizes TWO pallas_calls — dq and dk/dv; (o, lse) arrive as
# residuals from the forward — one model each; both tunables share
# ATTENTION_SPACE, so a config must be legal under all three models.
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _flash_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((2, 4, 4096, 128), (2, 2, 4096, 128), (2, 2, 4096, 128))
    b, h, s_q, d = shapes[0]
    kv, s_k = shapes[1][1], shapes[1][2]
    if h % kv:
        return None
    group = h // kv
    bq = min(config["block_q"], s_q)
    bk = min(config["block_k"], s_k)
    if s_q % bq or s_k % bk:
        return None
    grid = (b * h, s_q // bq, s_k // bk)
    qmap = lambda bh, qi, ki: (bh, qi, 0)
    lmap = lambda bh, qi, ki: (bh, qi)
    kvmap = lambda bh, qi, ki: ((bh // h) * kv + (bh % h) // group, ki, 0)
    return GridModel(
        "flash_attention", grid, ("parallel", "parallel", "arbitrary"),
        (
            RefModel("q", (1, bq, d), qmap, (b * h, s_q, d)),
            RefModel("k", (1, bk, d), kvmap, (b * kv, s_k, d)),
            RefModel("v", (1, bk, d), kvmap, (b * kv, s_k, d)),
            RefModel("out", (1, bq, d), qmap, (b * h, s_q, d), role="out"),
            RefModel("lse", (1, bq), lmap, (b * h, s_q), role="out"),
        ),
    )


def _flash_bwd_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((2, 4, 4096, 128), (2, 4, 4096, 128),
                  (2, 2, 4096, 128), (2, 2, 4096, 128),
                  (2, 4, 4096, 128), (2, 4, 4096))
    b, h, s_q, d = shapes[1]
    kv, s_k = shapes[2][1], shapes[2][2]
    if h % kv:
        return None
    group = h // kv
    bq = min(config["block_q"], s_q)
    bk = min(config["block_k"], s_k)
    if s_q % bq or s_k % bk:
        return None
    q_steps, k_steps = s_q // bq, s_k // bk
    qmap = lambda bh, qi, ki: (bh, qi, 0)
    lmap = lambda bh, qi, ki: (bh, qi)
    kvmap = lambda bh, qi, ki: ((bh // h) * kv + (bh % h) // group, ki, 0)
    q_dims, kv_dims = (b * h, s_q, d), (b * kv, s_k, d)
    dq_pass = GridModel(
        "flash_attention_bwd", (b * h, q_steps, k_steps),
        ("parallel", "parallel", "arbitrary"),
        (
            RefModel("q", (1, bq, d), qmap, q_dims),
            RefModel("k", (1, bk, d), kvmap, kv_dims),
            RefModel("v", (1, bk, d), kvmap, kv_dims),
            RefModel("do", (1, bq, d), qmap, q_dims),
            RefModel("lse", (1, bq), lmap, (b * h, s_q)),
            RefModel("delta", (1, bq), lmap, (b * h, s_q)),
            RefModel("dq", (1, bq, d), qmap, q_dims, role="out"),
        ),
    )
    # dk/dv stream Q per K block: grid axes are (bh, ki, qi).
    qmap_k = lambda bh, ki, qi: (bh, qi, 0)
    lmap_k = lambda bh, ki, qi: (bh, qi)
    kvmap_k = lambda bh, ki, qi: ((bh // h) * kv + (bh % h) // group, ki, 0)
    dkv_map = lambda bh, ki, qi: (bh, ki, 0)
    dkv_pass = GridModel(
        "flash_attention_bwd", (b * h, k_steps, q_steps),
        ("parallel", "parallel", "arbitrary"),
        (
            RefModel("q", (1, bq, d), qmap_k, q_dims),
            RefModel("k", (1, bk, d), kvmap_k, kv_dims),
            RefModel("v", (1, bk, d), kvmap_k, kv_dims),
            RefModel("do", (1, bq, d), qmap_k, q_dims),
            RefModel("lse", (1, bq), lmap_k, (b * h, s_q)),
            RefModel("delta", (1, bq), lmap_k, (b * h, s_q)),
            RefModel("dk", (1, bk, d), dkv_map, (b * h, s_k, d), role="out"),
            RefModel("dv", (1, bk, d), dkv_map, (b * h, s_k, d), role="out"),
        ),
    )
    return (dq_pass, dkv_pass)


register_grid_model("flash_attention", _flash_grid_model,
                    space=ATTENTION_SPACE)
register_grid_model("flash_attention_bwd", _flash_bwd_grid_model,
                    space=ATTENTION_SPACE)
