"""Flash attention (causal / sliding-window, GQA) — Pallas kernel.

TPU adaptation of the memory-hierarchy insight: never materialize the
[s_q, s_k] score matrix in HBM; stream K/V blocks through VMEM with an
online-softmax accumulator. Tunables are the (block_q, block_k) VMEM tiles —
the direct analogue of the paper's per-platform tile/pragma knobs (the best
blocks depend on seq_len and head_dim exactly as Figure 1's best variant
depends on input size).

Grid: (batch·heads, s_q/block_q, s_k/block_k); k-dim sequential (carries the
running max / denominator / output accumulator in VMEM scratch). Causal and
sliding-window masking prune fully-masked K/V blocks via `pl.when`, so SWA
cost scales with window, not seq_len.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref

_NEG_INF = -1e30  # avoid nan from (-inf) - (-inf) in fully-masked rows


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    k_steps: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level pruning: with causality, K blocks strictly in the future of
    # the whole Q block contribute nothing; with SWA, K blocks entirely
    # before the window do not either.
    q_hi = (qi + 1) * block_q - 1 + q_offset    # last absolute q position
    q_lo = qi * block_q + q_offset              # first absolute q position
    k_lo = ki * block_k
    k_hi = (ki + 1) * block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # [block_q, d]
        k = k_ref[0].astype(jnp.float32)          # [block_k, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # [block_q, block_k]

        if causal or window > 0:
            q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jnp.bool_(True)
            if causal:
                mask &= q_ids >= k_ids
            if window > 0:
                mask &= (q_ids - k_ids) < window
            s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                        # [block_q, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # [block_q, block_k]
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [b, h, s_q, d]
    k: jax.Array,  # [b, kv, s_k, d]
    v: jax.Array,  # [b, kv, s_k, d]
    *,
    block_q: int,
    block_k: int,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, s_q, d = q.shape
    _, kv, s_k, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)
    assert s_q % block_q == 0 and s_k % block_k == 0, (s_q, s_k, block_q, block_k)
    k_steps = s_k // block_k
    grid = (b * h, s_q // block_q, k_steps)
    # Decode/suffix alignment: q positions occupy the *end* of the k axis.
    q_offset = s_k - s_q

    qr = q.reshape(b * h, s_q, d)
    # GQA: map flattened (b*h) program index to its kv head.
    def kv_index(bh, qi, ki):
        bb = bh // h
        hh = bh % h
        return (bb * kv + hh // group, ki, 0)

    kr = k.reshape(b * kv, s_k, d)
    vr = v.reshape(b * kv, s_k, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            k_steps=k_steps,
            q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s_q, d)


def _attn_vmem_bytes(cfg, d: int = 128, dtype_bytes: int = 2) -> int:
    bq, bk = cfg["block_q"], cfg["block_k"]
    return (
        bq * d * dtype_bytes            # q tile
        + 2 * bk * d * dtype_bytes      # k, v tiles
        + bq * bk * 4                   # scores
        + bq * (d + 2) * 4              # acc + m + l scratch
    )


ATTENTION_SPACE = ParamSpace(
    [
        PowerOfTwoParam("block_q", 128, 2048),
        PowerOfTwoParam("block_k", 128, 2048),
    ],
    [
        Constraint(
            lambda c: _attn_vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "attention tile working set exceeds VMEM budget",
        )
    ],
)


def _attn_heuristic(q, k, v):
    s_q, s_k = q.shape[2], k.shape[2]
    blk = lambda s: min(512, max(128, 1 << (int(s) - 1).bit_length() if s < 128 else 128))
    return {"block_q": min(512, max(128, min(s_q, 512))) if s_q >= 128 else 128,
            "block_k": 512 if s_k >= 512 else 128}


def _attn_example():
    import numpy as np

    rs = np.random.RandomState(0)
    mk = lambda *s: jnp.asarray(rs.randn(*s) * 0.3, jnp.float32)
    return (mk(1, 4, 128, 16), mk(1, 2, 128, 16), mk(1, 2, 128, 16)), {"causal": True}


@tunable(
    "flash_attention",
    space=ATTENTION_SPACE,
    reference=functools.partial(ref.attention, causal=True),
    heuristic=_attn_heuristic,
    dispatch=DispatchSpec(
        # Reference takes the same (causal, window, scale) call kwargs.
        reference=ref.attention,
        # Same shapes, different masking semantics => distinct db records.
        key_extra=lambda kw: f"c{kw.get('causal', True)}w{kw.get('window', 0)}",
        example=_attn_example,
        # q, k, v all lead with the (data-parallel) batch dim.
        data_parallel_args=(0, 1, 2),
    ),
)
def flash_attention(
    q, k, v, *, block_q: int, block_k: int,
    causal: bool = True, window: int = 0,
    scale: Optional[float] = None, interpret: Optional[bool] = None,
):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return flash_attention_pallas(
        q, k, v, block_q=block_q, block_k=block_k,
        causal=causal, window=window, scale=scale, interpret=interpret,
    )
