"""Blocked MXU matmul — Pallas kernel with tunable VMEM tiling.

The paper's SIMD-pragma knob becomes the BlockSpec tile (bm, bn, bk): it
fixes the VMEM working set ``bm·bk + bk·bn + bm·bn(out) + bm·bn·4(acc)``
bytes and the MXU utilization (tiles should be multiples of 128 on the
contracting/lane dims). The k grid dim carries the fp32 accumulator and is
sequential ('arbitrary'); m/n are parallel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat
from ..core import Constraint, DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from ..core.platform import TPU_V5E
from . import ref


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, mults) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool = False,
) -> jax.Array:
    """[m, k] @ [k, n] -> [m, n] with explicit (bm, bn, bk) VMEM tiles.

    Non-divisible shapes are zero-padded up to tile multiples and the result
    sliced back (zero rows/cols contribute zero partial products, so padding
    is semantics-preserving for matmul).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    xp, wp = _pad_to(x, (bm, bk)), _pad_to(w, (bk, bn))
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def _vmem_bytes(cfg, dtype_bytes: int = 2) -> int:
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    return bm * bk * dtype_bytes + bk * bn * dtype_bytes + bm * bn * (dtype_bytes + 4)


MATMUL_SPACE = ParamSpace(
    [
        PowerOfTwoParam("bm", 8, 1024),
        PowerOfTwoParam("bn", 128, 1024),
        PowerOfTwoParam("bk", 128, 2048),
    ],
    [
        Constraint(
            lambda c: _vmem_bytes(c) <= TPU_V5E.vmem_bytes // 2,
            "tile working set exceeds VMEM budget",
        )
    ],
)


def _matmul_heuristic(x, w):
    """Shape-aware default ≈ what a hand-written library baseline would pick."""
    m, k = x.shape
    n = w.shape[1]
    pick = lambda d, cap: min(cap, max(8, 1 << (int(d) - 1).bit_length()))
    return {
        "bm": min(pick(m, 256), 1024),
        "bn": max(128, min(pick(n, 256), 1024)),
        "bk": max(128, min(pick(k, 512), 2048)),
    }


def _matmul_example():
    import numpy as np

    rs = np.random.RandomState(0)
    return (
        jnp.asarray(rs.randn(32, 64), jnp.float32),
        jnp.asarray(rs.randn(64, 16), jnp.float32),
    ), {}


def _matmul_canon(x, w):
    """Flatten leading (batch/seq) dims to rows: [..., k] @ [k, n].

    Model call sites pass activations of any rank; the kernel and its
    database keys see the canonical [rows, k] layout (rows is the
    data-parallel dim, so sharded traces key on local rows).
    """
    if x.ndim == 2:
        return (x, w), lambda out: out
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    return (xr, w), lambda out: out.reshape(*lead, out.shape[-1])


def _matmul_bwd(ct, x, w, **kwargs):
    """Backward plan: both gradients are matmul dispatch sites themselves.

    dL/dx = ct [m,n] @ wᵀ [n,k] and dL/dw = xᵀ [k,m] @ ct [m,n] — the
    auto-derived transposed-operand calls through the same registry, so a
    campaign record for the transposed bucket serves the gradient with zero
    extra machinery. dL/dw contracts over the token rows: under a sharded
    mesh its sharded dim is xᵀ's dim 1 / ct's dim 0, declared via
    ``dp_dims`` so the database key localizes the dims training actually
    shards (the planner emits the matching local-shape jobs).
    """
    from ..core.runtime import dispatch

    dx = dispatch("matmul", ct, w.T, **kwargs)
    dw = dispatch("matmul", x.T, ct, dp_dims={0: 1, 1: 0}, **kwargs)
    return dx, dw


@tunable(
    "matmul",
    space=MATMUL_SPACE,
    reference=ref.matmul,
    heuristic=_matmul_heuristic,
    dispatch=DispatchSpec(canonicalize=_matmul_canon, example=_matmul_example,
                          vjp="dispatch", bwd=_matmul_bwd),
)
def matmul(x, w, *, bm: int, bn: int, bk: int, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    return matmul_pallas(x, w, bm=bm, bn=bn, bk=bk, interpret=interpret)


# ---------------------------------------------------------------------------
# Abstract grid model — the statically-checkable mirror of matmul_pallas's
# clamp/pad/grid arithmetic (see core/gridmodel.py). Nominal shapes are a
# production-scale gemm so the legality verdict reflects real tiled axes.
# ---------------------------------------------------------------------------
from ..core.gridmodel import GridModel, RefModel, register_grid_model


def _matmul_grid_model(config, shapes=None):
    if shapes is None:
        shapes = ((4096, 4096), (4096, 4096))
    (m, k), n = shapes[0], shapes[1][1]
    bm = min(config["bm"], m)
    bn = min(config["bn"], n)
    bk = min(config["bk"], k)
    mp, kp, np_ = m + (-m) % bm, k + (-k) % bk, n + (-n) % bn
    grid = (mp // bm, np_ // bn, kp // bk)
    return GridModel(
        "matmul", grid, ("parallel", "parallel", "arbitrary"),
        (
            RefModel("x", (bm, bk), lambda i, j, kk: (i, kk), (mp, kp)),
            RefModel("w", (bk, bn), lambda i, j, kk: (kk, j), (kp, np_)),
            RefModel("out", (bm, bn), lambda i, j, kk: (i, j), (mp, np_),
                     role="out"),
        ),
    )


register_grid_model("matmul", _matmul_grid_model, space=MATMUL_SPACE)
