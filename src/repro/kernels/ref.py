"""Pure-jnp reference oracles ("reference implementation" in the paper's §2).

Every Pallas kernel variant is validated against these — a variant whose
output diverges from the oracle is pruned by the tuner's correctness gate.
They are also the lowering path used by the multi-pod dry-run (Pallas cannot
lower for TPU from a CPU-only container) and the fallback path in `ops.py`.
Keep them boring and obviously correct.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """[m, k] @ [k, n] -> [m, n], fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last dim: x * rsqrt(mean(x^2)+eps) * weight."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * weight


def attention(
    q: jax.Array,  # [b, h, s_q, d]
    k: jax.Array,  # [b, kv, s_k, d]
    v: jax.Array,  # [b, kv, s_k, d]
    causal: bool = True,
    scale: Optional[float] = None,
    window: int = 0,  # >0: sliding-window (causal) attention
) -> jax.Array:
    """Multi-head attention with GQA (h a multiple of kv), optional SWA."""
    b, h, s_q, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    scale = scale if scale is not None else d ** -0.5
    group = h // kv
    # expand kv heads to match q heads
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s_k = k.shape[2]
    if causal or window:
        q_idx = jnp.arange(s_q)[:, None] + (s_k - s_q)  # align ends (decode)
        k_idx = jnp.arange(s_k)[None, :]
        mask = jnp.ones((s_q, s_k), dtype=bool)
        if causal:
            mask &= q_idx >= k_idx
        if window:
            mask &= (q_idx - k_idx) < window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-row cross entropy: [r, v], [r] -> [r] (fp32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - label_logit


def ssm_scan(xc: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, h0: jax.Array):
    """Sequential S6 selective scan over time (one live state, `lax.scan`).

    xc [b,s,di] (model dtype), dt [b,s,di] fp32 (post-softplus), B/C
    [b,s,ds] fp32, A [di,ds] fp32 (negative), h0 [b,di,ds] fp32 carry-in.
    Returns (y [b,s,di] fp32, hN [b,di,ds] fp32).
    """
    xf = xc.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                       # [b,di],[b,di],[b,ds]x2
        dA = jnp.exp(dt_t[..., None] * A)               # [b,di,ds]
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1)       # [b,di]
        return h, y

    hN, ys = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (xf.swapaxes(0, 1), dt.swapaxes(0, 1), B.swapaxes(0, 1),
         C.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), hN


def ssm_update(xc: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
               A: jax.Array, h: jax.Array):
    """One fused decode step of the selective scan.

    xc/dt [b,di], B/C [b,ds], A [di,ds], h [b,di,ds].
    Returns (y [b,di] fp32, h_new [b,di,ds] fp32).
    """
    dA = jnp.exp(dt[..., None] * A)
    hn = dA * h + (dt * xc.astype(jnp.float32))[..., None] * B[:, None, :]
    y = jnp.sum(hn * C[:, None, :], axis=-1)
    return y, hn


def expert_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped expert GEMM: [e, c, k] @ [e, k, n] -> [e, c, n], fp32 acc."""
    return jnp.einsum(
        "eck,ekn->ecn", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def matmul_bias_act(x: jax.Array, w: jax.Array, b: jax.Array,
                    act: str = "none") -> jax.Array:
    """Fused-epilogue GEMM: ([m, k] @ [k, n] + b) through an activation.

    ``act`` ∈ {"none", "gelu", "silu"} — the epilogues the fused tunable
    offers (dense-with-bias projections and the ffn gate/up activations).
    """
    h = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "silu":
        h = jax.nn.silu(h)
    elif act != "none":
        raise ValueError(f"unknown fused activation {act!r}")
    return h.astype(x.dtype)


def rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Fused norm+projection: ``rmsnorm(x, scale) @ w`` (fp32 accumulation)."""
    return matmul(rmsnorm(x, scale, eps), w)


# ---------------------------------------------------------------------------
# Residual-emitting forward oracles — the tuning references of the residual-
# contract tunables (DispatchSpec.residuals > 0). Each returns
# ``(primal, *aux)`` with the same aux the Pallas variant emits, so the
# correctness gate compares like structure; each derives the aux from the
# same primal math (never a second code path). The plain oracles above stay
# the *deployment* references (reference-mode dispatch returns primals only).
# ---------------------------------------------------------------------------


def rmsnorm_res(x: jax.Array, weight: jax.Array, eps: float = 1e-6):
    """:func:`rmsnorm` + its per-row inverse rms residual ([rows] fp32)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    out = (xf * r).astype(x.dtype) * weight
    return out, r[..., 0]


def attention_res(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
    window: int = 0,
):
    """:func:`attention` + its per-query logsumexp residual ([b, h, s_q] fp32)."""
    b, h, s_q, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    scale = scale if scale is not None else d ** -0.5
    group = h // kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s_k = k.shape[2]
    if causal or window:
        q_idx = jnp.arange(s_q)[:, None] + (s_k - s_q)
        k_idx = jnp.arange(s_k)[None, :]
        mask = jnp.ones((s_q, s_k), dtype=bool)
        if causal:
            mask &= q_idx >= k_idx
        if window:
            mask &= (q_idx - k_idx) < window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    lse = jax.nn.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
    return out, lse


def softmax_xent_res(logits: jax.Array, labels: jax.Array):
    """:func:`softmax_xent` + its per-row logsumexp residual ([r] fp32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - label_logit, lse


# ---------------------------------------------------------------------------
# Backward oracles — the reference plane of the tuned backward dispatch
# sites. Each is the VJP of its forward oracle (so fwd/bwd reference pairs
# cannot drift apart), called with the cotangent first: bwd(ct, *fwd_args).
# They are the correctness gate for the Pallas bwd variants AND the
# Reference-tier fallback when a gradient bucket resolves to no kernel.
# ---------------------------------------------------------------------------


def rmsnorm_bwd(ct: jax.Array, x: jax.Array, weight: jax.Array,
                invrms: Optional[jax.Array] = None, eps: float = 1e-6):
    """VJP of :func:`rmsnorm`: (d_x, d_weight).

    ``invrms`` is the residual-threaded inverse rms the *kernel* consumes;
    the oracle stays the VJP of the forward oracle (it re-derives everything
    from x), so fwd/bwd reference pairs cannot drift apart.
    """
    del invrms
    _, vjp = jax.vjp(lambda xx, ww: rmsnorm(xx, ww, eps), x, weight)
    return vjp(ct)


def attention_bwd(
    ct: jax.Array,  # [b, h, s_q, d] — cotangent of the attention output
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: Optional[jax.Array] = None,    # residual: the forward output
    lse: Optional[jax.Array] = None,  # residual: per-query logsumexp
    causal: bool = True,
    scale: Optional[float] = None,
    window: int = 0,
):
    """VJP of :func:`attention`: (d_q, d_k, d_v).

    ``o``/``lse`` are the residuals the *kernel* consumes (delta rows and
    the softmax reconstruction); the oracle recomputes from (q, k, v).
    """
    del o, lse
    _, vjp = jax.vjp(
        lambda qq, kk, vv: attention(qq, kk, vv, causal=causal, scale=scale,
                                     window=window),
        q, k, v,
    )
    return vjp(ct)


def softmax_xent_bwd(ct: jax.Array, logits: jax.Array, labels: jax.Array,
                     lse: Optional[jax.Array] = None) -> jax.Array:
    """VJP of :func:`softmax_xent` w.r.t. logits: (softmax - onehot) · ct.

    ``ct`` is the per-row loss cotangent [r]; labels carry no gradient.
    ``lse`` is the residual the *kernel* consumes; the oracle recomputes.
    """
    del lse
    _, vjp = jax.vjp(lambda ll: softmax_xent(ll, labels), logits)
    return vjp(ct)[0]


def ssm_scan_bwd(ct_y: jax.Array, ct_h: jax.Array, xc, dt, B, C, A, h0):
    """VJP of :func:`ssm_scan`: (d_xc, d_dt, d_B, d_C, d_A, d_h0).

    Cotangents come first — ``ct_y`` for the per-step outputs, ``ct_h`` for
    the carried-out final state (prefill hands it to decode, so it is live).
    """
    _, vjp = jax.vjp(ssm_scan, xc, dt, B, C, A, h0)
    return vjp((ct_y, ct_h))


def ssm_update_bwd(ct_y: jax.Array, ct_h: jax.Array, xc, dt, B, C, A, h):
    """VJP of :func:`ssm_update`: (d_xc, d_dt, d_B, d_C, d_A, d_h)."""
    _, vjp = jax.vjp(ssm_update, xc, dt, B, C, A, h)
    return vjp((ct_y, ct_h))
