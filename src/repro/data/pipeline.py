"""Deterministic synthetic token pipeline with per-host sharding.

Production shape without production weight: the pipeline is seeded,
stateless-resumable (state = (seed, step)), yields already-host-sharded
batches, and knows every arch's input layout (tokens / frame embeddings /
patch prefixes). Determinism + O(1) resume state is what checkpoint-restart
and elastic rescale need from a data layer: after restoring step N on a
different host count, every host regenerates exactly its own shard of batch
N+1.

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, so models *can* learn (loss decreases measurably in the
examples) while requiring no disk data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch_size: int = 8          # global batch
    seq_len: int = 128
    host_index: int = 0
    host_count: int = 1
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticPipeline:
    """Stateless-resumable iterator over synthetic LM batches."""

    def __init__(self, cfg: ArchConfig, data: DataConfig, step: int = 0):
        if data.batch_size % data.host_count:
            raise ValueError(
                f"global batch {data.batch_size} not divisible by "
                f"{data.host_count} hosts"
            )
        self.cfg = cfg
        self.data = data
        self.step = step
        self._local = data.batch_size // data.host_count
        vocab = cfg.vocab_size
        # Zipf-ish unigram distribution (heavy head, long tail)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks**1.1
        self._probs = probs / probs.sum()

    # -- resumability ---------------------------------------------------------
    def state_dict(self) -> Dict:
        return {"seed": self.data.seed, "step": self.step}

    def load_state_dict(self, state: Dict) -> None:
        assert state["seed"] == self.data.seed, "data seed changed mid-run"
        self.step = int(state["step"])

    # -- generation ------------------------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        # independent stream per (seed, step, host): resume/elastic safe
        return np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step, self.data.host_index])
        )

    def _tokens(self, rng: np.random.Generator, n_rows: int) -> np.ndarray:
        d = self.data
        S = d.seq_len + 1  # +1: shift into (inputs, labels)
        toks = rng.choice(
            self.cfg.vocab_size, size=(n_rows, S), p=self._probs
        ).astype(np.int32)
        # plant motifs: spans repeated immediately, giving learnable structure
        for r in range(n_rows):
            if rng.random() < d.motif_prob and S >= 2 * d.motif_len + 1:
                start = rng.integers(0, S - 2 * d.motif_len)
                motif = toks[r, start : start + d.motif_len]
                toks[r, start + d.motif_len : start + 2 * d.motif_len] = motif
        return toks

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg, d = self.cfg, self.data
        rng = self._rng_for(self.step)
        self.step += 1
        B, S = self._local, d.seq_len

        if cfg.frontend == "audio_frames":
            labels = self._tokens(rng, B)[:, 1:]
            embeds = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.1
            return {"embeds": embeds, "labels": labels}

        if cfg.frontend == "vision_patches":
            P = cfg.num_prefix
            assert S > P, (S, P)
            toks = self._tokens(rng, B)
            embeds = rng.standard_normal((B, P, cfg.d_model)).astype(np.float32) * 0.1
            labels = toks[:, 1 : S + 1]
            mask = np.zeros((B, S), np.float32)
            mask[:, P:] = 1.0
            return {
                "embeds": embeds,
                "tokens": toks[:, : S - P],
                "labels": labels,
                "loss_mask": mask,
            }

        toks = self._tokens(rng, B)
        return {"tokens": toks[:, :S], "labels": toks[:, 1 : S + 1]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
