from . import checkpoint, resilience, trainer
from .trainer import Trainer, TrainerConfig
