"""Sharded, atomic, async checkpointing with elastic restore.

Layout on disk:
    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, leaf->file map, hash
        leaf_00000.npy ...  # one file per pytree leaf (np arrays)
        _COMMITTED          # written LAST; restore ignores dirs without it

Fault-tolerance properties:
  * atomic: the step directory is staged as .tmp-* and renamed only after
    _COMMITTED is fsync'd — a crash mid-save never corrupts the latest
    checkpoint (verified by test_checkpoint_kill_mid_save).
  * async: `save_async` hands the (host-local) arrays to a writer thread;
    training continues. `wait()` joins before the next save to bound memory.
  * elastic: restore() rebuilds arrays then the caller re-shards onto
    whatever mesh is current — checkpoints carry no mesh metadata, so a
    256-chip checkpoint restores onto 512 chips (or 1 CPU) unchanged.
  * integrity: manifest stores per-leaf (shape, dtype, crc32); restore
    validates before handing arrays back.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import tempfile
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..testing.faults import fault_point as _fault_point

log = logging.getLogger("repro.checkpoint")

_COMMIT_MARK = "_COMMITTED"
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        # Writer-thread failure, relayed to the training thread. Guarded by
        # a lock (writer sets, trainer reads-and-clears) and re-raised from
        # wait() — which save()/save_async() call first — so a failed async
        # write can never be silently treated as a committed recovery point.
        self._error: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        """Blocking sharded save. Returns the committed directory."""
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        """Device->host transfer now; disk write on a background thread."""
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                self._write(step, host_tree)
            except BaseException as e:  # surfaced on next wait()/save*
                with self._err_lock:
                    self._error = e
                log.warning(
                    "async checkpoint write for step %d failed: %s: %s "
                    "(will re-raise on the training thread)",
                    step, type(e).__name__, e,
                )

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._err_lock:
            e, self._error = self._error, None
        if e is not None:
            raise RuntimeError(f"async checkpoint failed: {e}") from e

    def _write(self, step: int, host_tree) -> str:
        # Named site for the chaos suite: inject ENOSPC-class write failures
        # deterministically (plan.install() — this runs on the writer thread).
        _fault_point(f"checkpoint.write:{step}", step=step)
        paths, leaves, treedef = _flatten_with_paths(host_tree)
        final = os.path.join(self.directory, f"step_{step:09d}")
        stage = tempfile.mkdtemp(prefix=".tmp-", dir=self.directory)
        try:
            manifest = {"step": step, "leaves": []}
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(leaf)
                fname = f"leaf_{i:05d}.npy"
                logical_dtype = str(arr.dtype)
                stored = arr
                # non-native dtypes (bfloat16, fp8) round-trip through .npy as
                # a same-width integer view; the manifest keeps the truth
                if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
                    stored = arr.view(f"u{arr.dtype.itemsize}")
                np.save(os.path.join(stage, fname), stored)
                manifest["leaves"].append(
                    {
                        "path": p,
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": logical_dtype,
                        "stored_dtype": str(stored.dtype),
                        "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                    }
                )
            manifest["treedef"] = jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex() \
                if hasattr(jax.tree_util.tree_structure(host_tree), "serialize_using_proto") else None
            with open(os.path.join(stage, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(stage, _COMMIT_MARK), "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(stage, final)
        except BaseException:
            shutil.rmtree(stage, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True
            )
        # remove stale staging dirs from crashed saves
        for name in os.listdir(self.directory):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                os.path.join(self.directory, name, _COMMIT_MARK)
            ):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings=None) -> Any:
        """Restore into the structure of `target_tree`.

        `shardings` (optional pytree of NamedSharding) re-shards every leaf
        onto the current mesh — this is the elastic-rescale path: the
        checkpoint knows nothing about meshes.
        """
        d = os.path.join(self.directory, f"step_{step:09d}")
        if not os.path.exists(os.path.join(d, _COMMIT_MARK)):
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        paths, leaves, treedef = _flatten_with_paths(target_tree)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        if set(paths) != set(by_path):
            missing = set(paths) - set(by_path)
            extra = set(by_path) - set(paths)
            raise ValueError(
                f"checkpoint/target tree mismatch: missing={sorted(missing)[:5]} "
                f"extra={sorted(extra)[:5]}"
            )
        out_leaves = []
        for p, tgt in zip(paths, leaves):
            e = by_path[p]
            arr = np.load(os.path.join(d, e["file"]))
            if str(arr.dtype) != e.get("stored_dtype", e["dtype"]):
                raise ValueError(f"manifest mismatch for {p}")
            if e.get("stored_dtype", e["dtype"]) != e["dtype"]:
                import ml_dtypes  # jax dependency

                arr = arr.view(np.dtype(getattr(ml_dtypes, e["dtype"])))
            if list(arr.shape) != e["shape"] or str(arr.dtype) != e["dtype"]:
                raise ValueError(f"manifest mismatch for {p}")
            if zlib.crc32(arr.tobytes()) & 0xFFFFFFFF != e["crc32"]:
                raise ValueError(f"crc mismatch for {p} — corrupt checkpoint")
            if hasattr(tgt, "shape") and tuple(tgt.shape) != arr.shape:
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {arr.shape} vs target {tgt.shape}"
                )
            out_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree
