"""The training loop: sharded init, step execution, checkpoint/restart,
straggler monitoring, gradient compression — assembled from the substrate.

Single-host usage (examples, tests) and pod usage share this class; the
difference is the mesh handed in. The Trainer never constructs device state
outside the mesh's shardings, so the same code drives 1 CPU or 512 chips.

Dispatch: the model's kernel sites (projection/FFN gemms, rmsnorm, the fused
loss, flash attention) resolve through the dispatch runtime — forward AND
backward: in kernel mode the gradients are dispatch sites too (transposed
matmul gemms, the ``*_bwd`` tunables), resolved under the same scope with
``bwd``-tagged telemetry, so a planned campaign (``campaign plan
--train-mesh``) pre-tunes everything a train step executes. Pass
``runtime=repro.runtime(db=..., mode=...)`` to pin a campaign database for
the whole run — every trace the trainer builds executes under that scope
*and* under the trainer's ``mesh_context``, so database keys use per-device
local shard shapes (what a campaign tuned), and ``runtime.telemetry``
reports which tier served each kernel×bucket per phase. With
``runtime=None`` the ambient/default runtime applies, as before
(``runtime=repro.runtime(..., bwd_dispatch=False)`` restores the old
reference-VJP backward recompute).
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.runtime import dispatch_phase
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..distributed import sharding as shd
from ..distributed.collectives import compress_grads, ef_init
from ..models import lm
from ..models.transformer import RunConfig
from ..obs.collect import current_collector as _obs_collector
from ..obs.trace import span as _obs_span
from ..testing.faults import fault_point as _fault_point
from ..optim import adamw
from . import checkpoint as ckpt_mod
from .resilience import RestartPolicy, StragglerMonitor, run_with_recovery

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "checkpoints"
    checkpoint_keep: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    seed: int = 0
    grad_compression: str = "none"      # none | bf16 | int8_ef
    max_failures: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        run: RunConfig,
        mesh: jax.sharding.Mesh,
        layout: shd.Layout,
        data_cfg: DataConfig,
        opt_cfg: Optional[adamw.AdamWConfig] = None,
        tcfg: Optional[TrainerConfig] = None,
        runtime: Optional[Any] = None,
    ):
        self.cfg = cfg
        self.run = run
        self.mesh = mesh
        self.layout = layout
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.runtime = runtime          # a TunedRuntime, or None = ambient
        # The degree the step's batch dim is sharded at — drives local-shape
        # database keying. Computed ONCE from the per-microbatch batch dim
        # (what every kernel site actually sees), mirroring the campaign
        # planner's plan_training_jobs; never re-derived per argument.
        # Known approximation: when microbatching shrinks the batch below
        # the input sharding's full multi-axis degree (b/k not divisible by
        # the axes that divide b), XLA's reshape propagation decides the
        # true per-device shape — keys then state the b/k-derived degree,
        # which planner and dispatch still agree on (see ROADMAP).
        sizes = shd.mesh_axis_sizes(mesh)
        self._dp_degree = shd.data_parallel_degree(
            sizes, layout,
            max(1, data_cfg.batch_size // max(1, run.microbatches)),
        )
        # When the microbatch divides the mesh differently from the full
        # input batch, the degree above is an approximation of XLA's actual
        # shard choice — flagged so the keying layer emits a one-time
        # structured warning naming the affected key.
        self._dp_approx = (
            run.microbatches > 1
            and self._dp_degree
            != shd.data_parallel_degree(sizes, layout, data_cfg.batch_size)
        )
        self.data = SyntheticPipeline(cfg, data_cfg)
        self.ckpt = ckpt_mod.Checkpointer(
            self.tcfg.checkpoint_dir, keep=self.tcfg.checkpoint_keep
        )
        self.monitor = StragglerMonitor()
        self.step = 0
        self._build()

    def _scope(self):
        """The trainer's execution scope: pinned runtime (if any) + ambient
        mesh/layout context.

        Entered around every call that may *trace* model code (init, the
        train step): jax.jit traces lazily, so the scope must be live at
        call time, not construction time. The mesh context is what switches
        dispatch keying to per-device local shard shapes.
        """
        stack = contextlib.ExitStack()
        if self.runtime is not None:
            stack.enter_context(self.runtime)
        stack.enter_context(
            shd.mesh_context(self.mesh, self.layout, dp_degree=self._dp_degree,
                             dp_approx=self._dp_approx)
        )
        return stack

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        cfg, mesh, layout = self.cfg, self.mesh, self.layout
        params_abs, axes = lm.abstract_params(cfg)
        self.p_sh = shd.param_shardings(axes, params_abs, mesh, layout)
        rep = shd.replicated(mesh)
        self.o_sh = adamw.state_shardings(self.p_sh, self.opt_cfg.master_fp32, rep)

        def init_all(rng):
            params, _ = lm.init_params(rng, cfg)
            opt_state = adamw.init(self.opt_cfg, params)
            return params, opt_state

        init_jit = jax.jit(init_all, out_shardings=(self.p_sh, self.o_sh))
        with self._scope():
            self.params, self.opt_state = init_jit(
                jax.random.PRNGKey(self.tcfg.seed)
            )
            if self.tcfg.grad_compression == "int8_ef":
                self.ef_state = jax.jit(ef_init, out_shardings=self.p_sh)(self.params)
            else:
                self.ef_state = None

        comp_mode = self.tcfg.grad_compression
        run, opt_cfg = self.run, self.opt_cfg

        def loss_fn(params, batch):
            return lm.loss_fn(params, batch, cfg, run)

        def train_step(params, opt_state, ef_state, batch):
            if run.microbatches > 1:
                k = run.microbatches
                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
                )

                def body(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                    return (
                        jax.tree_util.tree_map(lambda a, b: a + b.astype(a.dtype), g_acc, g),
                        l_acc + l,
                    ), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (grads, loss), _ = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32)), mbs
                )
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                loss = loss / k
            else:
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch
                )
            grads, ef_state = compress_grads(grads, ef_state, comp_mode)
            # Phase-tag the optimizer update: any dispatch resolved while
            # tracing it carries phase="opt" in telemetry/obs. adamw itself
            # contains no dispatch sites today, so existing fwd/bwd-only
            # accounting is unchanged — the tag is the hook.
            with dispatch_phase("opt"):
                params, opt_state, om = adamw.update(
                    opt_cfg, grads, opt_state, params
                )
            return params, opt_state, ef_state, {"loss": loss, **om}

        b_abs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            self.data.next_batch(),
        )
        self.data.step -= 1  # peek, don't consume
        b_sh = shd.data_specs(b_abs, mesh, layout)
        ef_sh = self.p_sh if self.ef_state is not None else None
        self._train_step = jax.jit(
            train_step,
            in_shardings=(self.p_sh, self.o_sh, ef_sh, b_sh),
            out_shardings=(self.p_sh, self.o_sh, ef_sh, None),
            donate_argnums=(0, 1, 2),
        )
        self._b_sh = b_sh

    # ------------------------------------------------------------------- state
    def _state_tree(self):
        t = {
            "params": self.params,
            "opt": self.opt_state,
            "data": {"step": jnp.asarray(self.data.step, jnp.int32)},
            "trainer_step": jnp.asarray(self.step, jnp.int32),
        }
        if self.ef_state is not None:
            t["ef"] = self.ef_state
        return t

    def save_checkpoint(self) -> None:
        tree = self._state_tree()
        if self.tcfg.async_checkpoint:
            self.ckpt.save_async(self.step, tree)
        else:
            self.ckpt.save(self.step, tree)

    def restore_checkpoint(self, step: Optional[int] = None) -> int:
        self.ckpt.wait()
        step = step if step is not None else self.ckpt.latest_step()
        if step is None:
            log.warning("no checkpoint to restore; restarting from scratch")
            self._build()
            self.step = 0
            self.data.step = 0
            return 0
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self._state_tree()
        )
        shardings = {
            "params": self.p_sh,
            "opt": self.o_sh,
            "data": {"step": shd.replicated(self.mesh)},
            "trainer_step": shd.replicated(self.mesh),
        }
        if self.ef_state is not None:
            shardings["ef"] = self.p_sh
        tree = self.ckpt.restore(step, target, shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        if self.ef_state is not None:
            self.ef_state = tree["ef"]
        self.data.step = int(tree["data"]["step"])
        self.step = int(tree["trainer_step"])
        log.info("restored checkpoint at step %d", self.step)
        return self.step

    # -------------------------------------------------------------------- run
    def run_one_step(self) -> Dict[str, float]:
        with _obs_span("train.data"):
            batch_np = self.data.next_batch()
        batch = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), batch_np, self._b_sh
        )
        t0 = time.perf_counter()
        with _obs_span("train.step", step=self.step):
            with self._scope():
                self.params, self.opt_state, self.ef_state, metrics = (
                    self._train_step(
                        self.params, self.opt_state, self.ef_state, batch
                    )
                )
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        self.monitor.record(self.step, dt)
        self.step += 1
        metrics["step_time_s"] = dt
        col = _obs_collector()
        if col.enabled:
            leaves = jax.tree_util.tree_leaves(batch_np)
            tokens = (
                int(np.prod(leaves[0].shape[:2]))
                if leaves and getattr(leaves[0], "ndim", 0) >= 2 else 0
            )
            col.observe("train.step_s", dt)
            if tokens and dt > 0:
                col.counter("train.tokens", tokens)
                col.gauge("train.tokens_per_s", tokens / dt)
        if self.step % self.tcfg.checkpoint_every == 0:
            self.save_checkpoint()
        if self.step % self.tcfg.log_every == 0:
            log.info(
                "step %d loss %.4f (%.2fs)", self.step, metrics["loss"], dt
            )
        return metrics

    def train(self, fail_hook: Optional[Callable[[int], None]] = None) -> Dict:
        """Run to total_steps with recovery. `fail_hook(step)` (tests) may
        raise to simulate node failure at a given step."""

        def step_fn(step: int) -> Dict:
            if fail_hook is not None:
                fail_hook(step)
            # Named chaos site: a FaultPlan can fail chosen steps without the
            # caller wiring a fail_hook (recovery drills exercise the same
            # run_with_recovery path either way).
            _fault_point(f"train.step:{step}", step=step)
            return self.run_one_step()

        def restore_fn() -> int:
            return self.restore_checkpoint()

        policy = RestartPolicy(max_failures=self.tcfg.max_failures)
        metrics = run_with_recovery(
            step_fn,
            restore_fn,
            total_steps=self.tcfg.total_steps,
            start_step=self.step,
            policy=policy,
            sleep=lambda s: None,
        )
        self.ckpt.wait()
        return metrics
