"""Fault-tolerance utilities: failure detection, straggler monitor, restart
policy.

At 1000+ nodes the mean time between node failures drops below job length;
the framework must treat failure as a steady-state input, not an exception:

  * :class:`StragglerMonitor` — robust per-step timing stats (median/MAD);
    flags steps beyond k·MAD and exposes a pluggable response (log, or a
    callback that would trigger re-slicing/hot-spare swap on a real fleet).
  * :class:`RestartPolicy` — bounded exponential backoff with a failure
    budget, so a flapping node cannot livelock the job.
  * :func:`run_with_recovery` — the supervision loop the Trainer uses: run
    step → on exception, restore from the last committed checkpoint and
    replay. The data pipeline's O(1) resume state makes replay exact.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from typing import Callable, Dict, List, Optional

log = logging.getLogger("repro.resilience")


class StragglerMonitor:
    """Flags abnormally slow steps via median + MAD (robust to warmup)."""

    def __init__(self, window: int = 50, threshold_mads: float = 5.0,
                 min_samples: int = 8,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.window = window
        self.threshold = threshold_mads
        self.min_samples = min_samples
        self.times: List[float] = []
        self.flagged: List[int] = []
        self.on_straggler = on_straggler

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def record(self, step: int, seconds: float) -> bool:
        """Record one step time; returns True if it is a straggler."""
        history = self.times[-self.window:]
        self.times.append(seconds)
        if len(history) < self.min_samples:
            return False
        med = self._median(history)
        mad = self._median([abs(t - med) for t in history]) or 1e-9
        if seconds > med + self.threshold * mad and seconds > 1.2 * med:
            self.flagged.append(step)
            log.warning(
                "straggler at step %d: %.3fs vs median %.3fs (MAD %.3fs)",
                step, seconds, med, mad,
            )
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False


@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 10
    backoff_base_s: float = 0.1
    backoff_cap_s: float = 30.0
    failures: int = 0

    def on_failure(self) -> float:
        """Record a failure; return backoff seconds. Raises if budget spent."""
        self.failures += 1
        if self.failures > self.max_failures:
            raise RuntimeError(
                f"failure budget exhausted ({self.failures} failures)"
            )
        return min(self.backoff_cap_s, self.backoff_base_s * 2 ** (self.failures - 1))


def run_with_recovery(
    step_fn: Callable[[int], Dict],
    restore_fn: Callable[[], int],
    total_steps: int,
    start_step: int = 0,
    policy: Optional[RestartPolicy] = None,
    sleep=time.sleep,
) -> Dict:
    """Supervision loop: execute steps, recover-and-replay on failure.

    step_fn(step) runs one training step (it owns state mutation).
    restore_fn() rolls state back to the last committed checkpoint and
    returns the step to resume from.
    """
    policy = policy or RestartPolicy()
    step = start_step
    metrics: Dict = {}
    while step < total_steps:
        try:
            metrics = step_fn(step)
            step += 1
        except KeyboardInterrupt:
            raise
        except Exception as e:
            backoff = policy.on_failure()
            log.error("step %d failed (%s); restoring (backoff %.2fs)", step, e, backoff)
            sleep(backoff)
            step = restore_fn()
    return metrics
