"""State-space / recurrent mixers: Mamba (jamba), mLSTM + sLSTM (xlstm).

All three follow the same deployment contract as attention:
  *_forward(params, x, chunk) -> (y, final_state)   # train / prefill
  *_decode(params, x_t, state) -> (y_t, new_state)  # one-token decode

Sequence-parallel forms never materialize [b, s, d_inner, d_state]:
  * Mamba routes its selective scan through the ``ssm_scan`` dispatch site
    (Pallas chunked kernel / chunked associative-scan reference — peak live
    tensor is [b, chunk, d_inner, d_state]) and decode through the fused
    ``ssm_update`` site; the projection gemms are registry ``matmul``
    dispatches, so a tuned database serves every hot op of the layer.
  * mLSTM uses the stabilized *chunkwise* form: intra-chunk attention-like
    matmuls under a cumulative-forget decay mask + inter-chunk matrix-memory
    carry (peak live tensor [b, h, chunk, chunk]); its projection gemms
    also dispatch registry ``matmul``.
  * sLSTM is inherently sequential (recurrent R matrix): `lax.scan` over
    time with exp-gating stabilizers; input/MLP gemms dispatch ``matmul``.

The scan chunk/block schedule is the kernel tunable's knob now (same role
as flash attention's block_k). Decode state is O(1) in sequence length —
which is why the long_500k cells run for xlstm/jamba and are skipped for
quadratic archs.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.runtime import dispatch
from .layers import Axes, Params, _init

LOG_EPS = -1e30


# ===========================================================================
# Mamba (S6 selective scan)
# ===========================================================================


def mamba_init(rng, d: int, dtype, expand: int = 2, d_state: int = 16,
               d_conv: int = 4) -> Tuple[Params, Axes]:
    di = expand * d
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(rng, 6)
    p: Params = {
        "in_proj": _init(ks[0], (d, 2 * di), dtype),
        "conv_w": _init(ks[1], (d_conv, di), dtype, scale=1.0 / math.sqrt(d_conv)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * d_state), dtype),
        "dt_proj": _init(ks[3], (dt_rank, di), dtype, scale=1.0 / math.sqrt(dt_rank)),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),  # softplus^-1(~0.12)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, d_state))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[4], (di, d), dtype, scale=1.0 / math.sqrt(di)),
    }
    a: Axes = {
        "in_proj": ("d_model", "ff"),
        "conv_w": ("conv_k", "ff"),
        "conv_b": ("ff",),
        "x_proj": ("ff", "ssm_small"),
        "dt_proj": ("ssm_small", "ff"),
        "dt_bias": ("ff",),
        "A_log": ("ff", "ssm_state"),
        "D": ("ff",),
        "out_proj": ("ff", "d_model"),
    }
    return p, a


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array = None) -> jax.Array:
    """Depthwise causal conv over time. x: [b, s, di]; w: [k, di]."""
    k = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    y = sum(xp[:, j : j + s] * w[j] for j in range(k))
    return y + b


def _mamba_project(p, x):
    xz = dispatch("matmul", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    return x_in, z


def _mamba_dtBC(p, xc):
    """xc: conv'd, silu'd branch [b, s, di] -> coefficient inputs.

    Returns (dt [b,s,di] fp32 post-softplus, B [b,s,ds] fp32, C [b,s,ds]
    fp32) — the precomputed per-step coefficients the ``ssm_scan`` /
    ``ssm_update`` dispatch sites consume.
    """
    d_state = p["A_log"].shape[1]
    dt_rank = p["x_proj"].shape[1] - 2 * d_state
    proj = dispatch("matmul", xc, p["x_proj"])
    dt, B, C = jnp.split(proj.astype(jnp.float32), [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        dispatch("matmul", dt, p["dt_proj"].astype(jnp.float32)) + p["dt_bias"]
    )  # [b,s,di]
    return dt, B, C


def _mamba_out(p, y, xc, z, out_dtype):
    """Skip term, silu gate, down-projection (fp32 gemm like the original)."""
    y = y + p["D"] * xc.astype(jnp.float32)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    return dispatch("matmul", g, p["out_proj"].astype(jnp.float32)).astype(out_dtype)


def mamba_forward(p: Params, x: jax.Array, *,
                  return_state: bool = False, scan_fn=None):
    """x: [b, s, d]. Returns y or (y, state) with state=(h, conv_tail).

    The scan is the ``ssm_scan`` dispatch site; its chunk/block schedule
    comes from the tuned runtime (the old ``chunk`` parameter was inert
    after the dispatch rewire and is removed). The model-level
    ``mamba_chunk`` tunable instead passes ``scan_fn`` (same
    (xc, dt, B, C, A, h0) contract) to pin an explicit chunk schedule for
    wall-clock measurement.
    Zero-padded tails inside the kernel are identity steps (dt = 0 =>
    dA = 1, dBx = 0), so the returned state is exactly h at step s-1 for
    any sequence length.
    """
    b, s, d = x.shape
    di = p["conv_b"].shape[0]
    d_state = p["A_log"].shape[1]
    k = p["conv_w"].shape[0]
    x_in, z = _mamba_project(p, x)
    xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    dt, B, C = _mamba_dtBC(p, xc)
    A = -jnp.exp(p["A_log"])                                    # [di, ds]
    h0 = jnp.zeros((b, di, d_state), jnp.float32)
    if scan_fn is None:
        y, hN = dispatch("ssm_scan", xc, dt, B, C, A, h0)
    else:
        y, hN = scan_fn(xc, dt, B, C, A, h0)
    out = _mamba_out(p, y, xc, z, x.dtype)
    if not return_state:
        return out
    # decode needs the last k-1 *pre-conv* inputs
    conv_tail = x_in[:, -(k - 1):] if s >= k - 1 else jnp.pad(
        x_in, ((0, 0), (k - 1 - s, 0), (0, 0))
    )
    return out, {"h": hN, "conv": conv_tail}


def mamba_state_spec(batch: int, d: int, dtype, expand: int = 2,
                     d_state: int = 16, d_conv: int = 4):
    di = expand * d
    return {
        "h": jax.ShapeDtypeStruct((batch, di, d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, d_conv - 1, di), dtype),
    }


def mamba_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array]):
    """x: [b, 1, d] one token. Returns (y [b,1,d], new_state).

    The state update is the fused ``ssm_update`` dispatch site.
    """
    x_in, z = _mamba_project(p, x)                              # [b,1,di]
    window = jnp.concatenate([state["conv"].astype(x.dtype), x_in], axis=1)  # [b,k,di]
    xc = jax.nn.silu((window * p["conv_w"][None]).sum(axis=1, keepdims=True) + p["conv_b"])
    dt, B, C = _mamba_dtBC(p, xc)                               # [b,1,...]
    A = -jnp.exp(p["A_log"])
    y, h = dispatch("ssm_update", xc[:, 0], dt[:, 0], B[:, 0], C[:, 0], A,
                    state["h"])
    out = _mamba_out(p, y[:, None], xc, z, x.dtype)
    return out, {"h": h, "conv": window[:, 1:]}


# ===========================================================================
# mLSTM (matrix-memory LSTM, xLSTM) — stabilized chunkwise-parallel form
# ===========================================================================


def mlstm_init(rng, d: int, n_heads: int, dtype, expand: int = 2) -> Tuple[Params, Axes]:
    di = expand * d
    ks = jax.random.split(rng, 7)
    p: Params = {
        "in_proj": _init(ks[0], (d, 2 * di), dtype),
        "wq": _init(ks[1], (di, di), dtype),
        "wk": _init(ks[2], (di, di), dtype),
        "wv": _init(ks[3], (di, di), dtype),
        "w_gates": _init(ks[4], (di, 2 * n_heads), jnp.float32, scale=0.01),
        "b_gates": jnp.concatenate(
            [jnp.zeros((n_heads,)), jnp.full((n_heads,), 3.0)]  # forget-bias>0
        ).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": _init(ks[5], (di, d), dtype, scale=1.0 / math.sqrt(di)),
    }
    a: Axes = {
        "in_proj": ("d_model", "ff"),
        "wq": ("ff", "ff2"), "wk": ("ff", "ff2"), "wv": ("ff", "ff2"),
        "w_gates": ("ff", "heads_small"),
        "b_gates": ("heads_small",),
        "norm_scale": ("ff",),
        "out_proj": ("ff", "d_model"),
    }
    return p, a


def _mlstm_qkvg(p, x, n_heads):
    b, s, d = x.shape
    di = p["wq"].shape[0]
    hd = di // n_heads
    xz = dispatch("matmul", x, p["in_proj"])
    xb, z = jnp.split(xz, 2, axis=-1)
    q = dispatch("matmul", xb, p["wq"]).reshape(b, s, n_heads, hd).swapaxes(1, 2)
    kk = dispatch("matmul", xb, p["wk"]).reshape(b, s, n_heads, hd).swapaxes(1, 2)
    v = dispatch("matmul", xb, p["wv"]).reshape(b, s, n_heads, hd).swapaxes(1, 2)
    # repro: allow-raw(gate projection is tiny — [di, 2h] with h a handful of heads, far below the tuned-gemm tile floor)
    gates = xb.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)                   # [b,s,h]
    log_f = jax.nn.log_sigmoid(f_raw)
    return q, kk, v, z, log_i.swapaxes(1, 2), log_f.swapaxes(1, 2)  # gates [b,h,s]


def mlstm_forward(p: Params, x: jax.Array, *, n_heads: int, chunk: int = 64,
                  return_state: bool = False):
    b, s, d = x.shape
    di = p["wq"].shape[0]
    hd = di // n_heads
    q, k, v, z, log_i, log_f = _mlstm_qkvg(p, x, n_heads)
    scale = hd ** -0.5

    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        padt = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 3))
        q, k, v = padt(q), padt(k), padt(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)), constant_values=LOG_EPS)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
    sp = q.shape[2]
    nc = sp // chunk
    resh = lambda t: t.reshape(b, n_heads, nc, chunk, *t.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # -> [nc, b, h, chunk, ...]
    qs, ks_, vs = resh(q), resh(k), resh(v)
    lis = log_i.reshape(b, n_heads, nc, chunk).swapaxes(0, 2).swapaxes(1, 2)
    lfs = log_f.reshape(b, n_heads, nc, chunk).swapaxes(0, 2).swapaxes(1, 2)

    # repro: allow-raw(mLSTM decay-masked score matmuls await the fused mlstm_scores tunable — ROADMAP item 1; plain-matmul records cannot carry the mask epilogue)
    def chunk_step(carry, inp):
        C, n, m = carry                       # [b,h,hd,hd], [b,h,hd], [b,h]
        qc, kc, vc, li, lf = inp              # [b,h,c,hd]x3, [b,h,c]x2
        F = jnp.cumsum(lf, axis=-1)           # inclusive cum log-forget
        # intra-chunk decay:  g[t,s_] = F_t - F_s + li_s  for s_ <= t
        g = F[..., :, None] - F[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        g = jnp.where(tri, g, LOG_EPS)
        # carry-in decay per step t: F_t (+ running stabilizer m)
        carry_lg = F + m[..., None]           # [b,h,c]
        m_new = jnp.maximum(g.max(-1), carry_lg)          # [b,h,c]
        Dmat = jnp.exp(g - m_new[..., None])              # [b,h,c,c]
        inter = jnp.exp(carry_lg - m_new)                 # [b,h,c]
        scores = jnp.einsum("bhtd,bhsd->bhts", qc.astype(jnp.float32) * scale,
                            kc.astype(jnp.float32)) * Dmat
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vc.astype(jnp.float32)) \
            + inter[..., None] * jnp.einsum("bhtd,bhde->bhte", qc.astype(jnp.float32) * scale, C)
        den = scores.sum(-1) + inter * jnp.einsum("bhtd,bhd->bht",
                                                  qc.astype(jnp.float32) * scale, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # chunk-final state update
        F_tot = F[..., -1]                                 # [b,h]
        lg_state = F_tot[..., None] - F + li               # decay each s to chunk end
        m_next = jnp.maximum(F_tot + m, lg_state.max(-1))
        w_s = jnp.exp(lg_state - m_next[..., None])        # [b,h,c]
        C_next = jnp.exp(F_tot + m - m_next)[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_s, kc.astype(jnp.float32), vc.astype(jnp.float32))
        n_next = jnp.exp(F_tot + m - m_next)[..., None] * n + jnp.einsum(
            "bhs,bhsd->bhd", w_s, kc.astype(jnp.float32))
        return (C_next, n_next, m_next), h

    C0 = jnp.zeros((b, n_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, n_heads, hd), jnp.float32)
    m0 = jnp.zeros((b, n_heads), jnp.float32)
    # repro: allow-raw(inter-chunk state recurrence is sequential by construction; the in-chunk compute above is the tunable site)
    (CN, nN, mN), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qs, ks_, vs, lis, lfs))
    h = hs.swapaxes(1, 2).swapaxes(0, 2).reshape(b, n_heads, sp, hd)[:, :, :s]
    h = h.swapaxes(1, 2).reshape(b, s, di)
    # per-head group norm (rms) then gate + down-proj
    hn = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + 1e-6)
    hn = (hn * p["norm_scale"]).astype(jnp.float32)
    out = dispatch(
        "matmul", hn * jax.nn.silu(z.astype(jnp.float32)),
        p["out_proj"].astype(jnp.float32),
    ).astype(x.dtype)
    if not return_state:
        return out
    return out, {"C": CN, "n": nN, "m": mN}


def mlstm_state_spec(batch: int, d: int, n_heads: int, expand: int = 2):
    di = expand * d
    hd = di // n_heads
    return {
        "C": jax.ShapeDtypeStruct((batch, n_heads, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, n_heads, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, n_heads), jnp.float32),
    }


def mlstm_decode(p: Params, x: jax.Array, state, *, n_heads: int):
    b = x.shape[0]
    di = p["wq"].shape[0]
    hd = di // n_heads
    q, k, v, z, log_i, log_f = _mlstm_qkvg(p, x, n_heads)      # seq len 1
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]               # [b,h,hd]
    li, lf = log_i[..., 0], log_f[..., 0]                      # [b,h]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    f_s = jnp.exp(lf + m - m_new)
    i_s = jnp.exp(li - m_new)
    # repro: allow-raw(decode-step rank-1 state update — [b,h,hd,hd] outer product, bandwidth-bound with no tile knobs)
    C = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = f_s[..., None] * n + i_s[..., None] * k.astype(jnp.float32)
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    # repro: allow-raw(decode-step state readout — [b,h,hd] contractions, too small to tile)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))  # repro: allow-raw(decode-step state readout — [b,h,hd] contractions, too small to tile)
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = h.reshape(b, 1, di)
    hn = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + 1e-6)
    hn = (hn * p["norm_scale"]).astype(jnp.float32)
    out = dispatch(
        "matmul", hn * jax.nn.silu(z.astype(jnp.float32)),
        p["out_proj"].astype(jnp.float32),
    ).astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM (scalar-memory LSTM with exp gating) — sequential recurrence
# ===========================================================================


def slstm_init(rng, d: int, n_heads: int, dtype) -> Tuple[Params, Axes]:
    hd = d // n_heads
    ks = jax.random.split(rng, 5)
    ff = ((4 * d // 3 + 63) // 64) * 64
    p: Params = {
        "w": _init(ks[0], (d, 4 * d), dtype),
        "r": _init(ks[1], (n_heads, hd, 4 * hd), dtype, scale=1.0 / math.sqrt(hd)),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(jnp.float32),  # order: z, i, f, o
        "up_g": _init(ks[2], (d, ff), dtype),
        "up_u": _init(ks[3], (d, ff), dtype),
        "down": _init(ks[4], (ff, d), dtype, scale=1.0 / math.sqrt(ff)),
    }
    a: Axes = {
        "w": ("d_model", "heads"),
        "r": ("heads_small", "hd", "hd4"),
        "b": ("heads",),
        "up_g": ("d_model", "ff"), "up_u": ("d_model", "ff"),
        "down": ("ff", "d_model"),
    }
    return p, a


def _slstm_cell(p, xw, state, n_heads):
    """One step. xw: [b, 4d] pre-computed x@w. state: dict of [b, d]."""
    b = xw.shape[0]
    d = state["h"].shape[-1]
    hd = d // n_heads
    hr = state["h"].reshape(b, n_heads, hd)
    # repro: allow-raw(per-step block-diagonal recurrent gemm carries h — sequential dependence keeps it inside the scan body)
    rec = jnp.einsum("bnh,nhk->bnk", hr.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    zf, if_, ff_, of_ = jnp.split(xw + rec + p["b"], 4, axis=-1)
    z = jnp.tanh(zf)
    o = jax.nn.sigmoid(of_)
    log_i = if_
    log_f = jax.nn.log_sigmoid(ff_)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c = f_s * state["c"] + i_s * z
    n = f_s * state["n"] + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def _slstm_mlp(p: Params, h: jax.Array) -> jax.Array:
    """Post-cell GeGLU MLP (pf=4/3); all three gemms are dispatch sites."""
    g = jax.nn.gelu(dispatch("matmul", h, p["up_g"])) * dispatch("matmul", h, p["up_u"])
    return dispatch("matmul", g, p["down"])


def slstm_forward(p: Params, x: jax.Array, *, n_heads: int, unroll: int = 1,
                  return_state: bool = False):
    b, s, d = x.shape
    xw = dispatch("matmul", x, p["w"]).astype(jnp.float32)      # [b,s,4d]
    state0 = {
        "c": jnp.zeros((b, d), jnp.float32),
        "n": jnp.zeros((b, d), jnp.float32),
        "h": jnp.zeros((b, d), jnp.float32),
        "m": jnp.zeros((b, d), jnp.float32),
    }

    def step(state, xw_t):
        new = _slstm_cell(p, xw_t, state, n_heads)
        return new, new["h"]

    # repro: allow-raw(scalar-memory LSTM recurrence is inherently sequential; the x@w and MLP gemms around it are dispatch sites)
    stateN, hs = jax.lax.scan(step, state0, xw.swapaxes(0, 1), unroll=unroll)
    h = hs.swapaxes(0, 1).astype(x.dtype)                       # [b,s,d]
    # post-MLP (GeGLU, pf=4/3)
    y = _slstm_mlp(p, h)
    if not return_state:
        return y
    return y, stateN


def slstm_state_spec(batch: int, d: int):
    return {k: jax.ShapeDtypeStruct((batch, d), jnp.float32) for k in ("c", "n", "h", "m")}


def slstm_decode(p: Params, x: jax.Array, state, *, n_heads: int):
    b = x.shape[0]
    xw = dispatch("matmul", x[:, 0], p["w"]).astype(jnp.float32)
    new = _slstm_cell(p, xw, state, n_heads)
    h = new["h"].astype(x.dtype)[:, None]
    y = _slstm_mlp(p, h)
    return y, new
