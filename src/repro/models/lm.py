"""Top-level language model: embed → segment stack → norm → chunked loss.

Public surface (all pure functions over explicit pytrees):
    init_params(rng, cfg)        -> (params, axes)       # real arrays
    abstract_params(cfg)         -> (specs, axes)        # ShapeDtypeStructs
    forward(params, batch, ...)  -> (hidden, aux)
    loss_fn(params, batch, ...)  -> (loss, metrics)      # seq-chunked vocab
    prefill(params, batch, ...)  -> (last_logits, caches)
    decode_step(params, tokens, caches, pos, ...) -> (logits, caches)

The loss never materializes [batch, seq, vocab]: logits are produced and
consumed per sequence chunk inside a scan (loss_chunk tunable). For the
262k-vocab archs this is the difference between a 0.5 PB activation and a
few hundred MB.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.runtime import dispatch
from . import transformer as tf
from .layers import (
    embed,
    embedding_init,
    norm_init,
    rmsnorm,
    rmsnorm_dense,
    unembed,
    unembed_init,
)

Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = embedding_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.jdtype)
    seg_p, seg_a = [], []
    for i, seg in enumerate(cfg.segments()):
        sp, sa = tf.segment_init(jax.random.fold_in(ks[1], i), cfg, seg)
        seg_p.append(sp)
        seg_a.append(sa)
    p["segments"], a["segments"] = tuple(seg_p), tuple(seg_a)
    p["final_norm"], a["final_norm"] = norm_init(cfg.d_model, cfg.jdtype)
    p["lm_head"], a["lm_head"] = unembed_init(ks[2], cfg.d_model, cfg.vocab_size, cfg.jdtype)
    return p, a


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct params + logical-axis tree, no device allocation.

    The axes tree is plain Python (strings), which eval_shape cannot return
    as an output — capture it by side effect during tracing instead.
    """
    box = {}

    def build():
        p, a = init_params(jax.random.PRNGKey(0), cfg)
        box["axes"] = a
        return p

    specs = jax.eval_shape(build)
    return specs, box["axes"]


def param_axes(cfg: ArchConfig):
    """Logical-axis tree without touching device memory."""
    return abstract_params(cfg)[1]


def param_count(cfg: ArchConfig) -> int:
    import math

    p, _ = abstract_params(cfg)
    return sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(p))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k of num_experts expert params)."""
    import math

    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    p, _ = abstract_params(cfg)
    expert = 0

    def walk(t):
        nonlocal expert
        if isinstance(t, dict):
            for k, v in t.items():
                if k == "moe":
                    for kk, vv in v.items():
                        if kk != "router":
                            expert += sum(
                                math.prod(x.shape)
                                for x in jax.tree_util.tree_leaves(vv)
                            )
                else:
                    walk(v)
        elif isinstance(t, (tuple, list)):
            for v in t:
                walk(v)

    walk(p)
    active_frac = cfg.experts_per_token / cfg.num_experts
    return int(total - expert * (1 - active_frac))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: Batch, cfg: ArchConfig):
    if cfg.frontend == "audio_frames":
        return batch["embeds"].astype(cfg.jdtype)
    if cfg.frontend == "vision_patches":
        tok = embed(params["embed"], batch["tokens"])
        return jnp.concatenate([batch["embeds"].astype(tok.dtype), tok], axis=1)
    return embed(params["embed"], batch["tokens"])


def forward(params, batch: Batch, cfg: ArchConfig, run: tf.RunConfig,
            mode: str = "train", cache_len: Optional[int] = None,
            true_len=None):
    x = _embed_inputs(params, batch, cfg)
    x, aux, caches = tf.stack_apply(
        params["segments"], x, cfg, run, mode, cache_len=cache_len,
        true_len=true_len,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caches


def _chunked_xent(lm_head, x, labels, mask, loss_chunk: int):
    """Mean xent over valid tokens; scan over seq chunks of the vocab matmul."""
    b, s, d = x.shape
    chunk = min(loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n_chunks = x.shape[1] // chunk
    xc = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        xx, ll, mm = inp
        logits = unembed(lm_head, xx.reshape(b * chunk, d))
        losses = dispatch("softmax_xent", logits, ll.reshape(-1))
        tot = tot + jnp.sum(losses * mm.reshape(-1))
        cnt = cnt + jnp.sum(mm)
        return (tot, cnt), None

    # repro: allow-raw(loss chunking loop — loss_chunk is the xent_chunk registry knob; the vocab matmul and xent inside are dispatch sites)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc, mc)
    )
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch: Batch, cfg: ArchConfig, run: tf.RunConfig,
            aux_weight: float = 0.01):
    x, aux, _ = forward(params, batch, cfg, run, mode="train")
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    xent = _chunked_xent(params["lm_head"], x, labels, mask, run.loss_chunk)
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, batch: Batch, cfg: ArchConfig, run: tf.RunConfig,
            cache_len: Optional[int] = None, true_len=None):
    """Full-sequence forward emitting caches + logits of the last position.

    `true_len` (scalar, may be traced) enables bucketed prefill: the batch is
    right-padded to a shape bucket, logits are read at position
    ``true_len - 1`` and window caches are ring-aligned to `true_len` so
    decode continues at absolute position `true_len`. Causality keeps the
    pad tokens out of every real position's output.
    """
    seq = (batch["embeds"].shape[1] if "tokens" not in batch else batch["tokens"].shape[1])
    if cfg.frontend == "vision_patches":
        seq = batch["embeds"].shape[1] + batch["tokens"].shape[1]
    x, _, caches = forward(
        params, batch, cfg, run, mode="prefill", cache_len=cache_len or seq,
        true_len=true_len,
    )
    last = x[:, -1] if true_len is None else jnp.take(x, true_len - 1, axis=1)
    logits = unembed(params["lm_head"], last)
    return logits, caches


def decode_step(params, tokens, caches, pos, cfg: ArchConfig, run: tf.RunConfig):
    """tokens: [b, 1] int32; pos: scalar or [b] absolute position(s).

    A vector `pos` decodes each batch row at its own absolute position —
    the slot-pool serving engine's contract, where every row is an
    independent in-flight request. Returns (logits, caches).
    """
    x = embed(params["embed"], tokens)
    x, _, caches = tf.stack_apply(
        params["segments"], x, cfg, run, mode="decode", caches=caches, pos=pos
    )
    # Final-norm → unembed is the rmsnorm_matmul fusion site (rmsnorm is
    # row-wise, so norm-then-slice == slice-then-norm on the single decode
    # position).
    logits = rmsnorm_dense(params["final_norm"], params["lm_head"], x[:, 0],
                           cfg.norm_eps)
    return logits, caches


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int):
    return tf.cache_specs(cfg, batch, cache_len)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    """Zero-filled cache pytree for a `batch`-slot decode pool."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, cache_len)
    )


def insert_cache(pool, new, slot):
    """Overwrite slot `slot`'s cache region with a freshly prefilled cache.

    Cache leaves are stacked (repeats, batch, ...) — batch is axis 1. `new`
    comes from a batch-1 prefill at the same cache_len; the write covers the
    slot's entire region, so nothing from the previous occupant survives.
    """
    return jax.tree_util.tree_map(
        lambda a, b: jax.lax.dynamic_update_slice_in_dim(
            a, b.astype(a.dtype), slot, axis=1
        ),
        pool, new,
    )
