"""Mixture-of-Experts: top-k routing with capacity, scatter-based dispatch.

Dispatch formulation matters enormously at scale, so it is a *tunable*:

  * ``scatter`` (default, production path): tokens are placed into a dense
    [experts, capacity, d] buffer via scatter, experts run one grouped
    einsum, results gather back. Memory/FLOPs scale with tokens·top_k, never
    with tokens·experts. With the expert dim sharded over the "model" mesh
    axis, XLA lowers the scatter/gather to the expert-parallel all-to-all —
    the paper's "collective schedule" knob emerges from layout choice.
  * ``dense`` (oracle path): every expert runs every token, combine weights
    zero out non-selected experts. O(tokens·experts) FLOPs — exact same
    math, used as the correctness reference and for tiny smoke configs.

Arctic's dense-MoE hybrid (residual dense FFN in parallel with the MoE) is a
config flag handled in transformer.py, not here.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.runtime import dispatch as rt_dispatch
from .layers import Axes, Params, _init

DispatchMode = str  # "scatter" | "dense"


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    """Expert buffer depth for the scatter path.

    Derived from the *global* (traced, unsharded) token count so the shape is
    static under jit. The campaign planner imports this to key expert_gemm
    tuning jobs on the exact (experts, capacity, hidden) the model will trace.
    """
    return int(max(top_k, capacity_factor * n_tokens * top_k / n_experts))


def _valid_mask(true_len, b: int, s: int) -> Optional[jax.Array]:
    """[b, s] bool validity mask from a scalar or per-row ``true_len``."""
    if true_len is None:
        return None
    tl = jnp.asarray(true_len)
    if tl.ndim == 0:
        tl = jnp.broadcast_to(tl, (b,))
    return jnp.arange(s)[None, :] < tl[:, None]


def moe_init(
    rng, d: int, ff: int, n_experts: int, dtype, ffn_kind: str = "swiglu"
) -> Tuple[Params, Axes]:
    ks = jax.random.split(rng, 4)
    p: Params = {
        "router": _init(ks[0], (d, n_experts), jnp.float32, scale=0.02),
        "wg": _init(ks[1], (n_experts, d, ff), dtype),
        "wu": _init(ks[2], (n_experts, d, ff), dtype),
        "wd": _init(ks[3], (n_experts, ff, d), dtype),
    }
    a: Axes = {
        "router": ("d_model", "experts_r"),  # router stays replicated
        "wg": ("experts", "d_model", "ff"),
        "wu": ("experts", "d_model", "ff"),
        "wd": ("experts", "ff", "d_model"),
    }
    if ffn_kind in ("gelu", "relu2"):
        del p["wg"], a["wg"]
    return p, a


def _expert_ffn(p: Params, x: jax.Array, ffn_kind: str) -> jax.Array:
    """x: [e, c, d] -> [e, c, d], grouped over the expert dim.

    All three expert contractions are ``expert_gemm`` dispatch sites keyed on
    (experts × capacity × hidden) — the tuned runtime resolves them instead
    of XLA's default grouped-einsum lowering.
    """
    if "wg" in p:
        act = jax.nn.silu if ffn_kind == "swiglu" else jax.nn.gelu
        h = act(rt_dispatch("expert_gemm", x, p["wg"])) * rt_dispatch(
            "expert_gemm", x, p["wu"]
        )
    else:
        h = jax.nn.gelu(rt_dispatch("expert_gemm", x, p["wu"]))
    return rt_dispatch("expert_gemm", h, p["wd"])


def _route(router_w, x2, top_k: int, valid: Optional[jax.Array] = None):
    """x2: [n, d] -> (weights [n, k] fp32, ids [n, k] int32, aux_loss).

    ``valid`` ([n] bool, optional) marks real tokens. Padding tokens get zero
    combine weight and are excluded from both factors of the load-balancing
    loss — otherwise pad routing skews ``ce`` toward whatever expert wins on
    the zero vector and the aux loss changes with batch padding.
    """
    # Router projection stays a plain jnp matmul: [n, d] @ [d, e] with e a
    # handful of experts is far below the tuned-gemm tile floor.
    # repro: allow-raw(router projection is [n, d] @ [d, e] with e a handful of experts — below the tuned-gemm tile floor)
    logits = x2.astype(jnp.float32) @ router_w          # [n, e]
    probs = jax.nn.softmax(logits, axis=-1)  # repro: allow-raw(router softmax over e experts — the fused kernel tiles vocab-scale axes, not e)
    weights, ids = jax.lax.top_k(probs, top_k)          # [n, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss.
    n, e = probs.shape
    one_hot = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    if valid is None:
        me = probs.mean(0)                               # mean prob per expert
        ce = one_hot.mean(0)                             # fraction routed (top-1)
    else:
        vf = valid.astype(jnp.float32)[:, None]          # [n, 1]
        denom = jnp.maximum(vf.sum(), 1.0)
        me = (probs * vf).sum(0) / denom
        ce = (one_hot * vf).sum(0) / denom
        weights = weights * vf.astype(weights.dtype)
    aux = e * jnp.sum(me * ce)
    return weights, ids, aux


def moe_apply(
    p: Params,
    x: jax.Array,                 # [b, s, d]
    *,
    top_k: int,
    ffn_kind: str = "swiglu",
    capacity_factor: float = 1.25,
    dispatch: DispatchMode = "scatter",
    true_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [b, s, d], aux_loss scalar).

    ``true_len`` (scalar or [b] int, optional): number of real tokens per
    row. Padding tokens beyond it are excluded from routing — they consume
    no expert capacity, contribute nothing to the aux loss, and produce zero
    output. Without the mask, batch-major flattening lets one row's padding
    claim capacity ahead of a later row's *real* tokens, silently dropping
    them and corrupting both output and load-balancing gradients.
    """
    b, s, d = x.shape
    n = b * s
    x2 = x.reshape(n, d)
    e = p["wu"].shape[0]
    mask = _valid_mask(true_len, b, s)
    valid = None if mask is None else mask.reshape(n)
    weights, ids, aux = _route(p["router"], x2, top_k, valid=valid)

    if dispatch == "dense":
        # Oracle: every expert sees every token. [e, n, d] compute.
        outs = _expert_ffn(p, jnp.broadcast_to(x2[None], (e, n, d)), ffn_kind)
        combine = jnp.zeros((n, e), jnp.float32)
        combine = combine.at[jnp.arange(n)[:, None], ids].add(weights)
        # repro: allow-raw(dense oracle path — correctness baseline for the scatter dispatch, never the serving path)
        y = jnp.einsum("ne,end->nd", combine, outs.astype(jnp.float32))
        return y.reshape(b, s, d).astype(x.dtype), aux

    # --- scatter dispatch --------------------------------------------------
    from ..distributed.sharding import constrain

    cap = expert_capacity(n, e, top_k, capacity_factor)
    # position of each (token, slot) within its expert's buffer
    flat_ids = ids.reshape(-1)                             # [n*k]
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # [n*k, e]
    if valid is not None:
        # Padding slots must not advance the running count: a masked token
        # contributes no occupancy, so real tokens later in the flat order
        # keep their capacity.
        flat_valid = jnp.repeat(valid, top_k)              # [n*k]
        onehot = onehot * flat_valid[:, None].astype(jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1              # running count
    pos = jnp.take_along_axis(pos_in_e, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < cap                                       # dropped if over capacity
    if valid is not None:
        keep = keep & flat_valid
    slot = flat_ids * cap + jnp.where(keep, pos, 0)        # [n*k]

    xk = jnp.repeat(x2, top_k, axis=0)                     # [n*k, d]
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xk, 0))
    # Sharding hints: pin the dispatch buffer to the expert-parallel layout
    # (expert dim on "model") and the token side to the data axes. Without
    # these, GSPMD resolves the cross-layout scatter by replicating the full
    # token tensor (its "involuntary full rematerialization" warning) — the
    # dominant collective cost in the arctic/mixtral baselines.
    if dispatch == "scatter_hinted":
        expert_in = constrain(buf.reshape(e, cap, d), "model", None, None)
    else:
        expert_in = buf.reshape(e, cap, d)
    expert_out = _expert_ffn(p, expert_in, ffn_kind)
    if dispatch == "scatter_hinted":
        expert_out = constrain(expert_out, "model", None, None)
    gathered = expert_out.reshape(e * cap, d)[slot]        # [n*k, d]
    wk = (weights.reshape(-1) * keep).astype(jnp.float32)
    y = (gathered.astype(jnp.float32) * wk[:, None]).reshape(n, top_k, d).sum(1)
    return y.reshape(b, s, d).astype(x.dtype), aux
