"""Attention layers: GQA with RoPE, full/SWA/local-global kinds, KV caches.

Two lowering paths, same math:
  * `dispatch("flash_attention")` — the Pallas kernel through the runtime
    (CPU interpret / TPU runtime);
  * `chunked_attention` — pure-XLA online-softmax over K/V chunks, used by
    the multi-pod dry-run (Pallas cannot lower to TPU from this host) and as
    the reference semantics. Chunking bounds the live score block to
    [q_chunk, k_chunk] so 32k-token prefill never materializes an s×s matrix.

Cache discipline:
  * full attention: ring-less cache [b, s_max, kv, hd], write at `pos`;
  * SWA/local layers: **rolling window cache** [b, window, kv, hd], write at
    `pos % window` — this is what keeps gemma3-27b decode_32k at ~0.4 TB
    instead of 2.1 TB (52 of its 62 layers are local).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import runtime as rt
from .layers import Axes, Params, apply_rope, dense, dense_init

NEG_INF = -1e30


def attention_init(
    rng,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    dtype,
    qkv_bias: bool = False,
) -> Tuple[Params, Axes]:
    ks = jax.random.split(rng, 4)
    pq, aq = dense_init(ks[0], d_model, n_heads * head_dim, dtype, "d_model", "heads", qkv_bias)
    pk, ak = dense_init(ks[1], d_model, n_kv * head_dim, dtype, "d_model", "kv_heads", qkv_bias)
    pv, av = dense_init(ks[2], d_model, n_kv * head_dim, dtype, "d_model", "kv_heads", qkv_bias)
    po, ao = dense_init(ks[3], n_heads * head_dim, d_model, dtype, "heads", "d_model")
    return (
        {"q": pq, "k": pk, "v": pv, "o": po},
        {"q": aq, "k": ak, "v": av, "o": ao},
    )


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure XLA; flash-equivalent math)
# ---------------------------------------------------------------------------


# repro: allow-raw(this IS the attn_chunks tunable body — the pure-XLA flash-equivalent reference; its q/k chunk sizes are the registry knobs)
def chunked_attention(
    q: jax.Array,        # [b, h, s_q, d]
    k: jax.Array,        # [b, kv, s_k, d]
    v: jax.Array,        # [b, kv, s_k, d]
    *,
    causal: bool,
    window: int = 0,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    kv_valid_len: Optional[jax.Array] = None,  # scalar or [b]: mask cache positions >= this
) -> jax.Array:
    b, h, s_q, d = q.shape
    _, kv, s_k, _ = k.shape
    group = h // kv
    scale = scale if scale is not None else d ** -0.5
    q_chunk = min(q_chunk, s_q)
    k_chunk = min(k_chunk, s_k)
    # pad to chunk multiples
    pq = (-s_q) % q_chunk
    pk = (-s_k) % k_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sq_p, sk_p = q.shape[2], k.shape[2]
    n_q, n_k = sq_p // q_chunk, sk_p // k_chunk
    q_off = s_k - s_q  # decode/suffix alignment: q occupies the end of k axis

    # [b, kv, g, sq, d] view so kv-head grouping is einsum-native (no repeat)
    qg = q.reshape(b, kv, group, sq_p, d)

    def one_q_chunk(qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=3)
        q_ids = qi * q_chunk + jnp.arange(q_chunk) + q_off

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, axis=2)
            s = jnp.einsum(
                "bkgqd,bkcd->bkgqc", qc.astype(jnp.float32), kc.astype(jnp.float32)
            ) * scale
            k_ids = ki * k_chunk + jnp.arange(k_chunk)
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= q_ids[:, None] >= k_ids[None, :]
            if window > 0:
                mask &= (q_ids[:, None] - k_ids[None, :]) < window
            mask &= (k_ids < s_k)[None, :]
            full = mask[None, None, None]            # [1, 1, 1, q, k]
            if kv_valid_len is not None:
                vl = jnp.asarray(kv_valid_len)
                if vl.ndim == 0:
                    full = full & (k_ids[None, :] < vl)[None, None, None]
                else:
                    # per-sequence valid length (slot-pool decode: each slot
                    # sits at its own absolute position)
                    full = full & (k_ids[None, :] < vl[:, None])[:, None, None, None, :]
            s = jnp.where(full, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kv, group, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, kv, group, q_chunk), jnp.float32),
            jnp.zeros((b, kv, group, q_chunk, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(n_k))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if n_q == 1:
        out = one_q_chunk(0)
    else:
        out = jax.lax.map(one_q_chunk, jnp.arange(n_q))  # [nq, b, kv, g, qc, d]
        out = jnp.moveaxis(out, 0, 3).reshape(b, kv, group, sq_p, d)
    out = out.reshape(b, h, sq_p, d)[:, :, :s_q]
    return out.astype(q.dtype)


def _attend(q, k, v, *, causal, window, use_kernel, kv_valid_len=None,
            q_chunk=512, k_chunk=1024):
    if use_kernel and rt.current_runtime().kernel_mode_active and kv_valid_len is None:
        return rt.dispatch("flash_attention", q, k, v, causal=causal, window=window)
    return chunked_attention(
        q, k, v, causal=causal, window=window,
        q_chunk=q_chunk, k_chunk=k_chunk, kv_valid_len=kv_valid_len,
    )


# ---------------------------------------------------------------------------
# Layer application: train/prefill (full sequence) and decode (one token)
# ---------------------------------------------------------------------------


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def attention_forward(
    p: Params,
    x: jax.Array,               # [b, s, d_model]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,            # 0 = full; >0 = sliding window
    positions: Optional[jax.Array] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    return_cache: bool = False,
    cache_len: Optional[int] = None,   # prefill: allocate cache of this length
    true_len: Optional[jax.Array] = None,  # prefill: real prompt length (s may be right-padded)
):
    """Training / prefill forward. Returns y or (y, cache).

    `true_len` supports bucketed (right-padded) prefill: the input holds
    `true_len` real tokens followed by pads. Causality already keeps pads out
    of real positions' outputs; `true_len` additionally makes the *rolling
    window cache* ring-consistent — slots are filled from real positions
    (pos % clen alignment at `true_len`), so decode can continue at absolute
    position `true_len`. Full caches need no change: rows are positions, and
    decode masks rows >= its own position.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q = _split_heads(dense(p["q"], x), n_heads, head_dim)
    k = _split_heads(dense(p["k"], x), n_kv, head_dim)
    v = _split_heads(dense(p["v"], x), n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    # [b, heads, s, hd] layout for the kernels. The constrain_heads anchors
    # tell the SPMD partitioner how the head dim is laid out on both sides
    # of the split/merge reshapes — without them the sharded train step
    # pays an involuntary full rematerialization of q/k/v around the
    # flash-attention dispatch (and its backward).
    from ..distributed import sharding as shd

    qh, kh, vh = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    qh = shd.constrain_heads(qh, n_heads, 1)
    kh = shd.constrain_heads(kh, n_kv, 1)
    vh = shd.constrain_heads(vh, n_kv, 1)
    y = _attend(qh, kh, vh, causal=True, window=window, use_kernel=True,
                q_chunk=q_chunk, k_chunk=k_chunk)
    y = shd.constrain_heads(y, n_heads, 1)
    y = jnp.swapaxes(y, 1, 2).reshape(b, s, n_heads * head_dim)
    y = shd.constrain_heads(y, n_heads, 2)
    out = dense(p["o"], y)
    if not return_cache:
        return out
    clen = cache_len or s
    if window > 0:
        clen = min(clen, window)
        if true_len is not None:
            # Ring slots from *real* positions: slot j holds the largest
            # position p < true_len with p % clen == j (junk for p < 0 is
            # zeroed; decode masks unwritten slots anyway).
            last = jnp.asarray(true_len) - 1
            j = jnp.arange(clen)
            pidx = last - jnp.mod(last - j, clen)
            ok = (pidx >= 0)[None, :, None, None]
            pc = jnp.clip(pidx, 0, s - 1)
            k_tail = jnp.where(ok, jnp.take(k, pc, axis=1), 0).astype(k.dtype)
            v_tail = jnp.where(ok, jnp.take(v, pc, axis=1), 0).astype(v.dtype)
            return out, {"k": k_tail, "v": v_tail}
        if s >= clen:
            # keep the last `clen` positions, rolled so slot = pos % clen
            k_tail = jnp.roll(k[:, -clen:], s % clen, axis=1)
            v_tail = jnp.roll(v[:, -clen:], s % clen, axis=1)
        else:
            # fewer tokens than the window: slots 0..s-1 = positions 0..s-1
            pad = clen - s
            k_tail = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_tail = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": k_tail, "v": v_tail}
    else:
        pad = clen - s
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        }
    return out, cache


def attention_cache_spec(
    batch: int, cache_len: int, n_kv: int, head_dim: int, window: int, dtype
) -> Dict[str, jax.ShapeDtypeStruct]:
    clen = min(cache_len, window) if window > 0 else cache_len
    shp = (batch, clen, n_kv, head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shp, dtype),
        "v": jax.ShapeDtypeStruct(shp, dtype),
    }


def attention_decode(
    p: Params,
    x: jax.Array,               # [b, 1, d_model]
    cache: Dict[str, jax.Array],
    pos: jax.Array,             # int32 scalar or [b]: absolute position per sequence
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
    k_chunk: int = 1024,
):
    """One-token decode against a cache. Returns (y, new_cache).

    `pos` may be a vector: in the slot-pool serving engine every cache row is
    an independent sequence at its own absolute position, so RoPE, the cache
    write slot, and the validity mask are all per-row.
    """
    b = x.shape[0]
    q = _split_heads(dense(p["q"], x), n_heads, head_dim)
    k = _split_heads(dense(p["k"], x), n_kv, head_dim)
    v = _split_heads(dense(p["v"], x), n_kv, head_dim)
    posv = jnp.broadcast_to(jnp.asarray(pos), (b,))            # [b]
    q = apply_rope(q, posv[:, None], rope_theta)
    k = apply_rope(k, posv[:, None], rope_theta)

    clen = cache["k"].shape[1]
    slot = jnp.mod(posv, clen) if window > 0 else posv          # [b]
    # per-row one-hot write (each sequence writes its own slot)
    hit = (jnp.arange(clen)[None, :] == slot[:, None])[:, :, None, None]
    ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])

    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(ck, 1, 2)
    vh = jnp.swapaxes(cv, 1, 2)
    # repro: allow-raw(single-token decode over the rolling window cache — [b,h,1,window] scores are cache-layout-bound, below any kernel tile floor)
    if window > 0:
        # Rolling cache: every slot is within the window by construction;
        # mask only the slots not yet written (pos < window), per row.
        valid = jnp.arange(clen)[None, :] <= posv[:, None]      # [b, clen]
        s = jnp.einsum(
            "bkgqd,bkcd->bkgqc",
            qh.reshape(b, n_kv, n_heads // n_kv, 1, head_dim).astype(jnp.float32),
            kh.astype(jnp.float32),
        ) * (head_dim ** -0.5)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum("bkgqc,bkcd->bkgqd", pattn, vh.astype(jnp.float32))
        y = y.reshape(b, n_heads, 1, head_dim).astype(x.dtype)
    else:
        y = chunked_attention(
            qh, kh, vh, causal=False, k_chunk=k_chunk,
            kv_valid_len=posv + 1,
        )
    y = jnp.swapaxes(y, 1, 2).reshape(b, 1, n_heads * head_dim)
    return dense(p["o"], y), {"k": ck, "v": cv}
