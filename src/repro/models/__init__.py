"""Model substrate: layers, attention, MoE, SSM mixers, transformer stack, LM."""
from . import attention, layers, lm, moe, ssm, transformer
from .transformer import RunConfig
