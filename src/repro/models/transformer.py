"""Block assembly: LayerSpec → layer params/apply, Segment → lax.scan stacks.

Heterogeneous layer patterns (gemma3 5:1 local:global, jamba 1-attn:7-mamba
with alternating MoE, xlstm mLSTM/sLSTM) are handled by scanning over
*super-blocks*: the pattern is unrolled inside the scan body, the repeats are
the scan axis. This keeps compiled HLO size O(pattern) instead of O(layers) —
the difference between compiling 40 dry-run cells in minutes vs hours.

Three modes share one code path:
    train   — full-sequence forward, no caches
    prefill — full-sequence forward, emits per-layer caches (scan ys)
    decode  — one-token forward, consumes + re-emits caches (scan xs/ys)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec, Segment
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import Axes, Params, ffn_apply, ffn_init, norm_init, rmsnorm


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime/layout knobs — the model-level tunable surface.

    These do not change math; they change chunking, remat and dispatch.
    The layout autotuner searches over a subset of them (see
    distributed/layout_space.py).
    """

    remat: str = "dots"          # none | dots | full
    q_chunk: int = 512
    k_chunk: int = 1024
    mamba_chunk: int = 32
    mlstm_chunk: int = 64
    loss_chunk: int = 512
    slstm_unroll: int = 1
    moe_dispatch: str = "scatter"   # scatter | dense
    microbatches: int = 1           # gradient-accumulation steps
    grad_compression: str = "none"  # none | bf16 (wire format of grad reduce)


# ---------------------------------------------------------------------------
# Layer init
# ---------------------------------------------------------------------------


def layer_init(rng, cfg: ArchConfig, spec: LayerSpec) -> Tuple[Params, Axes]:
    ks = jax.random.split(rng, 6)
    dt = cfg.jdtype
    p: Params = {}
    a: Axes = {}
    p["norm1"], a["norm1"] = norm_init(cfg.d_model, dt)

    if spec.mixer == "attn":
        p["mixer"], a["mixer"] = attn.attention_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, dt,
            qkv_bias=cfg.qkv_bias,
        )
    elif spec.mixer == "mamba":
        p["mixer"], a["mixer"] = ssm.mamba_init(
            ks[0], cfg.d_model, dt, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state,
        )
    elif spec.mixer == "mlstm":
        p["mixer"], a["mixer"] = ssm.mlstm_init(ks[0], cfg.d_model, cfg.num_heads, dt)
    elif spec.mixer == "slstm":
        p["mixer"], a["mixer"] = ssm.slstm_init(ks[0], cfg.d_model, cfg.num_heads, dt)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != "none":
        p["norm2"], a["norm2"] = norm_init(cfg.d_model, dt)
        if "moe" in spec.ffn:
            p["moe"], a["moe"] = moe_mod.moe_init(
                ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, dt, cfg.ffn_kind
            )
        if spec.ffn in ("dense", "moe+dense"):
            p["ffn"], a["ffn"] = ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt)
    return p, a


def superblock_init(rng, cfg: ArchConfig, pattern) -> Tuple[Params, Axes]:
    p, a = {}, {}
    for i, spec in enumerate(pattern):
        p[f"l{i}"], a[f"l{i}"] = layer_init(jax.random.fold_in(rng, i), cfg, spec)
    return p, a


def segment_init(rng, cfg: ArchConfig, seg: Segment) -> Tuple[Params, Axes]:
    """Stack `repeats` super-blocks along a leading scan axis."""
    blocks = []
    a0 = None
    for r in range(seg.repeats):
        bp, a0 = superblock_init(jax.random.fold_in(rng, r), cfg, seg.pattern)
        blocks.append(bp)
    p = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    a = jax.tree_util.tree_map(
        lambda ax: ("layers",) + tuple(ax),
        a0,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(s, str) for s in x),
    )
    return p, a


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------


def _mixer_apply(p, x, spec: LayerSpec, cfg: ArchConfig, run: RunConfig,
                 mode: str, cache, pos, true_len=None):
    kw = {}
    if spec.mixer == "attn":
        common = dict(
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, window=spec.window,
        )
        if mode == "train":
            return attn.attention_forward(
                p, x, q_chunk=run.q_chunk, k_chunk=run.k_chunk, **common
            ), None
        if mode == "prefill":
            y, c = attn.attention_forward(
                p, x, q_chunk=run.q_chunk, k_chunk=run.k_chunk,
                return_cache=True, cache_len=cache, true_len=true_len, **common
            )
            return y, c
        return attn.attention_decode(p, x, cache, pos, k_chunk=run.k_chunk, **common)

    if spec.mixer == "mamba":
        if mode == "train":
            return ssm.mamba_forward(p, x), None
        if mode == "prefill":
            return ssm.mamba_forward(p, x, return_state=True)
        return ssm.mamba_decode(p, x, cache)

    if spec.mixer == "mlstm":
        if mode == "train":
            return ssm.mlstm_forward(p, x, n_heads=cfg.num_heads, chunk=run.mlstm_chunk), None
        if mode == "prefill":
            return ssm.mlstm_forward(
                p, x, n_heads=cfg.num_heads, chunk=run.mlstm_chunk, return_state=True
            )
        return ssm.mlstm_decode(p, x, cache, n_heads=cfg.num_heads)

    if spec.mixer == "slstm":
        if mode == "train":
            return ssm.slstm_forward(p, x, n_heads=cfg.num_heads, unroll=run.slstm_unroll), None
        if mode == "prefill":
            return ssm.slstm_forward(
                p, x, n_heads=cfg.num_heads, unroll=run.slstm_unroll, return_state=True
            )
        return ssm.slstm_decode(p, x, cache, n_heads=cfg.num_heads)
    raise ValueError(spec.mixer)


def layer_apply(p, x, spec: LayerSpec, cfg: ArchConfig, run: RunConfig,
                mode: str, cache=None, pos=None, true_len=None):
    """Returns (x, aux_loss, new_cache_or_None)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    y, new_cache = _mixer_apply(p["mixer"], h, spec, cfg, run, mode, cache, pos,
                                true_len=true_len)
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y2 = 0.0
        if "moe" in spec.ffn:
            ym, aux = moe_mod.moe_apply(
                p["moe"], h2, top_k=cfg.experts_per_token, ffn_kind=cfg.ffn_kind,
                capacity_factor=cfg.capacity_factor, dispatch=run.moe_dispatch,
                true_len=true_len,
            )
            y2 = y2 + ym
        if spec.ffn in ("dense", "moe+dense"):
            y2 = y2 + ffn_apply(p["ffn"], h2, cfg.ffn_kind)
        x = x + y2
    return x, aux, new_cache


def superblock_apply(p, x, pattern, cfg, run, mode, caches=None, pos=None,
                     cache_len=None, true_len=None):
    """Apply one super-block. caches: dict l{i} -> cache (decode) or None."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for i, spec in enumerate(pattern):
        c = None
        if mode == "decode":
            c = caches[f"l{i}"]
        elif mode == "prefill":
            c = cache_len
        x, aux, nc = layer_apply(p[f"l{i}"], x, spec, cfg, run, mode, c, pos,
                                 true_len=true_len)
        aux_total = aux_total + aux
        if mode != "train":
            new_caches[f"l{i}"] = nc
    return x, aux_total, (new_caches if mode != "train" else None)


def _remat_wrap(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Whole-stack apply (scan over segment repeats)
# ---------------------------------------------------------------------------


def stack_apply(segments_params, x, cfg: ArchConfig, run: RunConfig,
                mode: str, caches=None, pos=None, cache_len=None,
                true_len=None):
    """Apply all segments. Returns (x, aux, caches_or_None).

    segments_params: tuple of stacked segment params.
    caches: tuple (per segment) of stacked cache pytrees (decode mode).
    """
    segs = cfg.segments()
    aux_total = jnp.zeros((), jnp.float32)
    out_caches = []
    for si, (seg, p_seg) in enumerate(zip(segs, segments_params)):
        pattern = seg.pattern

        if mode == "train":
            def body(carry, p_sb):
                xx, aux = carry
                xx, a, _ = superblock_apply(p_sb, xx, pattern, cfg, run, "train")
                return (xx, aux + a), None

            body = _remat_wrap(body, run)
            # repro: allow-raw(layer-stacking scan — structural iteration over stacked superblock params, zero FLOPs of its own)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p_seg)
            out = None

        elif mode == "prefill":
            def body(carry, p_sb):
                xx, aux = carry
                xx, a, cc = superblock_apply(
                    p_sb, xx, pattern, cfg, run, "prefill", cache_len=cache_len,
                    true_len=true_len,
                )
                return (xx, aux + a), cc

            body = _remat_wrap(body, run)
            # repro: allow-raw(layer-stacking scan — structural iteration over stacked superblock params, zero FLOPs of its own)
            (x, aux_total), seg_caches = jax.lax.scan(body, (x, aux_total), p_seg)
            out_caches.append(seg_caches)

        else:  # decode
            def body(xx, inp):
                p_sb, c_sb = inp
                xx, _, cc = superblock_apply(
                    p_sb, xx, pattern, cfg, run, "decode", caches=c_sb, pos=pos
                )
                return xx, cc

            # repro: allow-raw(layer-stacking scan — structural iteration over stacked superblock params, zero FLOPs of its own)
            x, seg_caches = jax.lax.scan(body, x, (p_seg, caches[si]))
            out_caches.append(seg_caches)

    return x, aux_total, (tuple(out_caches) if mode != "train" else None)


# ---------------------------------------------------------------------------
# Cache specs (abstract, for the dry-run)
# ---------------------------------------------------------------------------


def layer_cache_spec(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     cache_len: int):
    dt = cfg.jdtype
    if spec.mixer == "attn":
        return attn.attention_cache_spec(
            batch, cache_len, cfg.num_kv_heads, cfg.hd, spec.window, dt
        )
    if spec.mixer == "mamba":
        return ssm.mamba_state_spec(
            batch, cfg.d_model, dt, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state,
        )
    if spec.mixer == "mlstm":
        return ssm.mlstm_state_spec(batch, cfg.d_model, cfg.num_heads)
    if spec.mixer == "slstm":
        return ssm.slstm_state_spec(batch, cfg.d_model)
    raise ValueError(spec.mixer)


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int):
    """Abstract cache pytree matching stack_apply's decode layout."""
    out = []
    for seg in cfg.segments():
        sb = {
            f"l{i}": layer_cache_spec(cfg, spec, batch, cache_len)
            for i, spec in enumerate(seg.pattern)
        }
        stacked = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((seg.repeats,) + s.shape, s.dtype), sb
        )
        out.append(stacked)
    return tuple(out)
