"""Model-level tunables: annotation sites above the kernel layer.

The paper's annotations attach to loops; ours attach wherever a declared
knob changes schedule-not-semantics. Besides Pallas BlockSpecs, that is:

  * chunked-attention (q_chunk, k_chunk)  — VMEM/L2 working set
  * mamba scan chunk                       — state-materialization window
  * mLSTM chunk                            — intra-chunk matrix size
  * xent loss chunk                        — logits materialization window

Each wraps the production implementation and declares its reference —
`tests/test_ssm.py` separately proves chunk-invariance, so the tuner's
correctness gate is a redundant belt-and-braces here (as in the paper,
where the reference compare catches miscompiled variants).

These tunables measure meaningfully on ANY platform with the wall-clock
evaluator — which is how `benchmarks/fig1_autotune.py` reproduces the
paper's Figure-1 protocol on this CPU host.
"""
from __future__ import annotations

import functools

from ..core import DispatchSpec, ParamSpace, PowerOfTwoParam, tunable
from . import ssm
from .attention import chunked_attention
from ..kernels import ref


ATTN_CHUNK_SPACE = ParamSpace(
    [
        PowerOfTwoParam("q_chunk", 32, 2048),
        PowerOfTwoParam("k_chunk", 32, 2048),
    ]
)


def _attn_ref(q, k, v):
    return ref.attention(q, k, v, causal=True)


def _attn_heuristic(q, k, v):
    return {"q_chunk": 512, "k_chunk": 1024}  # the framework default


def _attn_chunks_example():
    import numpy as np
    import jax.numpy as jnp

    rs = np.random.RandomState(0)
    mk = lambda *s: jnp.asarray(rs.randn(*s) * 0.3, jnp.float32)
    return (mk(1, 4, 64, 16), mk(1, 2, 64, 16), mk(1, 2, 64, 16)), {}


@tunable("attn_chunks", space=ATTN_CHUNK_SPACE, reference=_attn_ref,
         heuristic=_attn_heuristic,
         dispatch=DispatchSpec(example=_attn_chunks_example,
                               data_parallel_args=(0, 1, 2)))
def attention_chunked(q, k, v, *, q_chunk: int, k_chunk: int):
    return chunked_attention(q, k, v, causal=True, q_chunk=q_chunk, k_chunk=k_chunk)


MAMBA_CHUNK_SPACE = ParamSpace([PowerOfTwoParam("chunk", 4, 512)])


def make_mamba_tunable(params):
    """Binds mamba params (closure) so the tunable signature is (x, *, chunk).

    ``mamba_forward`` no longer takes a chunk arg (it was inert after the
    dispatch rewire and has been removed), so the knob pins an explicit
    chunked-scan schedule through the ``scan_fn`` hook — same measurement
    protocol as before the dispatch rewire.
    """
    from ..kernels.ssm_scan import ssm_scan_chunked

    def ref_fn(x):
        return ssm.mamba_forward(
            params, x,
            scan_fn=functools.partial(ssm_scan_chunked, chunk=x.shape[1]))

    @tunable("mamba_chunk", space=MAMBA_CHUNK_SPACE, reference=ref_fn,
             default={"chunk": 32})
    def mamba_chunked(x, *, chunk: int):
        return ssm.mamba_forward(
            params, x,
            scan_fn=functools.partial(ssm_scan_chunked, chunk=chunk))

    return mamba_chunked


XENT_CHUNK_SPACE = ParamSpace([PowerOfTwoParam("loss_chunk", 32, 4096)])


def make_xent_tunable(lm_head_w):
    import jax.numpy as jnp

    def ref_fn(x, labels):
        # repro: allow-raw(tuning reference oracle — deliberately unfused full-vocab matmul the chunked variant is gated against)
        logits = x.reshape(-1, x.shape[-1]) @ lm_head_w
        return ref.softmax_xent(logits, labels.reshape(-1)).mean()

    @tunable("xent_chunk", space=XENT_CHUNK_SPACE, reference=ref_fn,
             default={"loss_chunk": 512})
    def xent_chunked(x, labels, *, loss_chunk: int):
        from .lm import _chunked_xent

        mask = jnp.ones(labels.shape, jnp.float32)
        return _chunked_xent({"w": lm_head_w}, x, labels, mask, loss_chunk)

    return xent_chunked
