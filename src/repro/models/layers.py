"""Shared layer primitives: init helpers, norms, FFNs, rotary embeddings.

Parameter convention: every init function returns ``(params, axes)`` where
``axes`` mirrors the params pytree and names each dim with a *logical axis*
string (e.g. ``("d_model", "ff")``). The sharding solver
(`repro.distributed.sharding`) maps logical axes → mesh axes with
divisibility checks; model code never mentions mesh axes.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.runtime import dispatch, fusion_wins

Params = Dict[str, Any]
Axes = Dict[str, Any]


def _init(rng, shape, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0] if len(shape) > 1 else 1.0)
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def dense_init(rng, d_in: int, d_out: int, dtype, in_axis: str, out_axis: str,
               bias: bool = False) -> Tuple[Params, Axes]:
    keys = jax.random.split(rng, 2)
    p: Params = {"w": _init(keys[0], (d_in, d_out), dtype)}
    a: Axes = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        a["b"] = (out_axis,)
    return p, a


def dense(p: Params, x: jax.Array) -> jax.Array:
    # Projection gemms go through the dispatch runtime: a tuned matmul record
    # (or the heuristic default) serves the site, and reference mode lowers
    # to plain jnp.dot. The dispatch spec's canonicalization flattens leading
    # dims, so call sites stay rank-generic. Biased projections fuse the
    # bias add into the gemm epilogue — but only where the database banked a
    # winning fused record (fusion_wins); everywhere else the unfused matmul
    # path (and its records) is untouched.
    if "b" in p and fusion_wins("matmul_bias_act", x, p["w"], p["b"]):
        return dispatch("matmul_bias_act", x, p["w"], p["b"])
    y = dispatch("matmul", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, dtype) -> Tuple[Params, Axes]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("d_model",)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # The dispatch spec's canonicalization owns the flatten-to-rows/reshape
    # dance, so call sites stay rank-generic.
    return dispatch("rmsnorm", x, p["scale"], eps=eps)


def rmsnorm_dense(pn: Params, pd: Params, x: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    """rmsnorm(x) projected through a dense layer — the norm→gemm producer/
    consumer pair (final-norm → unembed). Fuses into ``rmsnorm_matmul``
    where the database banked a winning record for this site; the unfused
    path keeps the separate rmsnorm + matmul dispatches (and their
    records)."""
    if "b" not in pd and fusion_wins("rmsnorm_matmul", x, pn["scale"], pd["w"],
                                     eps=eps):
        return dispatch("rmsnorm_matmul", x, pn["scale"], pd["w"], eps=eps)
    return dense(pd, rmsnorm(pn, x, eps))


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

FFN_KINDS = ("swiglu", "geglu", "gelu", "relu2")


def ffn_init(rng, d: int, ff: int, kind: str, dtype) -> Tuple[Params, Axes]:
    ks = jax.random.split(rng, 3)
    if kind in ("swiglu", "geglu"):
        p = {
            "wg": _init(ks[0], (d, ff), dtype),
            "wu": _init(ks[1], (d, ff), dtype),
            "wd": _init(ks[2], (ff, d), dtype, scale=1.0 / math.sqrt(ff)),
        }
        a = {"wg": ("d_model", "ff"), "wu": ("d_model", "ff"), "wd": ("ff", "d_model")}
    elif kind in ("gelu", "relu2"):
        p = {
            "wu": _init(ks[0], (d, ff), dtype),
            "wd": _init(ks[1], (ff, d), dtype, scale=1.0 / math.sqrt(ff)),
        }
        a = {"wu": ("d_model", "ff"), "wd": ("ff", "d_model")}
    else:
        raise ValueError(f"unknown ffn kind {kind!r}")
    return p, a


def _act_matmul(x: jax.Array, w: jax.Array, act: str) -> jax.Array:
    """act(x @ w) — fused into the gemm epilogue where the database banked a
    winning ``matmul_bias_act`` record for this site (zero bias), else the
    plain matmul dispatch followed by the jnp activation."""
    zb = jnp.zeros((w.shape[-1],), x.dtype)
    if fusion_wins("matmul_bias_act", x, w, zb, act=act):
        return dispatch("matmul_bias_act", x, w, zb, act=act)
    y = dispatch("matmul", x, w)
    return jax.nn.silu(y) if act == "silu" else jax.nn.gelu(y)


def ffn_apply(p: Params, x: jax.Array, kind: str) -> jax.Array:
    mm = lambda a, w: dispatch("matmul", a, w)
    if kind == "swiglu":
        return mm(_act_matmul(x, p["wg"], "silu") * mm(x, p["wu"]), p["wd"])
    if kind == "geglu":
        return mm(_act_matmul(x, p["wg"], "gelu") * mm(x, p["wu"]), p["wd"])
    if kind == "gelu":
        return mm(_act_matmul(x, p["wu"], "gelu"), p["wd"])
    if kind == "relu2":
        h = jax.nn.relu(mm(x, p["wu"]))
        return mm(h * h, p["wd"])
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., s, n_heads, head_dim]; positions: [s] or broadcastable."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [s, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [s, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(rng, vocab: int, d: int, dtype) -> Tuple[Params, Axes]:
    return (
        {"table": _init(rng, (vocab, d), dtype, scale=1.0)},
        {"table": ("vocab", "d_model")},
    )


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed_init(rng, d: int, vocab: int, dtype) -> Tuple[Params, Axes]:
    return (
        {"w": _init(rng, (d, vocab), dtype)},
        {"w": ("d_model", "vocab")},
    )


def unembed(p: Params, x: jax.Array) -> jax.Array:
    return dispatch("matmul", x, p["w"])
