"""jit-able train / prefill / serve steps + their sharding assignments.

`build_cell` is the single entry point shared by the dry-run, the trainer
and the serving engine: given (arch config, shape, mesh, layout, run config)
it returns the step function, abstract inputs, and in/out shardings — so
what the dry-run compiles is byte-for-byte what the launcher would run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec, input_specs
from ..distributed import sharding as shd
from ..models import lm
from ..models.transformer import RunConfig
from ..optim import adamw


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, run: RunConfig, opt_cfg: adamw.AdamWConfig):
    # grad accumulation dtype doubles as the reduction wire format: bf16
    # halves both the accumulator HBM and the DP all-reduce bytes.
    acc_dtype = jnp.bfloat16 if run.grad_compression == "bf16" else jnp.float32

    def loss_fn(params, batch):
        return lm.loss_fn(params, batch, cfg, run)

    def train_step(params, opt_state, batch):
        if run.microbatches > 1:
            k = run.microbatches
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
            )

            def mb_body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, loss_acc + loss, aux_acc + metrics["aux"]), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                mb_body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                mbs,
            )
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss / k
            metrics = {"xent": loss, "aux": aux / k}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            if run.grad_compression == "bf16":
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.bfloat16), grads
                )
        params, opt_state, opt_metrics = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, run: RunConfig, cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, run, cache_len=cache_len)

    return prefill_step


def make_serve_step(cfg: ArchConfig, run: RunConfig):
    def serve_step(params, caches, tokens, pos):
        return lm.decode_step(params, tokens, caches, pos, cfg, run)

    return serve_step


# ---------------------------------------------------------------------------
# cell assembly (shared by dry-run / trainer / server)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    """One (arch × shape × mesh) lowering unit."""

    step_fn: Any
    abstract_inputs: Tuple
    in_shardings: Tuple
    out_shardings: Any
    kind: str
    donate: Tuple[int, ...] = ()
    mesh: Any = None
    layout: Any = None


def build_cell(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh: jax.sharding.Mesh,
    layout: shd.Layout,
    run: RunConfig,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
) -> Cell:
    params_abs, axes = lm.abstract_params(cfg)
    p_sh = shd.param_shardings(axes, params_abs, mesh, layout)
    batch_abs = input_specs(cfg, shape)
    rep = shd.replicated(mesh)

    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        opt_abs = jax.eval_shape(functools.partial(adamw.init, opt_cfg), params_abs)
        o_sh = adamw.state_shardings(p_sh, opt_cfg.master_fp32, rep)
        b_sh = shd.data_specs(batch_abs, mesh, layout)
        step = make_train_step(cfg, run, opt_cfg)
        return Cell(
            step_fn=step,
            abstract_inputs=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            kind="train",
            donate=(0, 1),
            mesh=mesh,
            layout=layout,
        )

    if shape.kind == "prefill":
        b_sh = shd.data_specs(batch_abs, mesh, layout)
        step = make_prefill_step(cfg, run, cache_len=shape.seq_len)
        return Cell(
            step_fn=step,
            abstract_inputs=(params_abs, batch_abs),
            in_shardings=(p_sh, b_sh),
            out_shardings=None,
            kind="prefill",
            mesh=mesh,
            layout=layout,
        )

    # decode
    caches_abs = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = shd.cache_shardings(caches_abs, mesh, layout)
    tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    t_sh = shd.data_specs(tok_abs, mesh, layout)
    step = make_serve_step(cfg, run)
    return Cell(
        step_fn=step,
        abstract_inputs=(params_abs, caches_abs, tok_abs, pos_abs),
        in_shardings=(p_sh, c_sh, t_sh, rep),
        out_shardings=(None, c_sh),
        kind="decode",
        donate=(1,),
        mesh=mesh,
        layout=layout,
    )


def lower_cell(cell: Cell, mesh: jax.sharding.Mesh):
    """jit → lower for one cell (no compile; caller decides).

    The mesh rides in on the NamedShardings; the ambient mesh_context lets
    deep model code (MoE dispatch hints) place sharding constraints during
    tracing.
    """
    from ..distributed.sharding import mesh_context

    jitted = jax.jit(
        cell.step_fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate,
    )
    with mesh_context(mesh, cell.layout):
        return jitted.lower(*cell.abstract_inputs)
