import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory / cost / collective analyses.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b
    PYTHONPATH=src python -m repro.launch.dryrun --arch ... --shape train_4k \
        --mesh single --variant fsdp=0,remat=dots,microbatches=8   # hillclimb

Outputs one JSON per cell under benchmarks/results/dryrun/ plus a summary
table on stdout. Roofline terms use the TPU v5e constants from the brief.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from ..configs.base import SHAPES, ARCH_NAMES, cell_is_runnable, get_config
from ..core.evaluate import collective_stats, roofline_from_compiled
from ..core.platform import TPU_V5E
from ..distributed.sharding import Layout
from ..launch import defaults, mesh as mesh_mod, steps
from ..models import lm

RESULTS_DIR = os.path.join("benchmarks", "results", "dryrun")


def parse_variant(s):
    """'fsdp=0,remat=full,microbatches=8' -> overrides for Layout/RunConfig."""
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        if v in ("0", "1") and k in ("fsdp", "shard_experts", "head_aware"):
            out[k] = bool(int(v))
        elif k == "data_axes":          # e.g. data_axes=data+model (pure DP)
            out[k] = tuple(v.split("+"))
        elif v.isdigit():
            out[k] = int(v)
        else:
            out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant=None,
             save: bool = True, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    mesh_name = "pod2" if multi_pod else "pod1"
    tag = f"{cfg.name}__{shape.name}__{mesh_name}"
    if variant:
        tag += "__" + "-".join(f"{k}{v}" for k, v in sorted(variant.items()))
    if not ok:
        rec = {"cell": tag, "status": "skipped", "reason": why}
        if save:
            _save(tag, rec)
        if verbose:
            print(f"SKIP {tag}: {why}")
        return rec

    m = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    layout = defaults.default_layout(cfg, multi_pod)
    run = defaults.default_run(cfg, shape)
    if variant:
        lkeys = {f.name for f in dataclasses.fields(Layout)}
        rkeys = {f.name for f in dataclasses.fields(type(run))}
        layout = dataclasses.replace(layout, **{k: v for k, v in variant.items() if k in lkeys})
        run = dataclasses.replace(run, **{k: v for k, v in variant.items() if k in rkeys})

    t0 = time.time()
    try:
        cell = steps.build_cell(cfg, shape, m, layout, run)
        lowered = steps.lower_cell(cell, m)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        terms = roofline_from_compiled(
            compiled, TPU_V5E, chips=m.devices.size, hlo_text=hlo
        )
        n_params = lm.param_count(cfg)
        n_active = lm.active_param_count(cfg)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * n_active * tokens
        per_chip_model_flops = model_flops / m.devices.size
        rec = {
            "cell": tag,
            "status": "ok",
            "arch": cfg.name,
            "shape": shape.name,
            "kind": shape.kind,
            "mesh": list(m.devices.shape),
            "chips": int(m.devices.size),
            "layout": dataclasses.asdict(layout),
            "run": dataclasses.asdict(run),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            },
            "cost": {
                "flops_per_chip": terms.flops,
                "bytes_per_chip": terms.hlo_bytes,
            },
            "collectives": coll,
            "roofline": terms.to_json(),
            "model_flops_total": model_flops,
            "model_flops_per_chip": per_chip_model_flops,
            "useful_flops_ratio": (
                per_chip_model_flops / terms.flops if terms.flops else None
            ),
            "params": n_params,
            "active_params": n_active,
        }
        if verbose:
            dom = terms.dominant
            print(
                f"OK   {tag}: lower {t_lower:.0f}s compile {t_compile:.0f}s | "
                f"compute {terms.compute_s*1e3:.1f}ms mem {terms.memory_s*1e3:.1f}ms "
                f"coll {terms.collective_s*1e3:.1f}ms -> {dom} | "
                f"useful {rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}"
            )
    except Exception as e:
        rec = {
            "cell": tag,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        if verbose:
            print(f"FAIL {tag}: {type(e).__name__}: {e}")
    if save:
        _save(tag, rec)
    return rec


def _save(tag, rec):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default=None,
                    help="layout/run overrides: k=v,k=v (hillclimb probe)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    variant = parse_variant(args.variant)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cfgn = get_config(arch).name
                tag = f"{cfgn}__{shape}__{'pod2' if mp else 'pod1'}"
                if variant:
                    tag += "__" + "-".join(f"{k}{v}" for k, v in sorted(variant.items()))
                path = os.path.join(RESULTS_DIR, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"HAVE {tag}")
                            continue
                rec = run_cell(arch, shape, mp, variant=variant)
                if rec["status"] == "error":
                    n_fail += 1
    print(f"\ndone; {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
