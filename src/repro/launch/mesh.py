"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices before any jax
import; everything else sees the real 1-device CPU).

Topology (TPU v5e): single pod = 16×16 = 256 chips, axes ("data", "model");
multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism over DCN, "model" stays intra-pod ICI.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devs[:n], axis_types=auto)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1×1 mesh for CPU smoke tests and examples."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh(
        (1, 1), ("data", "model"), devices=jax.devices()[:1], axis_types=auto
    )
