"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices before any jax
import; everything else sees the real 1-device CPU).

Topology (TPU v5e): single pod = 16×16 = 256 chips, axes ("data", "model");
multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism over DCN, "model" stays intra-pod ICI.
"""
from __future__ import annotations

import math

import jax


def _mk_mesh(shape, axes, devices) -> jax.sharding.Mesh:
    # jax.sharding.AxisType (explicit-sharding API) does not exist in older
    # jax; Auto is also the default there, so omitting axis_types is exact.
    if hasattr(jax.sharding, "AxisType"):
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, devices=devices, axis_types=auto)
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    return _mk_mesh(shape, axes, devs[:n])


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1×1 mesh for CPU smoke tests and examples."""
    return _mk_mesh((1, 1), ("data", "model"), jax.devices()[:1])


def parse_mesh_spec(spec: str):
    """"DATAxMODEL" (or "PODxDATAxMODEL") -> (shape tuple, axis names).

    The shared notation for ``--mesh`` launcher flags and the campaign
    planner's ``--train-mesh``: "2x4" is a (data=2, model=4) mesh, "2x16x16"
    prepends a pod axis.
    """
    try:
        dims = tuple(int(d) for d in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh spec {spec!r}: expected e.g. '2x4' or '2x16x16'")
    if len(dims) == 2:
        return dims, ("data", "model")
    if len(dims) == 3:
        return dims, ("pod", "data", "model")
    raise ValueError(f"mesh spec {spec!r}: expected 2 or 3 dims, got {len(dims)}")


def make_mesh_from_spec(spec: str) -> jax.sharding.Mesh:
    """Build a mesh from a "DATAxMODEL" spec over the available devices."""
    shape, axes = parse_mesh_spec(spec)
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "importing jax for a fake-device host mesh"
        )
    return _mk_mesh(shape, axes, devs[:n])
