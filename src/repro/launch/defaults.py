"""Per-(arch × shape) default Layout and RunConfig — the *paper-faithful
baseline* configuration.

These are the 'untuned -O3' analogue: sensible hand rules a performance
engineer would start from. The §Perf hillclimbs then search the layout/run
spaces from here; winners are stored in the tuning database keyed by
(arch, shape, mesh) and take precedence at launch.
"""
from __future__ import annotations

from ..configs.base import ArchConfig, ShapeSpec
from ..distributed.sharding import Layout
from ..models.transformer import RunConfig

# params ≳ 20B get FSDP + aggressive remat + deeper grad accumulation
_BIG = {"gemma3-27b", "arctic-480b", "mixtral-8x7b", "jamba-1.5-large-398b"}

# §Perf hillclimb winners (EXPERIMENTS.md) — the shipped per-(arch, shape)
# specializations, exactly the paper's 'tuning database' at the layout level.
# Keys are (arch, shape.kind); values are Layout/RunConfig field overrides.
TUNED = {
    ("qwen2-0.5b", "train"): {
        # pure data-parallelism: a 0.5B model cannot amortize TP activation
        # all-reduces; DP-256 is compute/memory-bound (rf 0.1% -> 31%)
        "tensor_axis": "none", "data_axes": ("data", "model"),
        "microbatches": 1, "head_aware": True,
    },
    ("minitron-4b", "train"): {
        # head-aware TP (24 heads must not split mid-head) + single batch
        # pass (grad all-reduce out of the accumulation scan): rf 0.8% -> 22%
        "head_aware": True, "microbatches": 1,
    },
    ("arctic-480b", "train"): {
        # head-aware TP; MoE dispatch resharding remains dominant — next
        # iteration is shard_map all-to-all dispatch (see EXPERIMENTS.md §Perf)
        "head_aware": True,
    },
}


def tuned_overrides(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    return dict(TUNED.get((cfg.name, shape.kind), {"head_aware": True}))


def default_layout(cfg: ArchConfig, multi_pod: bool = False) -> Layout:
    return Layout(
        tensor_axis="model",
        data_axes=("data",),
        fsdp=cfg.name in _BIG,
        shard_experts=True,
        counts=(
            ("heads", cfg.num_heads),
            ("kv_heads", cfg.num_kv_heads),
            ("experts", max(cfg.num_experts, 1)),
        ),
        # head_aware=False reproduces the recorded naive baseline; the
        # hillclimb flips it on as iteration 1 (see EXPERIMENTS.md §Perf).
        head_aware=False,
        name="baseline",
    )


def default_run(cfg: ArchConfig, shape: ShapeSpec) -> RunConfig:
    big = cfg.name in _BIG
    if shape.name == "train_smoke":
        # The dev-host smoke configuration (launch.train --smoke). Kept here
        # so `campaign plan --train-shapes train_smoke` derives jobs with the
        # exact chunking the smoke trainer dispatches.
        return RunConfig(remat="none", loss_chunk=32, q_chunk=32, k_chunk=32,
                         microbatches=1)
    if shape.kind == "train":
        return RunConfig(
            remat="full" if big else "dots",
            microbatches=8 if big else 4,
            q_chunk=512,
            k_chunk=1024,
            loss_chunk=512,
            mamba_chunk=32,
            mlstm_chunk=64,
            moe_dispatch="scatter",
        )
    if shape.kind == "prefill":
        return RunConfig(
            remat="none",
            microbatches=1,
            q_chunk=512,
            k_chunk=2048,
            loss_chunk=512,
            mamba_chunk=64,
            mlstm_chunk=64,
            moe_dispatch="scatter",
        )
    # decode: single-chunk attention (scores are [b, h, 1, s] — tiny), no remat
    return RunConfig(
        remat="none",
        microbatches=1,
        q_chunk=1,
        k_chunk=shape.seq_len,
        loss_chunk=512,
        mamba_chunk=64,
        mlstm_chunk=64,
        moe_dispatch="scatter",
    )
