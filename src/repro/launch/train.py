"""Production training launcher.

On a TPU pod this builds the production mesh and the full-size model; on a
dev host it degrades to the 1-device mesh + reduced config (--smoke). The
same Trainer/steps path the multi-pod dry-run compiled is what runs here —
build_cell is shared, so dry-run success is launch success.

    # pod (256 chips):
    python -m repro.launch.train --arch mixtral-8x7b --shape train_4k --steps 1000
    # dev smoke:
    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke --steps 5
"""
from __future__ import annotations

import argparse
import logging

import jax

from ..configs.base import SHAPES, get_config
from ..data.pipeline import DataConfig
from ..optim import adamw
from ..train.trainer import Trainer, TrainerConfig
from . import defaults
from .mesh import make_host_mesh, make_production_mesh

logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU dev box)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        batch, seq = 8, 64
        run = defaults.default_run(cfg, shape)
        run = type(run)(remat="none", loss_chunk=32, q_chunk=32, k_chunk=32,
                        microbatches=1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        batch, seq = shape.global_batch, shape.seq_len
        run = defaults.default_run(cfg, shape)
    layout = defaults.default_layout(cfg, args.multi_pod)

    trainer = Trainer(
        cfg, run, mesh, layout,
        DataConfig(seed=args.seed, batch_size=batch, seq_len=seq,
                   host_index=jax.process_index(), host_count=jax.process_count()),
        adamw.AdamWConfig(total_steps=args.steps),
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir,
            grad_compression=args.compression,
            seed=args.seed,
        ),
    )
    # resume if a checkpoint exists
    if trainer.ckpt.latest_step() is not None:
        trainer.restore_checkpoint()
    metrics = trainer.train()
    print(f"done at step {trainer.step}: {metrics}")


if __name__ == "__main__":
    main()
