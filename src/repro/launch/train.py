"""Production training launcher.

On a TPU pod this builds the production mesh and the full-size model; on a
dev host it degrades to the 1-device mesh + reduced config (--smoke). The
same Trainer/steps path the multi-pod dry-run compiled is what runs here —
build_cell is shared, so dry-run success is launch success.

Training runs under a *pinned dispatch runtime* (mirroring launch/serve):
``--db`` points every kernel the step traces at a campaign-exported
per-platform database, ``--mode`` picks kernel/reference/auto dispatch, and
the run ends with the runtime's telemetry report — which resolution tier
(exact / cover / heuristic / reference) served each kernel×bucket. Because
the trainer traces under its mesh context, those buckets are keyed on
per-device *local* shard shapes: the shapes ``campaign plan --train-mesh``
pre-tunes.

    # pod (256 chips), with a campaign artifact:
    python -m repro.launch.train --arch mixtral-8x7b --shape train_4k \\
        --steps 1000 --db tpu-v5e.json --mode kernel
    # dev smoke:
    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke --steps 5
"""
from __future__ import annotations

import argparse
import logging
import os

import jax

import repro
from ..configs.base import SHAPES, get_config
from ..core.database import TuningDatabase
from ..core.platform import set_platform_override
from ..data.pipeline import DataConfig
from ..optim import adamw
from ..train.trainer import Trainer, TrainerConfig
from . import defaults
from .mesh import make_host_mesh, make_mesh_from_spec, make_production_mesh

logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU dev box)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="explicit mesh spec DATAxMODEL (e.g. 2x4) over the "
                         "available devices; overrides the smoke/production "
                         "mesh choice")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--db", default=None,
                    help="campaign-exported tuning database for this platform")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "kernel", "reference"),
                    help="dispatch mode for the trainer's runtime")
    ap.add_argument("--platform", default=None,
                    help="override the fingerprinted platform key (db namespace)")
    ap.add_argument("--telemetry-out", default=None,
                    help="write the runtime telemetry snapshot JSON here "
                         "(feed to `campaign status --telemetry` / "
                         "benchmarks/campaign_report.py)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable the obs collector for the run and write its "
                         "snapshot JSON here (render with "
                         "`python -m repro.obs report --metrics <file>`)")
    ap.add_argument("--metrics-sample", type=float, default=1.0,
                    help="obs sample rate for high-frequency sites (1.0 = all)")
    args = ap.parse_args()
    if args.db and not os.path.exists(args.db):
        # A typo'd path would otherwise open as an EMPTY database and every
        # bucket would silently resolve at the heuristic tier.
        ap.error(f"--db {args.db}: no such file")
    if args.platform:
        set_platform_override(args.platform)

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_mesh_from_spec(args.mesh) if args.mesh else make_host_mesh()
        shape = SHAPES["train_smoke"]
        batch, seq = shape.global_batch, shape.seq_len
        run = defaults.default_run(cfg, shape)
    else:
        mesh = (make_mesh_from_spec(args.mesh) if args.mesh
                else make_production_mesh(multi_pod=args.multi_pod))
        batch, seq = shape.global_batch, shape.seq_len
        run = defaults.default_run(cfg, shape)
    layout = defaults.default_layout(cfg, args.multi_pod)

    rt = repro.runtime(
        db=TuningDatabase(args.db) if args.db else None,
        mode=args.mode, name="train",
    )
    # Observability is opt-in: without --metrics-out the ambient collector
    # stays the disabled process default and instrumentation costs one
    # branch per site (the overhead contract).
    import contextlib

    import repro.obs as obs

    col = (
        obs.collect(name="train", sample_rate=args.metrics_sample)
        if args.metrics_out else contextlib.nullcontext()
    )
    with col:
        trainer = Trainer(
            cfg, run, mesh, layout,
            DataConfig(seed=args.seed, batch_size=batch, seq_len=seq,
                       host_index=jax.process_index(),
                       host_count=jax.process_count()),
            adamw.AdamWConfig(total_steps=args.steps),
            TrainerConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                checkpoint_dir=args.ckpt_dir,
                grad_compression=args.compression,
                seed=args.seed,
            ),
            runtime=rt,
        )
        # resume if a checkpoint exists
        if trainer.ckpt.latest_step() is not None:
            trainer.restore_checkpoint()
        metrics = trainer.train()
    print(f"done at step {trainer.step}: {metrics}")
    print(rt.telemetry.report())
    if args.telemetry_out:
        rt.telemetry.write(args.telemetry_out)
        print(f"wrote telemetry -> {args.telemetry_out}")
    if args.metrics_out:
        col.write(args.metrics_out)
        print(f"wrote metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
