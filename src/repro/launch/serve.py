"""Production serving launcher: continuous-batching engine on the chosen mesh.

    # pod:
    python -m repro.launch.serve --arch qwen2.5-3b --requests 64
    # dev smoke:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs.base import SHAPES, get_config
from ..models import lm
from ..serving.engine import EngineConfig, Request, ServingEngine
from . import defaults
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES["decode_32k"]
    if args.smoke:
        cfg = cfg.reduced()
        if cfg.num_experts:
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    layout = defaults.default_layout(cfg, args.multi_pod)
    run = defaults.default_run(cfg, shape)
    if args.smoke:
        run = dataclasses.replace(
            run, q_chunk=32, k_chunk=max(32, args.max_seq), loss_chunk=32
        )

    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(
        cfg, run, params, mesh, layout,
        EngineConfig(max_batch=8, max_seq=args.max_seq),
    )
    rs = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(
            Request(
                prompt=rs.randint(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=args.new_tokens,
                temperature=0.7 if i % 2 else 0.0,
                seed=i,
                arrival_time=float(i),   # staggered: exercises in-flight admission
            )
        )
    done = engine.serve()
    toks = sum(len(r.output) for r in done)
    st = engine.stats
    print(f"served {len(done)} requests / {toks} tokens; "
          f"p50 latency {sorted(r.latency_s for r in done)[len(done)//2]:.2f}s "
          f"({sorted(r.latency_steps for r in done)[len(done)//2]} ticks); "
          f"{st['decode_steps']} pool decode steps, "
          f"{st['tokens_out']/max(1, st['decode_steps']):.2f} tok/step")


if __name__ == "__main__":
    main()
