"""Production serving launcher: continuous-batching engine on the chosen mesh.

The engine gets its own scoped dispatch runtime (`repro.runtime`): pass a
campaign-exported per-platform database via ``--db`` and every kernel the
model traces resolves against it — no process-global state — and the run
ends with the runtime's telemetry report (which resolution tier served each
kernel×bucket: the sustained-performance accounting).

    # pod, with a campaign artifact:
    python -m repro.launch.serve --arch qwen2.5-3b --requests 64 \\
        --db tpu-v5e.json --warmup
    # dev smoke:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import numpy as np

import repro
from ..configs.base import SHAPES, get_config
from ..core.database import TuningDatabase
from ..models import lm
from ..serving.engine import EngineConfig, Request, ServingEngine
from . import defaults
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--db", default=None,
                    help="campaign-exported tuning database for this platform")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "kernel", "reference"),
                    help="dispatch mode for the engine's runtime")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-resolve every slot-pool bucket before serving")
    ap.add_argument("--platform", default=None,
                    help="override the fingerprinted platform key (db namespace)")
    ap.add_argument("--telemetry-out", default=None,
                    help="write the runtime telemetry snapshot JSON here "
                         "(feed to `campaign status --telemetry` / "
                         "benchmarks/campaign_report.py)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable the obs collector for the run and write its "
                         "snapshot JSON here (render with "
                         "`python -m repro.obs report --metrics <file>`)")
    ap.add_argument("--metrics-sample", type=float, default=1.0,
                    help="obs sample rate for per-tick gauges (1.0 = all)")
    args = ap.parse_args()
    if args.platform:
        from ..core.platform import set_platform_override

        set_platform_override(args.platform)
    if args.db and not os.path.exists(args.db):
        # A typo'd path would otherwise open as an EMPTY database and every
        # bucket would silently resolve at the heuristic tier — the exact
        # wasted-artifact failure warmup exists to prevent.
        ap.error(f"--db {args.db}: no such file")

    cfg = get_config(args.arch)
    shape = SHAPES["decode_32k"]
    if args.smoke:
        cfg = cfg.reduced()
        if cfg.num_experts:
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    layout = defaults.default_layout(cfg, args.multi_pod)
    run = defaults.default_run(cfg, shape)
    if args.smoke:
        run = dataclasses.replace(
            run, q_chunk=32, k_chunk=max(32, args.max_seq), loss_chunk=32
        )

    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    rt = repro.runtime(
        db=TuningDatabase(args.db) if args.db else None,
        mode=args.mode, name="serve",
    )
    engine = ServingEngine(
        cfg, run, params, mesh, layout,
        EngineConfig(max_batch=8, max_seq=args.max_seq),
        runtime=rt,
    )
    import contextlib

    import repro.obs as obs
    from ..obs.metrics import percentile_row

    col = (
        obs.collect(name="serve", sample_rate=args.metrics_sample)
        if args.metrics_out else contextlib.nullcontext()
    )
    with col:
        if args.warmup:
            resolved = engine.warmup()
            print(f"warmup resolved {len(resolved)} kernel buckets")
        rs = np.random.RandomState(0)
        for i in range(args.requests):
            engine.submit(
                Request(
                    prompt=rs.randint(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=0.7 if i % 2 else 0.0,
                    seed=i,
                    arrival_time=float(i),  # staggered: exercises in-flight admission
                )
            )
        done = engine.serve()
    toks = sum(len(r.output) for r in done)
    st = engine.stats
    print(f"served {len(done)} requests / {toks} tokens; "
          f"p50 latency {sorted(r.latency_s for r in done)[len(done)//2]:.2f}s "
          f"({sorted(r.latency_steps for r in done)[len(done)//2]} ticks); "
          f"{st['decode_steps']} pool decode steps, "
          f"{st['tokens_out']/max(1, st['decode_steps']):.2f} tok/step")
    if args.metrics_out:
        snap = col.snapshot()
        for name, label in (("serve.admission_s", "admission"),
                            ("serve.per_token_s", "per-token"),
                            ("serve.latency_s", "request latency")):
            row = percentile_row(snap, name)
            if row:
                print(f"{label}: p50 {row['p50']*1e3:.2f}ms  "
                      f"p95 {row['p95']*1e3:.2f}ms  p99 {row['p99']*1e3:.2f}ms "
                      f"(n={row['count']})")
        col.write(args.metrics_out)
        print(f"wrote metrics -> {args.metrics_out}")
    print(rt.telemetry.report())
    if args.telemetry_out:
        rt.telemetry.write(args.telemetry_out)
        print(f"wrote telemetry -> {args.telemetry_out}")


if __name__ == "__main__":
    main()
