"""Launch layer: meshes, per-cell step assembly, dry-run, train/serve drivers."""
