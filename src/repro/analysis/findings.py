"""Finding/Report types shared by every static-analysis pass.

A *finding* is one diagnostic: which pass produced it, how severe it is,
where it points, and what it says. A *report* aggregates findings across
passes plus free-form stats (counts the CLI prints and tests assert on).

Severity contract:

* ``error`` — a contract violation: an untuned raw-compute site, a racy
  output ref, a missing backward oracle, a stale database key. The default
  exit code is non-zero when any error is present.
* ``warn``  — suspicious but possibly intentional: an unknown platform
  fingerprint, a capacity key that drifted from the arch config. Fails
  only under ``--strict``.
* ``info``  — accounting: pragma-suppressed sites, per-platform pruning
  counts. Never affects the exit code.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Sequence

SEVERITIES = ("error", "warn", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str      # "lint" | "legality" | "contracts" | "db"
    severity: str       # one of SEVERITIES
    location: str       # "path:line", "kernel@platform", db key, ...
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    def format(self) -> str:
        return f"{self.severity:>5}  [{self.pass_name}] {self.location}: {self.message}"

    def to_json(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


class Report:
    """Ordered findings + stats, with the exit-code policy in one place."""

    def __init__(self) -> None:
        self.findings: List[Finding] = []
        self.stats: Dict[str, Any] = {}

    def add(self, pass_name: str, severity: str, location: str, message: str) -> None:
        self.findings.append(Finding(pass_name, severity, location, message))

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.stats.update(other.stats)

    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    def errors(self) -> List[Finding]:
        return self.by_severity("error")

    def warnings(self) -> List[Finding]:
        return self.by_severity("warn")

    def counts(self) -> Dict[str, int]:
        return {s: len(self.by_severity(s)) for s in SEVERITIES}

    def exit_code(self, strict: bool = False) -> int:
        """0 = clean. Errors always fail; warnings fail only under strict."""
        if self.errors():
            return 1
        if strict and self.warnings():
            return 1
        return 0

    def format(self, verbose: bool = False) -> str:
        sev_rank = {s: i for i, s in enumerate(SEVERITIES)}
        shown = [
            f for f in self.findings if verbose or f.severity != "info"
        ]
        shown.sort(key=lambda f: (sev_rank[f.severity], f.pass_name, f.location))
        lines = [f.format() for f in shown]
        c = self.counts()
        lines.append(
            f"analysis: {c['error']} error(s), {c['warn']} warning(s), "
            f"{c['info']} info"
        )
        return "\n".join(lines)

    def to_json(self) -> Dict[str, Any]:
        return {
            "findings": [f.to_json() for f in self.findings],
            "counts": self.counts(),
            "stats": self.stats,
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=1, sort_keys=True)
