"""repro.analysis — static analysis over the autotuning contract.

Three passes, no compilation:

1. **lint** — dispatch-completeness: raw compute in model code
   (``jnp.einsum``/``@``/``jax.nn.softmax``/``lax.scan``) must route through
   a registry tunable or carry a ``# repro: allow-raw(<reason>)`` pragma.
2. **legality** — every Pallas grid model abstractly evaluated over its full
   config space × platform fingerprint: lane/sublane alignment, index-map
   bounds, write-write races (``repro.core.gridmodel``).
3. **contracts** — registry/planner/database coherence: backward plans
   dispatch registered tunables with oracles, ``DEFAULT_KERNELS`` is
   registry-covered, databases/manifests carry no stale or unreachable keys.

CLI: ``python -m repro.analysis check [--strict] [--db ...] [--manifest ...]``
(also exposed as ``python -m repro.campaign check`` for the db/manifest
subset operators run against live campaigns).
"""
from .findings import Finding, Report
from .cli import main, run_checks

__all__ = ["Finding", "Report", "main", "run_checks"]
