"""Pass 2 — Pallas kernel legality over full config spaces.

Abstractly evaluates every registered grid model (``repro.core.gridmodel``)
over its tunable's complete config space on each requested platform
fingerprint, without compiling anything:

* **race** or **oob** findings are errors — a shipped kernel whose output
  refs alias along a parallel grid axis, or whose index map walks off the
  padded array, is wrong on *some* platform even if today's interpreter
  runs happen to pass.
* a space with **zero** legal configs is an error — the tuner would find
  no valid variant on that platform.
* alignment-only pruning is ``info`` accounting: those configs exist for
  CPU-interpret coverage and are statically skipped on TPU (the tuner's
  pre-pass and ``ParamSpace.legal_configs`` consume the same verdicts).
"""
from __future__ import annotations

from typing import Optional, Sequence

from .findings import Report

DEFAULT_PLATFORMS = ("tpu-v5e", "tpu-v4")


def check_legality(
    platforms: Sequence[str] = DEFAULT_PLATFORMS,
    report: Optional[Report] = None,
) -> Report:
    report = report if report is not None else Report()
    from ..core.gridmodel import registered_models, space_report
    from ..core.runtime import ensure_registered

    ensure_registered()
    stats = {}
    for kernel in sorted(registered_models()):
        for platform in platforms:
            r = space_report(kernel, platform)
            loc = f"{kernel}@{platform}"
            stats[loc] = {
                "total": r["total"], "legal": r["legal"], "illegal": r["illegal"],
            }
            by_cat = r.get("by_category", {})
            for cat in ("race", "oob"):
                n = by_cat.get(cat, 0)
                if n:
                    sample = next(
                        (s for s in r.get("reasons", ()) if s.startswith(cat)),
                        "",
                    )
                    report.add(
                        "legality", "error", loc,
                        f"{n} config(s) with a {cat} hazard — e.g. {sample}"
                        if sample else f"{n} config(s) with a {cat} hazard",
                    )
            if r["legal"] == 0:
                report.add(
                    "legality", "error", loc,
                    f"no legal configs (all {r['total']} pruned): the tuner "
                    "would find no valid variant on this platform",
                )
            elif r["illegal"]:
                report.add(
                    "legality", "info", loc,
                    f"{r['illegal']} of {r['total']} configs statically "
                    f"pruned ({r['legal']} legal)",
                )
            if r.get("redundant"):
                report.add(
                    "legality", "info", loc,
                    f"{r['redundant']} legal config(s) are grid-signature "
                    "duplicates at nominal shapes (measurement redundancy)",
                )
    report.stats["legality"] = stats
    return report
