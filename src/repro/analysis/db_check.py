"""Database + manifest contract checks (``campaign check`` backend).

Loads the tuning database as *raw JSON* on purpose: ``TuningDatabase.load``
silently drops wrong-schema blobs (correct for the runtime — stale records
must not be served), but an operator running ``check`` wants the finding,
not a silent fresh start. Checks:

* schema version drift (pre-current databases) — warn;
* record keys naming a platform fingerprint that is neither a known profile
  nor the detected one — warn (a db tuned elsewhere, or a typo'd export);
* stale pre-promoted-dtype keys: an integer-dtype key for a tunable whose
  example call promotes to float (softmax_xent keyed on its int32 labels,
  before keys switched to the promoted dtype) — error, the runtime will
  never hit it;
* records whose stored config is no longer valid in the tunable's current
  space — warn (the space evolved; dispatch would fall through this record);
* pre-residual ``*_bwd`` keys: a backward record whose key carries fewer
  operands than the tunable's current example call — recorded before the
  residual contract made the forward's saved aux (flash o/lse, rmsnorm
  inv-rms, xent lse) keyed dispatch args. The runtime will never ExactHit
  it; it survives only as a warm-start seed — warn, re-plan + re-run;
* manifest: the pre-backward-plane hazard (``@dp`` training scenarios, no
  ``*_bwd`` roster) — error, mirroring ``campaign run``'s refusal;
* expert_gemm capacity drift: db records whose bucketed capacity dim no
  longer matches any capacity the manifest's expert_gemm jobs expect —
  warn, deduplicated through ``obs.warn_once`` so drift also lands in the
  event buffer operators already watch.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Sequence

from .findings import Report


def _load_raw_db(path: str) -> Optional[Dict[str, Any]]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _example_arg_count(tunable) -> Optional[int]:
    """Arity of the tunable's example call (None when there is no example)."""
    spec = tunable.dispatch
    if spec is None or getattr(spec, "example", None) is None:
        return None
    try:
        args, _kwargs = spec.example()
        return len(args)
    except Exception:                                 # pragma: no cover
        return None


def _example_promotes_float(tunable) -> Optional[bool]:
    """True when the tunable's example call computes in a float dtype."""
    spec = tunable.dispatch
    if spec is None or getattr(spec, "example", None) is None:
        return None
    try:
        args, _kwargs = spec.example()
        from ..core.tuner import promoted_dtype

        dtypes = [a.dtype for a in args if hasattr(a, "dtype")]
        return promoted_dtype(dtypes).startswith(("float", "bfloat", "f"))
    except Exception:                                 # pragma: no cover
        return None


def check_db(
    db_path: str,
    manifest_path: Optional[str] = None,
    report: Optional[Report] = None,
) -> Report:
    report = report if report is not None else Report()
    from ..core.annotate import registered
    from ..core.database import SCHEMA_VERSION, shape_bucket, split_key
    from ..core.platform import PROFILES, detect_platform
    from ..core.runtime import ensure_registered

    ensure_registered()
    regs = registered()
    known_platforms = set(PROFILES) | {detect_platform().name}

    blob = _load_raw_db(db_path)
    if blob is None:
        report.add("db", "info", db_path, "no tuning database at this path")
        report.stats["db"] = {"records": 0}
        return report

    schema = blob.get("schema", 0)
    if schema != SCHEMA_VERSION:
        report.add(
            "db", "warn", db_path,
            f"schema {schema} != current {SCHEMA_VERSION}: the runtime "
            "ignores every record in this file (re-run the campaign)",
        )
    records: Dict[str, Any] = blob.get("records", {})
    report.stats["db"] = {"records": len(records), "schema": schema}

    seen_platforms = set()
    float_example_cache: Dict[str, Optional[bool]] = {}
    arity_cache: Dict[str, Optional[int]] = {}
    for key, rec in sorted(records.items()):
        kernel, platform, shapes, dtype, _extra = split_key(key)
        if platform not in known_platforms and platform not in seen_platforms:
            seen_platforms.add(platform)
            report.add(
                "db", "warn", key,
                f"unknown platform fingerprint {platform!r} (known: "
                f"{sorted(known_platforms)}) — foreign export or typo",
            )
        t = regs.get(kernel)
        if t is None:
            report.add(
                "db", "warn", key,
                f"record for unregistered tunable {kernel!r}: dead weight, "
                "nothing will ever look it up",
            )
            continue
        if dtype.startswith(("int", "uint")):
            if kernel not in float_example_cache:
                float_example_cache[kernel] = _example_promotes_float(t)
            if float_example_cache[kernel]:
                report.add(
                    "db", "error", key,
                    f"stale integer-dtype key ({dtype}) for a float-computing "
                    "kernel — recorded before keys used the promoted dtype; "
                    "the runtime will never hit it (re-tune rebuilds it)",
                )
        if kernel.endswith("_bwd"):
            if kernel not in arity_cache:
                arity_cache[kernel] = _example_arg_count(t)
            want = arity_cache[kernel]
            if want is not None and len(shapes) < want:
                report.add(
                    "db", "warn", key,
                    f"{kernel} record keyed under a pre-residual signature "
                    f"({len(shapes)} operands, current dispatch keys "
                    f"{want}): the runtime will never ExactHit it — it is "
                    "warm-start-only (transfer seeds still mine it); "
                    "re-plan and re-run the backward roster",
                )
        cfg = (rec or {}).get("config")
        if cfg is not None and not t.space.is_valid(cfg):
            why = t.space.why_invalid(cfg)
            report.add(
                "db", "warn", key,
                f"stored config is no longer valid in {kernel}'s space "
                f"({why}); dispatch falls through this record",
            )

    if manifest_path:
        _check_manifest(manifest_path, records, report)
    else:
        report.add(
            "db", "info", db_path,
            "no manifest given: capacity-drift and backward-roster checks "
            "skipped (pass --manifest)",
        )
    return report


def _check_manifest(
    manifest_path: str, records: Dict[str, Any], report: Report
) -> None:
    from ..campaign import scheduler
    from ..core.database import split_key

    if not os.path.exists(manifest_path):
        report.add("db", "warn", manifest_path, "manifest path does not exist")
        return
    manifest = scheduler.CampaignManifest.load(manifest_path)
    if scheduler.manifest_missing_bwd(manifest):
        report.add(
            "db", "error", manifest_path,
            "manifest has sharding-aware training jobs (@dp scenarios) but "
            "no backward roster — it predates the tuned backward plane; "
            "re-plan before running",
        )
    # Expert-capacity drift: the MoE x operand is (experts, capacity, d) —
    # its bucketed middle dim is the capacity the records were tuned at. If
    # the plan's expert_gemm jobs (derived from today's arch configs via
    # expert_capacity()) expect a different bucket set, the banked records
    # will never ExactHit under the new routing.
    expected = {
        s[1]
        for j in manifest.jobs
        if j.kernel == "expert_gemm"
        for s in (j.bucketed_shapes()[:1] or ())
        if len(s) == 3
    }
    if not expected:
        return
    from ..obs.collect import warn_once

    for key in sorted(records):
        kernel, platform, shapes, _dtype, _extra = split_key(key)
        if kernel != "expert_gemm" or not shapes or len(shapes[0]) != 3:
            continue
        capacity = shapes[0][1]
        if capacity not in expected:
            warn_once(
                "analysis.expert_gemm_capacity",
                key=key,
                detail=(
                    f"record capacity bucket {capacity} not among the plan's "
                    f"expected buckets {sorted(expected)}"
                ),
            )
            report.add(
                "db", "warn", key,
                f"expert_gemm capacity bucket {capacity} no longer matches "
                f"the plan's expert_capacity() buckets {sorted(expected)} — "
                "routing changed; this record is unreachable",
            )
