"""Pass 1 — dispatch-completeness lint.

Walks the model code's AST and flags *raw compute*: calls that burn FLOPs or
launch a recurrence without routing through a registry tunable (``jnp.einsum``
/ ``dot`` / ``matmul`` / ``tensordot``, the ``@`` operator, ``jax.nn.softmax``,
``lax.scan``). Every such site is either a dispatch-coverage gap the tuner
cannot see, or a deliberate decision — and deliberate decisions must say why:

    y = jnp.einsum("bi,io->bo", x, w)  # repro: allow-raw(gate matmul is tiny)

    # repro: allow-raw(decay-masked scores need a fused kernel; ROADMAP item)
    def chunk_step(...):
        ...

Pragma grammar: ``# repro: allow-raw(<reason>)``. A same-line pragma covers
that line's sites. A pragma on its *own* line covers the entire statement
that begins on the next line — including compound statements, so one pragma
above a ``def`` covers every raw site in that function. Reasons are free
text (no parentheses) and surface as ``info`` findings, so the authoritative
map of remaining untuned sites is always one ``check`` run away.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .findings import Report

PRAGMA_RE = re.compile(r"#\s*repro:\s*allow-raw\(([^)]*)\)")

# Dotted-call patterns that count as raw compute. Matched against the full
# dotted path of the callee (e.g. "jnp.einsum", "jax.lax.scan").
_FLOP_TAILS = {"einsum", "dot", "matmul", "tensordot"}
_FLOP_ROOTS = {"jnp", "jax", "np", "numpy"}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.nn.softmax' for Attribute chains rooted at a Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _classify_call(path: str) -> Optional[str]:
    parts = path.split(".")
    if parts[-1] in _FLOP_TAILS and parts[0] in _FLOP_ROOTS:
        return f"raw {parts[-1]}"
    if path.endswith("nn.softmax"):
        return "raw softmax"
    if path.endswith("lax.scan"):
        return "raw lax.scan recurrence"
    return None


class _RawComputeVisitor(ast.NodeVisitor):
    def __init__(self) -> None:
        self.sites: List[Tuple[int, str]] = []       # (lineno, label)

    def visit_Call(self, node: ast.Call) -> None:
        path = _dotted(node.func)
        if path is not None:
            label = _classify_call(path)
            if label is not None:
                self.sites.append((node.lineno, f"{label} ({path})"))
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.MatMult):
            self.sites.append((node.lineno, "raw @ matmul operator"))
        self.generic_visit(node)


def _collect_pragmas(
    source_lines: Sequence[str],
) -> Tuple[Dict[int, str], Dict[int, str]]:
    """(same-line pragmas, own-line pragmas), keyed by 1-based line number.

    A pragma is *own-line* when nothing but whitespace precedes the comment;
    it then covers the statement beginning on the following line.
    """
    same_line: Dict[int, str] = {}
    own_line: Dict[int, str] = {}
    for i, line in enumerate(source_lines, start=1):
        m = PRAGMA_RE.search(line)
        if not m:
            continue
        reason = m.group(1).strip() or "unspecified"
        if line[: m.start()].strip():
            same_line[i] = reason
        else:
            own_line[i] = reason
    return same_line, own_line


def _covered_ranges(
    tree: ast.AST, own_line: Dict[int, str]
) -> List[Tuple[int, int, str]]:
    """(first, last, reason) line ranges covered by own-line pragmas."""
    out: List[Tuple[int, int, str]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.stmt):
            continue
        reason = own_line.get(node.lineno - 1)
        if reason is not None:
            out.append((node.lineno, node.end_lineno or node.lineno, reason))
    return out


def lint_source(source: str, path: str, report: Report) -> None:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:                          # pragma: no cover
        report.add("lint", "error", f"{path}:{e.lineno or 0}", f"syntax error: {e.msg}")
        return
    visitor = _RawComputeVisitor()
    visitor.visit(tree)
    if not visitor.sites:
        return
    same_line, own_line = _collect_pragmas(source.splitlines())
    ranges = _covered_ranges(tree, own_line)

    def _reason_for(lineno: int) -> Optional[str]:
        if lineno in same_line:
            return same_line[lineno]
        for first, last, reason in ranges:
            if first <= lineno <= last:
                return reason
        return None

    for lineno, label in sorted(visitor.sites):
        loc = f"{path}:{lineno}"
        reason = _reason_for(lineno)
        if reason is not None:
            report.add("lint", "info", loc, f"{label} — allowed: {reason}")
            report.stats["lint_allowed"] = report.stats.get("lint_allowed", 0) + 1
        else:
            report.add(
                "lint", "error", loc,
                f"{label} not routed through a registry tunable; dispatch it "
                "or annotate `# repro: allow-raw(<reason>)`",
            )
            report.stats["lint_raw"] = report.stats.get("lint_raw", 0) + 1


def lint_paths(paths: Sequence[str], report: Optional[Report] = None) -> Report:
    """Lint every ``.py`` file under each path (file or directory)."""
    report = report if report is not None else Report()
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(
                    os.path.join(root, n) for n in names if n.endswith(".py")
                )
        elif p.endswith(".py"):
            files.append(p)
    report.stats["lint_files"] = len(files)
    for f in sorted(files):
        with open(f) as fh:
            lint_source(fh.read(), f, report)
    return report


def default_models_dir() -> str:
    """src/repro/models — the layer the lint holds to the dispatch contract."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(os.path.dirname(here), "models")
