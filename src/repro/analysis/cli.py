"""``python -m repro.analysis check`` — run the static-analysis passes.

    check   lint model code for unrouted raw compute (pass 1), abstractly
            verify every Pallas grid model over its full config space on
            TPU fingerprints (pass 2), and cross-check registry/planner
            contracts (pass 3); optionally audit a tuning database and
            campaign manifest (--db/--manifest, the `campaign check` body).

Exit code: 1 when any error finding is present; ``--strict`` also fails on
warnings (the CI leg runs strict). ``--json`` emits the machine-readable
report for tooling.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .findings import Report

PASSES = ("lint", "legality", "contracts", "db")


def run_checks(
    models_dir: Optional[str] = None,
    platforms: Optional[List[str]] = None,
    db: Optional[str] = None,
    manifest: Optional[str] = None,
    passes: Optional[List[str]] = None,
) -> Report:
    """Programmatic entry point (also the `campaign check` backend)."""
    from . import contracts, db_check, legality, lint

    passes = list(passes or PASSES)
    report = Report()
    if "lint" in passes:
        lint.lint_paths([models_dir or lint.default_models_dir()], report)
    if "legality" in passes:
        legality.check_legality(
            platforms or list(legality.DEFAULT_PLATFORMS), report
        )
    if "contracts" in passes:
        contracts.check_contracts(report)
    if "db" in passes and db:
        db_check.check_db(db, manifest_path=manifest, report=report)
    return report


def cmd_check(args) -> int:
    passes = [p for p in args.passes.split(",") if p]
    unknown = set(passes) - set(PASSES)
    if unknown:
        print(f"error: unknown pass(es) {sorted(unknown)}; "
              f"choose from {list(PASSES)}", file=sys.stderr)
        return 2
    report = run_checks(
        models_dir=args.models_dir,
        platforms=[p for p in args.platforms.split(",") if p],
        db=args.db,
        manifest=args.manifest,
        passes=passes,
    )
    if args.json:
        print(report.dumps())
    else:
        print(report.format(verbose=args.verbose))
    return report.exit_code(strict=args.strict)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    pc = sub.add_parser("check", help="run the static-analysis passes")
    pc.add_argument("--models-dir", default=None,
                    help="directory to lint (default: src/repro/models)")
    pc.add_argument("--platforms", default="tpu-v5e,tpu-v4",
                    help="comma-separated platform fingerprints for the "
                         "legality pass")
    pc.add_argument("--db", default=None,
                    help="tuning database to audit (enables the db pass)")
    pc.add_argument("--manifest", default=None,
                    help="campaign manifest to cross-check against --db")
    pc.add_argument("--passes", default=",".join(PASSES),
                    help="comma-separated subset of passes to run")
    pc.add_argument("--strict", action="store_true",
                    help="exit 1 on warnings too (the CI gate)")
    pc.add_argument("--json", action="store_true",
                    help="emit the machine-readable report")
    pc.add_argument("--verbose", "-v", action="store_true",
                    help="also print info findings (allowed sites, pruning)")
    pc.set_defaults(fn=cmd_check)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
