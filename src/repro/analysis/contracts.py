"""Pass 3 — registry contract verification.

Cross-checks the three places a kernel must agree with itself:

* every registered tunable has a correctness oracle (its tuning
  ``reference``) — without one the autotuner's gate is vacuous;
* every ``vjp="dispatch"`` tunable's backward plan actually routes through
  registered tunables: its ``bwd`` callable must dispatch either a matched
  ``<name>_bwd`` sibling or the forward tunable itself (matmul/expert_gemm
  gradients reuse the forward kernel with transposed operands) — unless the
  spec declares ``bwd_via``, in which case the plan is verified against
  those names instead (fused-epilogue tunables decompose their gradients
  onto *other* kernels' dispatch sites) — and every dispatch target it
  names must exist in the registry with an oracle;
* the campaign planner's default roster (``planner.DEFAULT_KERNELS``) only
  names registered tunables — a roster typo silently plans zero jobs for
  that kernel.

The backward-plan check reads the ``bwd`` source (``inspect.getsource``)
for ``dispatch("<name>", ...)`` sites: the registry declares *that* a
backward plan exists, the source names *which* tunables it resolves
through, and this pass pins the two together.
"""
from __future__ import annotations

import inspect
import re
from typing import Optional

from .findings import Report

_DISPATCH_RE = re.compile(r"dispatch\(\s*[\"']([^\"']+)[\"']")


def check_contracts(report: Optional[Report] = None) -> Report:
    report = report if report is not None else Report()
    from ..campaign.planner import DEFAULT_KERNELS
    from ..core.annotate import registered
    from ..core.runtime import ensure_registered

    ensure_registered()
    regs = registered()
    n_dispatch_vjp = 0

    for name in sorted(regs):
        t = regs[name]
        if t.reference is None:
            report.add(
                "contracts", "error", name,
                "tunable has no reference oracle: the tuner's correctness "
                "gate cannot validate its variants",
            )
        spec = t.dispatch
        if spec is None or getattr(spec, "vjp", None) != "dispatch":
            continue
        n_dispatch_vjp += 1
        bwd = getattr(spec, "bwd", None)
        if bwd is None:
            report.add(
                "contracts", "error", name,
                'vjp="dispatch" declared but no bwd callable attached',
            )
            continue
        try:
            src = inspect.getsource(bwd)
        except (OSError, TypeError):                  # pragma: no cover
            report.add(
                "contracts", "warn", name,
                "bwd source unavailable; cannot verify its dispatch targets",
            )
            continue
        targets = sorted(set(_DISPATCH_RE.findall(src)))
        if not targets:
            report.add(
                "contracts", "error", name,
                'vjp="dispatch" bwd never calls dispatch(...): gradients '
                "would bypass the policy pipeline entirely",
            )
            continue
        via = tuple(getattr(spec, "bwd_via", ()) or ())
        if via:
            undeclared = [v for v in via if v not in targets]
            if undeclared:
                report.add(
                    "contracts", "error", name,
                    f"bwd_via declares {undeclared} but the bwd source never "
                    "dispatches them — the declared decomposition has drifted "
                    "from the plan",
                )
        elif f"{name}_bwd" not in targets and name not in targets:
            report.add(
                "contracts", "error", name,
                f"bwd dispatches {targets} but neither {name}_bwd nor the "
                f"forward tunable — gradient records would bank under an "
                "unrelated key",
            )
        for target in targets:
            if target not in regs:
                report.add(
                    "contracts", "error", name,
                    f"bwd dispatches unregistered tunable {target!r}",
                )
            elif regs[target].reference is None:
                report.add(
                    "contracts", "error", name,
                    f"bwd target {target!r} has no reference oracle",
                )

    for kernel in DEFAULT_KERNELS:
        if kernel not in regs:
            report.add(
                "contracts", "error", f"planner:{kernel}",
                "DEFAULT_KERNELS names a tunable missing from the registry — "
                "campaign plans would silently skip it",
            )

    report.stats["contracts"] = {
        "tunables": len(regs),
        "dispatch_vjp": n_dispatch_vjp,
        "roster": len(DEFAULT_KERNELS),
    }
    return report
