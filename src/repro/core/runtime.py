"""The dispatch runtime: scoped tuned contexts + pluggable resolution.

This module is the deployment half of the annotation story. ``@tunable``
declares *what* can specialize (:mod:`repro.core.annotate`); the runtime
decides, per call site, *which* implementation and config actually runs —
and makes that decision scoped, swappable, and observable:

* **Scoped contexts** — a :class:`TunedRuntime` pins a tuning database, a
  mode (``"kernel"`` | ``"reference"`` | ``"auto"``), and a resolution
  policy for everything executed under ``with`` it::

      with repro.runtime(db=serve_db, mode="kernel") as rt:
          engine.serve()            # every kernel dispatch uses serve_db
      print(rt.telemetry.report())

  Runtimes nest (inner wins; unspecified fields inherit from the enclosing
  runtime at construction) and live on a context-local stack, so serving,
  campaign evaluation, and tests each pin their own db/mode without
  cross-talk — including across threads: a fresh thread starts at the
  process-default runtime, never at another thread's scope.

* **Pluggable resolution** — the exact→cover→heuristic chain that used to
  be hard-coded in ``tune_or_lookup`` is a pipeline of
  :class:`ResolutionPolicy` objects. The default is
  ``(ExactHit, TuneNow, CoverSet, Heuristic, Reference)``; pass
  ``policy=(ExactHit(), Reference())`` for a "run only measured configs,
  else fall back to reference" deployment, or insert a custom policy (an
  object with ``name`` and ``resolve(request)``) anywhere in the chain.

* **Telemetry** — every dispatch records which tier served which
  kernel×bucket (:class:`Telemetry`; tiers ``override | exact | tune |
  cover | heuristic | reference`` plus cache hits), tagged with the
  dispatch *phase*: ``fwd`` for forward sites, ``bwd`` for gradient sites
  resolved inside a backward dispatch plan. This is the paper's
  sustained-performance accounting: after a warmed serving run,
  ``telemetry.snapshot()`` shows exactly how much traffic ran on tuned
  records vs cover-set entries vs the vendor-baseline heuristic — and
  after a tuned train step, whether the *gradient* sites hit too.

* **Tuned backward plane** — in kernel mode, a tunable whose dispatch spec
  declares ``vjp="dispatch"`` + a ``bwd`` plan differentiates through
  *dispatch sites*: the bound variant is wrapped in a ``jax.custom_vjp``
  whose backward calls ``spec.bwd(ct, *canonical_args, **kwargs)``, and
  that plan routes each gradient through ``dispatch(...)`` again (matmul's
  dL/dx and dL/dw are transposed-operand matmul dispatches; flash
  attention / rmsnorm / softmax-xent resolve their own ``*_bwd``
  tunables). Every backward call therefore gets its own database key,
  policy resolution, and ``bwd``-tagged telemetry row — a campaign
  pre-tunes gradients exactly like forwards, and a tuned train step stops
  paying reference-speed backward recomputes. ``runtime(...,
  bwd_dispatch=False)`` restores the old reference-VJP recompute (the
  fwd-only-tuned baseline the benchmarks compare against).

* **Resolution cache** — per-runtime ``{db key: Resolution}``; repeated jit
  traces of the same shape bucket stop re-hitting the database (see
  ``benchmarks/dispatch_overhead.py`` for the cold/warm gap). Bounded:
  LRU-evicted past ``cache_capacity`` entries with an optional
  ``cache_ttl`` (evictions show up in telemetry), so very-long-lived
  servers cannot grow it without limit. ``clear_cache()`` after mutating
  the database mid-flight.

* **Platform + sharding aware keys** — keys are namespaced under the
  *detected* platform (``repro.core.platform.detect_platform``; override
  via ``REPRO_PLATFORM`` / ``set_platform_override`` / a per-runtime
  ``platform=``), and inside an active ``mesh_context`` batch-leading args
  are keyed on their per-device *local shard* shapes — the shapes a
  sharding-aware campaign (``plan_training_jobs``) tuned.

Deployment entry points are generated from the registry
(:func:`entry_point` / :func:`dispatch`), so adding a kernel is one
``@tunable(..., dispatch=DispatchSpec(...))`` decorator with zero edits
anywhere else. The old global-mode API (``ops.set_kernel_mode`` /
``ops.kernels_enabled`` / ``ops.<kernel>``) completed its deprecation cycle
and is gone — ``kernels/ops.py`` survives only as the migration guide.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

from .annotate import DispatchSpec, Tunable, get_tunable
from .database import TuningDatabase, default_db
from .params import Config
from .platform import detect_platform, platform_override

# Import order is safe: repro.obs's collector/tracing layers are
# stdlib-only (obs.drift, which does import core modules, is lazy), and so
# is the fault-injection harness (one bool check when no plan is active).
from ..obs.collect import current_collector as _obs_collector
from ..obs.trace import span as _obs_span
from ..testing.faults import fault_point as _fault_point

_MODES = ("kernel", "reference", "auto")


class DispatchFault(RuntimeError):
    """A guarded dispatch's own fault signal (e.g. a failed non-finite
    probe) — raised and caught inside the guard, quarantining the bucket."""

_platform_name: Optional[str] = None


def _platform() -> str:
    """Effective platform key: the override escape hatch if set, else the
    memoized fingerprint (the backend cannot change within a process, and
    ``jax.devices()`` per dispatch would dominate warm resolution)."""
    ov = platform_override()
    if ov:
        return ov
    global _platform_name
    if _platform_name is None:
        _platform_name = detect_platform().name
    return _platform_name

# Resolution tiers, in the order the default pipeline consults them.
# "bgtune" is the BackgroundTune tier (repro.core.bgtune): a miss served by
# the heuristic config while an async tuner works the bucket toward "exact".
TIERS = ("override", "exact", "tune", "bgtune", "cover", "heuristic", "reference")

# Dispatch phases: forward sites, gradient sites (dispatches made while a
# backward dispatch plan is executing), and optimizer-update sites (the
# trainer tags its parameter update "opt" — no kernel dispatches live there
# today, so the tag exists for phase-resolved *timing*, not tier counts).
# Ambient, not threaded through call signatures: a bwd plan is ordinary
# model-layer code calling dispatch().
PHASES = ("fwd", "bwd", "opt")

_phase_ctx: "contextvars.ContextVar[str]" = contextvars.ContextVar(
    "repro_dispatch_phase", default="fwd"
)


@contextlib.contextmanager
def dispatch_phase(phase: str):
    """Tag every dispatch in this scope with `phase` ('fwd'|'bwd'|'opt').

    The runtime enters ``dispatch_phase("bwd")`` around a dispatch spec's
    backward plan, so telemetry separates gradient-site resolutions from
    forward ones — the accounting behind "the train step's backward FLOPs
    run on tuned records too".
    """
    if phase not in PHASES:
        raise ValueError(f"phase {phase!r} not in {PHASES}")
    tok = _phase_ctx.set(phase)
    try:
        yield
    finally:
        _phase_ctx.reset(tok)


def current_phase() -> str:
    return _phase_ctx.get()


# ---------------------------------------------------------------------------
# Resolution requests / results / policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResolutionRequest:
    """Everything a policy may consult to resolve one kernel×bucket."""

    tunable: Tunable
    args: tuple                      # canonicalized positional args
    key: str                         # full database key (platform+bucket+dtype)
    key_extra: str
    db: TuningDatabase
    platform: str
    runtime: "TunedRuntime"
    # Per-call effective tuning permissions (runtime defaults, possibly
    # overridden by the resolve() caller — e.g. warmup(allow_tune=True)
    # must not mutate a runtime other threads are dispatching through).
    allow_tune: bool = False
    tune_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Resolution:
    """Outcome of resolving one kernel×bucket.

    ``config=None`` means "execute the reference implementation" (the
    terminal :class:`Reference` tier); otherwise the config is bound as a
    kernel variant.

    ``key`` is the database key the resolution answered (``None`` only for
    tiers that never compute one — reference mode, ``config=`` overrides).
    ``cache=False`` keeps the resolution out of the runtime's resolution
    cache, so the next resolve re-runs the pipeline — how the BackgroundTune
    tier stays hot-swappable (every resolve re-consults ExactHit until the
    promoted record lands) and how quarantined buckets re-probe. ``probe``
    marks a resolution whose first guarded execution should be validated
    (exception guard + optional non-finite check) before the health book
    clears it.
    """

    config: Optional[Config]
    tier: str
    key: Optional[str] = None
    cache: bool = True
    probe: bool = False


class ResolutionPolicy:
    """One tier of the resolution pipeline.

    ``resolve`` returns a :class:`Resolution` to stop the chain, or ``None``
    to pass the request to the next policy. ``name`` is the telemetry tier
    label.
    """

    name = "policy"

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{type(self).__name__}>"


class ExactHit(ResolutionPolicy):
    """A stored record for this exact key: zero-cost specialization."""

    name = "exact"

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        rec = req.db.lookup(req.key)
        if rec is not None and req.tunable.space.is_valid(rec.config):
            return Resolution(dict(rec.config), self.name)
        return None


class TuneNow(ResolutionPolicy):
    """Tune on the spot (writes the record) — only if the runtime allows it."""

    name = "tune"

    def __init__(self, **tune_kwargs: Any):
        self.tune_kwargs = tune_kwargs

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        if not req.allow_tune:
            return None
        from .tuner import autotune  # late: tuner imports annotate/database

        kwargs = dict(self.tune_kwargs)
        kwargs.update(req.tune_kwargs)
        res = autotune(
            req.tunable, req.args, db=req.db, key_extra=req.key_extra, **kwargs
        )
        return Resolution(dict(res.best_config), self.name)


class CoverSet(ResolutionPolicy):
    """Nearest 'few fit most' cover entry: measured config, unseen bucket."""

    name = "cover"

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        # Rank neighbours by the shapes the key was computed from (already
        # bucketed and — under a sharded mesh — localized to the per-device
        # shard), so cover transfer is consistent with exact-hit keying.
        from .database import split_key

        shapes = split_key(req.key)[2]
        for entry in req.db.lookup_cover(req.tunable.name, req.platform, shapes):
            cfg = entry.get("config")
            if cfg is not None and req.tunable.space.is_valid(cfg):
                return Resolution(dict(cfg), self.name)
        return None


class Heuristic(ResolutionPolicy):
    """The shape heuristic default — the 'vendor baseline'. Always succeeds."""

    name = "heuristic"

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        return Resolution(req.tunable.default_config(*req.args), self.name)


class Reference(ResolutionPolicy):
    """Terminal tier: run the reference implementation, not a kernel variant.

    In the default pipeline :class:`Heuristic` always resolves first, so
    this only fires in trimmed pipelines such as ``(ExactHit(),
    Reference())`` — "tuned configs or bust".
    """

    name = "reference"

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        return Resolution(None, self.name)


def default_policy() -> Tuple[ResolutionPolicy, ...]:
    return (ExactHit(), TuneNow(), CoverSet(), Heuristic(), Reference())


# ---------------------------------------------------------------------------
# Health book (guarded execution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Health:
    level: str              # "record" (this db record) | "kernel" (any variant)
    fails: int = 0
    until: float = 0.0      # monotonic stamp the quarantine lapses (probe due)
    backoff: float = 0.0    # current re-probe interval


class HealthBook:
    """Per-runtime quarantine ledger for faulting kernel executions.

    Keyed like the resolution cache (full db keys). Two quarantine levels:
    ``"record"`` — the stored/measured config for this bucket faulted, but
    the kernel itself may be fine (resolution skips the db-record tiers and
    serves the heuristic); ``"kernel"`` — the heuristic config faulted too,
    so no variant is trusted for this bucket (resolution goes straight to
    reference). Entries re-probe after an exponential backoff (capped), so
    a transient fault — or a record fixed by a re-tune — heals without a
    restart; a persistent fault re-quarantines with a longer interval.
    Bounded: past ``capacity`` entries the oldest-lapsing are dropped (a
    dropped entry just means one extra probe).
    """

    def __init__(self, base_s: float = 5.0, max_s: float = 300.0,
                 capacity: int = 1024):
        self.base_s = float(base_s)
        self.max_s = float(max_s)
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: Dict[str, _Health] = {}

    def consult(self, key: str) -> Optional[Tuple[str, str]]:
        """None when healthy; ("probe"|"blocked", level) when quarantined."""
        with self._lock:
            h = self._entries.get(key)
            if h is None:
                return None
            state = "probe" if time.monotonic() >= h.until else "blocked"
            return state, h.level

    def quarantine(self, key: str, level: str) -> _Health:
        with self._lock:
            h = self._entries.get(key)
            if h is None:
                h = self._entries[key] = _Health(level=level)
            elif level == "kernel":
                h.level = "kernel"      # escalate; never de-escalate here
            h.fails += 1
            h.backoff = min(self.max_s, self.base_s * (2 ** (h.fails - 1)))
            h.until = time.monotonic() + h.backoff
            while len(self._entries) > self.capacity:
                victim = min(self._entries, key=lambda k: self._entries[k].until)
                del self._entries[victim]
            return h

    def record_ok(self, key: str) -> None:
        with self._lock:
            self._entries.pop(key, None)

    def __len__(self) -> int:
        return len(self._entries)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        now = time.monotonic()
        with self._lock:
            return {
                k: {"level": h.level, "fails": h.fails,
                    "backoff_s": h.backoff, "probe_in_s": max(0.0, h.until - now)}
                for k, h in self._entries.items()
            }


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


class Telemetry:
    """Per-runtime counters: which tier served each kernel×bucket.

    ``tiers``    — total dispatches per tier.
    ``by_key``   — ``{db key: {tier: count}}`` (reference-mode and explicit
                   ``config=`` dispatches, which never compute a bucket key,
                   are recorded under ``"<kernel>|*"``).
    ``phases``   — ``{phase: {tier: count}}`` for ``fwd`` vs ``bwd``
                   dispatch sites (the tuned-backward-plane accounting: a
                   fully pre-tuned train step shows ``exact``-only counts
                   under BOTH phases).
    ``by_key_phase`` — ``{phase: {db key: {tier: count}}}``: the per-site
                   breakdown split by phase, so a gate can name the exact
                   gradient bucket that fell off the tuned path.
    ``cache_hits`` / ``calls`` — resolution-cache effectiveness.
    ``cache_evictions`` — entries dropped by the cache's LRU/TTL bound (a
                   nonzero rate on a short-lived run usually means the
                   capacity is too small for the working set).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with getattr(self, "_lock", threading.Lock()):
            self.tiers: Dict[str, int] = {}
            self.by_key: Dict[str, Dict[str, int]] = {}
            self.phases: Dict[str, Dict[str, int]] = {}
            self.by_key_phase: Dict[str, Dict[str, Dict[str, int]]] = {}
            self.calls = 0
            self.cache_hits = 0
            self.cache_evictions = 0

    def record(self, kernel: str, key: Optional[str], tier: str,
               cached: bool = False, phase: Optional[str] = None) -> None:
        k = key if key is not None else f"{kernel}|*"
        phase = phase if phase is not None else _phase_ctx.get()
        with self._lock:
            self.calls += 1
            if cached:
                self.cache_hits += 1
            self.tiers[tier] = self.tiers.get(tier, 0) + 1
            per = self.by_key.setdefault(k, {})
            per[tier] = per.get(tier, 0) + 1
            ph = self.phases.setdefault(phase, {})
            ph[tier] = ph.get(tier, 0) + 1
            pk = self.by_key_phase.setdefault(phase, {}).setdefault(k, {})
            pk[tier] = pk.get(tier, 0) + 1
        # Fold into the ambient obs collector: the same accounting becomes a
        # tagged counter next to the latency histograms (one enabled-check
        # when nobody is collecting). Keys are deliberately NOT a tag — the
        # per-key breakdown stays in this class; tag cardinality stays
        # kernel × tier × phase × hit/miss.
        col = _obs_collector()
        if col.enabled:
            col.counter(
                "dispatch.calls", kernel=kernel, tier=tier, phase=phase,
                cached="hit" if cached else "miss",
            )

    def record_eviction(self, count: int = 1) -> None:
        with self._lock:
            self.cache_evictions += count

    @property
    def cache_hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            total = self.calls or 1
            return {
                "calls": self.calls,
                "cache_hits": self.cache_hits,
                "cache_hit_rate": self.cache_hit_rate,
                "cache_evictions": self.cache_evictions,
                "tiers": dict(self.tiers),
                "tier_rates": {t: n / total for t, n in self.tiers.items()},
                "by_key": {k: dict(v) for k, v in self.by_key.items()},
                "phases": {p: dict(v) for p, v in self.phases.items()},
                "by_key_phase": {
                    p: {k: dict(v) for k, v in per.items()}
                    for p, per in self.by_key_phase.items()
                },
            }

    def write(self, path: str) -> None:
        """Export the snapshot as JSON — the artifact `campaign status
        --telemetry` / benchmarks/campaign_report.py consume (one exporter
        shared by the launchers' --telemetry-out flags)."""
        import json

        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def report(self) -> str:
        """Human-readable sustained-performance accounting."""
        snap = self.snapshot()
        lines = [
            "dispatch telemetry: %d calls, %d cache hits (%.0f%%), %d evictions"
            % (snap["calls"], snap["cache_hits"], 100 * snap["cache_hit_rate"],
               snap["cache_evictions"])
        ]
        for tier in TIERS:
            if tier in snap["tiers"]:
                lines.append(
                    f"  tier {tier:<9} {snap['tiers'][tier]}"
                    f" ({100 * snap['tier_rates'][tier]:.0f}%)"
                )
        for phase in PHASES:
            per = snap["phases"].get(phase)
            if per:
                detail = ", ".join(f"{t}={per[t]}" for t in TIERS if t in per)
                lines.append(f"  phase {phase:<8} {sum(per.values())} ({detail})")
        for key in sorted(snap["by_key"]):
            per = snap["by_key"][key]
            detail = ", ".join(f"{t}={per[t]}" for t in TIERS if t in per)
            lines.append(f"  {key}: {detail}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

_INHERIT = object()

# Context-local stack of active runtimes. contextvars give us both asyncio-
# and thread-isolation: a new thread starts with an empty stack and falls
# back to the process-default runtime.
_stack: "contextvars.ContextVar[Tuple[TunedRuntime, ...]]" = contextvars.ContextVar(
    "repro_runtime_stack", default=()
)

_root_lock = threading.Lock()
_root: Optional["TunedRuntime"] = None


class TunedRuntime:
    """A scoped dispatch context: db × mode × policy × cache × telemetry.

    Parameters left unspecified inherit from the runtime that is active at
    construction time (ultimately the process-default runtime), so
    ``repro.runtime(mode="reference")`` inside a serving scope keeps the
    serving database while flipping the implementation path.

    ``db=None`` is meaningful: it means "whatever :func:`default_db`
    resolves to at call time" — the process-default runtime uses it so
    ``set_default_db`` keeps working mid-session.

    ``platform=None`` (the default) namespaces database keys under the
    *detected* platform (:func:`repro.core.platform.detect_platform`,
    honouring the override escape hatch) — callers no longer wire a platform
    string. Pass an explicit name to pin a runtime to a foreign namespace
    (e.g. inspecting a v5e artifact from a dev host).

    The resolution cache is bounded: `cache_capacity` entries, LRU-evicted
    (a long-lived server cycling through many shape buckets cannot grow it
    without limit), plus an optional `cache_ttl` in seconds after which an
    entry re-resolves — evictions are counted in ``telemetry``.
    """

    def __init__(
        self,
        db: Union[TuningDatabase, None, object] = _INHERIT,
        mode: Union[str, object] = _INHERIT,
        policy: Union[Sequence[ResolutionPolicy], None, object] = _INHERIT,
        allow_tune: Union[bool, object] = _INHERIT,
        tune_kwargs: Union[Dict[str, Any], None, object] = _INHERIT,
        platform: Union[str, None, object] = _INHERIT,
        cache_capacity: Union[int, object] = _INHERIT,
        cache_ttl: Union[float, None, object] = _INHERIT,
        bwd_dispatch: Union[bool, object] = _INHERIT,
        guard: Union[bool, object] = _INHERIT,
        guard_nonfinite: Union[bool, object] = _INHERIT,
        name: str = "",
        _is_root: bool = False,
    ):
        parent = None if _is_root else current_runtime()
        self.db = db if db is not _INHERIT else (parent.db if parent else None)
        self.mode = mode if mode is not _INHERIT else (parent.mode if parent else "auto")
        if self.mode not in _MODES:
            raise ValueError(f"mode {self.mode!r} not in {_MODES}")
        pol = policy if policy is not _INHERIT else (parent.policy if parent else None)
        self.policy: Tuple[ResolutionPolicy, ...] = (
            tuple(pol) if pol is not None else default_policy()
        )
        self.allow_tune = bool(
            allow_tune if allow_tune is not _INHERIT
            else (parent.allow_tune if parent else False)
        )
        tk = tune_kwargs if tune_kwargs is not _INHERIT else None
        self.tune_kwargs: Dict[str, Any] = dict(tk or {})
        self.platform: Optional[str] = (
            platform if platform is not _INHERIT
            else (parent.platform if parent else None)
        )
        cap = (
            cache_capacity if cache_capacity is not _INHERIT
            else (parent.cache_capacity if parent else 4096)
        )
        self.cache_capacity = max(0, int(cap))
        self.cache_ttl: Optional[float] = (
            cache_ttl if cache_ttl is not _INHERIT
            else (parent.cache_ttl if parent else None)
        )
        # Whether kernel-mode dispatch differentiates through the tuned
        # backward plane (vjp="dispatch" specs). False restores the
        # reference-VJP recompute — the fwd-only-tuned baseline.
        self.bwd_dispatch = bool(
            bwd_dispatch if bwd_dispatch is not _INHERIT
            else (parent.bwd_dispatch if parent else True)
        )
        # Guarded execution: a faulting kernel variant quarantines its db key
        # in the health book and the dispatch falls through to heuristic /
        # reference instead of raising. guard=False restores raise-through
        # (debugging a kernel wants the traceback, not a silent downgrade).
        self.guard = bool(
            guard if guard is not _INHERIT else (parent.guard if parent else True)
        )
        # Opt-in: on a bucket's first (probe) resolution, a concrete kernel
        # output containing non-finite values counts as a fault. Off by
        # default — under jit the output is a tracer and unobservable, and
        # legitimate kernels can emit inf masks.
        self.guard_nonfinite = bool(
            guard_nonfinite if guard_nonfinite is not _INHERIT
            else (parent.guard_nonfinite if parent else False)
        )
        self.health = HealthBook()
        self.name = name or ("default" if _is_root else f"runtime@{id(self):x}")
        self.telemetry = Telemetry()
        # key -> (db it was resolved against, Resolution, monotonic stamp),
        # LRU-ordered. The db reference is validated on lookup so a swapped
        # database (rt.db reassignment, or set_default_db for db=None
        # runtimes) can never serve a stale resolution from its predecessor;
        # the stamp enforces cache_ttl.
        self._cache: "collections.OrderedDict[str, Tuple[TuningDatabase, Resolution, float]]" = (
            collections.OrderedDict()
        )
        self._cache_lock = threading.Lock()

    # -- scoping -------------------------------------------------------------
    # Deliberately token-free: one runtime instance may be entered
    # concurrently from several threads AND from interleaved asyncio tasks
    # on one thread (each task/thread sees its own copy of the contextvar
    # stack). A contextvar Token would have to be reset in the exact context
    # that created it; popping the innermost occurrence of `self` from the
    # current context's stack is equivalent for our usage and safe in all of
    # the above.
    def __enter__(self) -> "TunedRuntime":
        _stack.set(_stack.get() + (self,))
        return self

    def __exit__(self, *exc) -> None:
        s = _stack.get()
        for i in range(len(s) - 1, -1, -1):
            if s[i] is self:
                _stack.set(s[:i] + s[i + 1:])
                return

    # -- mode ----------------------------------------------------------------
    @property
    def kernel_mode_active(self) -> bool:
        """Whether dispatch takes the kernel path (vs reference).

        ``"auto"`` reads ``REPRO_USE_PALLAS`` lazily, so flipping the env var
        between calls behaves the same as the old import-time ``_STATE``
        for test processes that set it up front, while also supporting
        per-leg CI overrides.
        """
        if self.mode == "kernel":
            return True
        if self.mode == "reference":
            return False
        return os.environ.get("REPRO_USE_PALLAS", "0") == "1"

    # -- cache ---------------------------------------------------------------
    def clear_cache(self) -> None:
        with self._cache_lock:
            self._cache.clear()

    def _cache_evict(self, key: str) -> None:
        with self._cache_lock:
            self._cache.pop(key, None)

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def _cache_get(self, key: str, db: TuningDatabase) -> Optional[Resolution]:
        now = time.monotonic()
        with self._cache_lock:
            hit = self._cache.get(key)
            if hit is None:
                return None
            cached_db, res, stamp = hit
            if cached_db is not db:
                return None
            if self.cache_ttl is not None and now - stamp > self.cache_ttl:
                del self._cache[key]
                self.telemetry.record_eviction()
                return None
            self._cache.move_to_end(key)        # LRU touch
            return res

    def _cache_put(self, key: str, db: TuningDatabase, res: Resolution) -> None:
        if self.cache_capacity <= 0:
            return
        with self._cache_lock:
            self._cache[key] = (db, res, time.monotonic())
            self._cache.move_to_end(key)
            evicted = 0
            while len(self._cache) > self.cache_capacity:
                self._cache.popitem(last=False)
                evicted += 1
        if evicted:
            self.telemetry.record_eviction(evicted)

    # -- resolution ----------------------------------------------------------
    def resolve(self, tunable: Union[str, Tunable], args: Sequence[Any],
                key_extra: str = "",
                allow_tune: Optional[bool] = None,
                tune_kwargs: Optional[Dict[str, Any]] = None,
                dp_dims: Optional[Dict[int, int]] = None) -> Resolution:
        """Run the policy pipeline for (tunable, args), with caching.

        Returns the cached :class:`Resolution` when this bucket key was
        resolved before under this runtime against the same database
        (telemetry still counts the call, flagged as a cache hit).

        ``allow_tune`` / ``tune_kwargs`` override the runtime's defaults for
        THIS call only (how warmup grants TuneNow permission without
        mutating a runtime other threads may be dispatching through). A
        cached resolution wins over ``allow_tune=True`` — ``clear_cache()``
        first to force re-tuning of already-resolved buckets.

        ``dp_dims`` overrides which dim of which arg is keyed at its local
        shard size under a sharded mesh (see ``tuner._args_key``) — backward
        dispatch sites with transposed operands pass it.
        """
        from .tuner import _args_key  # late: tuner imports this module's deps

        tunable = _as_tunable(tunable)
        db = self.db if self.db is not None else default_db()
        platform = self.platform or _platform()
        col = _obs_collector()
        t0 = time.perf_counter() if col.enabled else 0.0
        key = _args_key(tunable, args, platform, key_extra, dp_dims=dp_dims)
        # Health first: a quarantined bucket must not serve its cached (or
        # freshly re-resolved) faulting config. "blocked" short-circuits to
        # the degraded tier; "probe" (backoff lapsed) re-runs the pipeline
        # uncached with probe=True so the guard re-validates before the
        # health book clears the entry.
        probe = False
        skip_record_tiers = False
        if self.guard:
            h = self.health.consult(key)
            if h is not None:
                state, level = h
                if state == "probe":
                    probe = True
                elif level == "kernel":
                    res = Resolution(None, "reference", key=key, cache=False)
                    self.telemetry.record(tunable.name, key, res.tier)
                    if col.enabled:
                        col.observe(
                            "dispatch.resolve_s", time.perf_counter() - t0,
                            tier=res.tier, phase=_phase_ctx.get(), cached="miss",
                        )
                    return res
                else:
                    skip_record_tiers = True
        if not (probe or skip_record_tiers):
            hit = self._cache_get(key, db)
            if hit is not None:
                self.telemetry.record(tunable.name, key, hit.tier, cached=True)
                if col.enabled:
                    col.observe(
                        "dispatch.resolve_s", time.perf_counter() - t0,
                        tier=hit.tier, phase=_phase_ctx.get(), cached="hit",
                    )
                return hit
        req = ResolutionRequest(
            tunable=tunable, args=tuple(args), key=key, key_extra=key_extra,
            db=db, platform=platform, runtime=self,
            allow_tune=self.allow_tune if allow_tune is None else bool(allow_tune),
            tune_kwargs={**self.tune_kwargs, **(tune_kwargs or {})},
        )
        pipeline = self.policy
        if skip_record_tiers:
            # Record-level quarantine: the stored/measured config for this
            # bucket faulted — resolve among the non-db tiers only.
            pipeline = tuple(
                p for p in pipeline if p.name not in ("exact", "tune", "cover")
            )
        res: Optional[Resolution] = None
        for pol in pipeline:
            res = pol.resolve(req)
            if res is not None:
                break
        if res is None:
            # An exhausted custom pipeline falls back to reference execution.
            res = Resolution(None, "reference")
        res.key = key
        if probe or skip_record_tiers:
            res.cache = False
            res.probe = probe
        elif self.guard and self.guard_nonfinite and res.config is not None:
            # First-resolve warmup probe: the guarded dispatch validates this
            # execution's output; the cached copy is a plain resolution.
            res = dataclasses.replace(res, probe=True)
        if res.cache:
            self._cache_put(key, db, dataclasses.replace(res, probe=False))
        self.telemetry.record(tunable.name, key, res.tier)
        if col.enabled:
            # Per-tier resolution latency: a 'tune' row is a full search, an
            # 'exact' miss is one db lookup, a 'hit' is the cache fast path.
            col.observe(
                "dispatch.resolve_s", time.perf_counter() - t0,
                tier=res.tier, phase=_phase_ctx.get(), cached="miss",
            )
        return res

    # -- dispatch ------------------------------------------------------------
    def dispatch(self, tunable: Union[str, Tunable], *args,
                 config: Optional[Config] = None,
                 dp_dims: Optional[Dict[int, int]] = None, **kwargs):
        """Execute one tunable through this runtime's resolution chain.

        Reference mode wins over everything — including ``config=`` — just
        like the old ``ops.*`` wrappers: it is the escape hatch for hosts
        where the kernel cannot lower at all (multi-pod dry-runs), so an
        explicit config must not force a kernel there. In kernel mode,
        ``config=`` bypasses resolution (tier ``override``); otherwise the
        resolved config is bound as a kernel variant on the canonicalized
        arguments, and the :class:`Reference` tier executes the dispatch
        spec's reference fn on the *original* arguments.

        The kernel path is differentiable: with ``vjp="dispatch"`` and a
        declared backward plan (and ``bwd_dispatch`` enabled on this
        runtime), gradients are themselves dispatch sites resolved through
        this same chain under ``dispatch_phase("bwd")``; with
        ``vjp="reference"`` the bound variant's backward recomputes the
        reference implementation's VJP. ``dp_dims`` overrides local-shape
        keying per arg (backward sites with transposed operands).

        Under an enabled obs collector each dispatch runs inside a
        ``span("dispatch")`` (kernel + phase on the event), so resolution
        and execution cost shows up in the span tree; disabled collectors
        skip straight to the implementation — one branch, no span object.
        """
        col = _obs_collector()
        if col.enabled:
            name = tunable.name if isinstance(tunable, Tunable) else str(tunable)
            with _obs_span("dispatch", kernel=name, phase=_phase_ctx.get()):
                return self._dispatch_impl(tunable, args, config, dp_dims, kwargs)
        return self._dispatch_impl(tunable, args, config, dp_dims, kwargs)

    def _dispatch_impl(self, tunable, args, config, dp_dims, kwargs):
        tunable = _as_tunable(tunable)
        spec = tunable.dispatch or _DEFAULT_SPEC
        if not self.kernel_mode_active:
            self.telemetry.record(tunable.name, None, "reference")
            return _reference_call(tunable, spec, args, kwargs)
        if _jvp_nesting(args) >= 2:
            # Second-order autodiff (`jax.grad(jax.grad(...))`): the outer
            # linearization re-traces the inner custom_vjp's forward with
            # JVP tangents attached, and the raw Pallas call inside has no
            # JVP rule. Differentiate through the reference implementation
            # instead — first-order dispatch (depth 1) stays on the tuned
            # kernel path, so training never takes this branch.
            self.telemetry.record(tunable.name, None, "reference")
            return _reference_call(tunable, spec, args, kwargs)
        if config is not None:
            # Explicit config= stays unguarded: the caller pinned a variant
            # by hand (tests, benchmarks) and wants the real traceback.
            self.telemetry.record(tunable.name, None, "override")
            cargs, restore = spec.canon(args)
            return restore(_kernel_call(self, tunable, spec, config, cargs, kwargs))
        cargs, restore = spec.canon(args)
        res = self.resolve(tunable, cargs, key_extra=spec.extra_for(kwargs),
                           dp_dims=dp_dims)
        if res.config is None:
            return _reference_call(tunable, spec, args, kwargs)
        if not self.guard:
            _fault_point(f"dispatch.kernel:{tunable.name}", tier=res.tier)
            return restore(_kernel_call(self, tunable, spec, res.config, cargs, kwargs))
        return self._guarded_call(tunable, spec, res, args, cargs, restore, kwargs)

    def _guarded_call(self, tunable, spec, res, args, cargs, restore, kwargs):
        """Execute a resolved kernel variant behind the fault guard.

        On exception (or a failed non-finite probe) the bucket's db key is
        quarantined in the health book and execution falls through the
        remaining tiers — heuristic config first (when the faulting tier was
        a stored/measured record and the heuristic differs), reference
        terminally — so a miscompiled variant or poisoned record degrades a
        site instead of taking down the run. Exceptions at trace time are
        caught the same as concrete-execution ones (dispatch under jit runs
        at trace time); KeyboardInterrupt/SystemExit still propagate. The
        fall-through execution records an extra telemetry row under the
        tier that actually served, so a gate can see both the resolution
        and the degradation.
        """
        key = res.key
        try:
            rule = _fault_point(f"dispatch.kernel:{tunable.name}", tier=res.tier)
            out = _kernel_call(self, tunable, spec, res.config, cargs, kwargs)
            if rule is not None and rule.kind == "nan":
                out = _nan_corrupt(out)
            if res.probe:
                if self.guard_nonfinite and _has_nonfinite(out):
                    raise DispatchFault(
                        f"non-finite output from {tunable.name} during "
                        "first-resolve probe"
                    )
                self.health.record_ok(key)
            return restore(out)
        except Exception as e:
            level = (
                "record" if res.tier in ("exact", "tune", "cover") else "kernel"
            )
            self._note_quarantine(tunable, key, res.tier, level, e)
        if level == "record":
            hcfg = tunable.default_config(*cargs)
            if hcfg != res.config:
                try:
                    _fault_point(f"dispatch.kernel:{tunable.name}", tier="heuristic")
                    out = _kernel_call(self, tunable, spec, hcfg, cargs, kwargs)
                    self.telemetry.record(tunable.name, key, "heuristic")
                    return restore(out)
                except Exception as e2:
                    self._note_quarantine(tunable, key, "heuristic", "kernel", e2)
            else:
                # The heuristic IS the faulting config; retrying is pointless.
                self.health.quarantine(key, "kernel")
        self.telemetry.record(tunable.name, key, "reference")
        return _reference_call(tunable, spec, args, kwargs)

    def _note_quarantine(self, tunable, key, tier, level, exc) -> None:
        self.health.quarantine(key, level)
        self._cache_evict(key)
        col = _obs_collector()
        if col.enabled:
            col.counter(
                "dispatch.quarantine", kernel=tunable.name, tier=tier, level=level
            )
        # Fires even when metric collection is off: a silently-degraded site
        # is exactly the hazard warn_once exists for.
        col.warn_once(
            "dispatch.quarantine", key=f"{key}|{level}", kernel=tunable.name,
            tier=tier, level=level, error=f"{type(exc).__name__}: {exc}",
        )

    # -- fusion policy -------------------------------------------------------
    def fusion_wins(self, tunable: Union[str, Tunable], *args, **kwargs) -> bool:
        """Whether a fused-epilogue site should dispatch *fused* here.

        The resolution-policy hook behind ``kernels/fused.py``: a model
        layer asks "does the database say fusion wins for this call?"
        before routing through a fused tunable instead of its unfused
        ops. True iff the kernel path is active AND the active database
        holds an exact record (with a still-valid config) for the
        canonicalized call — i.e. the fused site would resolve ExactHit.
        A campaign that measured the fused variant as a win banks that
        record; sites it never tuned (or where fusion lost and the job
        was dropped) keep their unfused dispatch chain, so e2e ExactHit
        coverage is invariant under this hook. Pure lookup: no telemetry
        rows, no cache mutation, no tuning.
        """
        if not self.kernel_mode_active:
            return False
        from .tuner import _args_key  # late: tuner imports this module's deps

        try:
            tunable = _as_tunable(tunable)
        except KeyError:
            return False
        spec = tunable.dispatch or _DEFAULT_SPEC
        cargs, _ = spec.canon(args)
        db = self.db if self.db is not None else default_db()
        platform = self.platform or _platform()
        key = _args_key(tunable, cargs, platform, spec.extra_for(kwargs))
        rec = db.lookup(key)
        return rec is not None and tunable.space.is_valid(rec.config)

    def __repr__(self) -> str:
        db = "default" if self.db is None else (self.db.path or "memory")
        plat = self.platform or "detected"
        return (
            f"<TunedRuntime {self.name} mode={self.mode} db={db} "
            f"platform={plat} policy=({', '.join(p.name for p in self.policy)})>"
        )


_DEFAULT_SPEC = DispatchSpec()


def _kernel_call(runtime: "TunedRuntime", tunable: Tunable, spec: DispatchSpec,
                 config: Config, cargs: tuple, kwargs: Dict[str, Any]):
    """Execute one bound kernel variant on canonical args, trainably.

    Pallas kernels have no transpose rules, so a bare variant inside
    ``jax.grad`` fails. Three backward strategies, per ``spec.vjp``:

    * ``"dispatch"`` (with a declared ``spec.bwd`` and the runtime's
      ``bwd_dispatch`` enabled) — the variant is wrapped in a
      ``jax.custom_vjp`` whose backward executes the spec's backward plan
      under ``dispatch_phase("bwd")``: each gradient is a dispatch site of
      its own, resolved through the active runtime's policy pipeline with
      its own database key and telemetry row. The tuned backward plane.
    * ``"reference"`` — backward runs the VJP of the reference
      implementation on the same (canonical) arguments: mathematically the
      reference gradient, at the cost of one reference recompute (the
      fwd-only-tuned baseline; also the fallback when a dispatch-vjp
      tunable runs under ``bwd_dispatch=False``).
    * ``"none"`` — the bare variant (backward-plane tunables themselves).

    The *residual contract* (``spec.residuals > 0``) threads forward
    intermediates into the backward plan: the variant returns
    ``(primal, *aux)``; ``fwd`` saves ``(args, primal, aux)`` as the
    ``custom_vjp`` residuals; the plan is called
    ``bwd(ct, *args, primal, *aux, **kwargs)``; the caller only ever sees
    the primal. With ``vjp="reference"`` the aux outputs are simply
    discarded (the reference VJP recomputes everything, as before).
    """
    import jax

    variant = tunable.variant(**config)
    ref = spec.reference_for(tunable)
    n_res = spec.residuals
    mode = spec.vjp
    if mode == "dispatch" and (spec.bwd is None or not runtime.bwd_dispatch):
        mode = "reference"
    if mode == "none" or (mode == "reference" and ref is None):
        out = variant(*cargs, **kwargs)
        return out[0] if n_res else out

    # kwargs (eps/causal/window/...) are schedule-or-semantics flags, never
    # differentiated: bind them by closure so custom_vjp sees arrays only.
    @jax.custom_vjp
    def run(*a):
        out = variant(*a, **kwargs)
        return out[0] if n_res else out

    def fwd(*a):
        out = variant(*a, **kwargs)
        if n_res:
            return out[0], (a, out[0], tuple(out[1:]))
        return out, (a, None, ())

    if mode == "dispatch":
        def bwd(res, ct):
            a, primal, aux = res
            with dispatch_phase("bwd"):
                if n_res:
                    grads = spec.bwd(ct, *a, primal, *aux, **kwargs)
                else:
                    grads = spec.bwd(ct, *a, **kwargs)
            return _match_cotangents(grads, a)
    else:
        def bwd(res, ct):
            a, _, _ = res
            return jax.vjp(lambda *p: ref(*p, **kwargs), *a)[1](ct)

    run.defvjp(fwd, bwd)
    try:
        return run(*cargs)
    except TypeError as e:
        if "forward-mode" not in str(e):
            raise
        # `jax.jvp` / `jax.linearize` over a dispatch site: custom_vjp has
        # no forward-mode rule. Fall back to the reference implementation
        # (jvp-able jnp math) on the canonical args — the caller's restore
        # still applies to our return value.
        runtime.telemetry.record(tunable.name, None, "reference")
        return ref(*cargs, **kwargs)


def _jvp_nesting(args) -> int:
    """Depth of forward-mode (JVP) tracer nesting across ``args``.

    ``jax.grad`` linearizes through one JVP trace (depth 1 — the depth
    ``custom_vjp`` handles); ``jax.grad(jax.grad(...))`` stacks a second
    (depth 2 — the depth it cannot). Walking ``.primal`` is cheap and
    version-stable: a ``JVPTracer``'s primal is the tracer of the
    enclosing trace.
    """
    from jax.interpreters import ad

    deepest = 0
    for x in args:
        d = 0
        while isinstance(x, ad.JVPTracer) and d < 8:
            d += 1
            x = x.primal
        if d > deepest:
            deepest = d
    return deepest


def _match_cotangents(grads, primals) -> tuple:
    """Align a backward plan's outputs with custom_vjp's cotangent contract.

    The plan returns one gradient per canonical primal, ``None`` for
    non-differentiable args. JAX expects a ``float0`` cotangent for integer
    primals (labels and the like) and the primal's dtype for inexact ones.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    grads = tuple(grads)
    if len(grads) != len(primals):
        raise ValueError(
            f"backward plan returned {len(grads)} gradients for "
            f"{len(primals)} primals"
        )
    out = []
    for g, x in zip(grads, primals):
        dtype = jnp.result_type(x)
        if not jnp.issubdtype(dtype, jnp.inexact):
            out.append(np.zeros(np.shape(x), jax.dtypes.float0))
        elif g is None:
            out.append(jnp.zeros(jnp.shape(x), dtype))
        else:
            out.append(g.astype(dtype))
    return tuple(out)


def _has_nonfinite(out) -> bool:
    """True when a *concrete* output contains NaN/inf float values.

    Traced outputs (dispatch under jit) are unobservable here and count as
    finite — the probe is a warmup-time check, not a jit-time one.
    """
    import jax
    import numpy as np

    for leaf in jax.tree_util.tree_leaves(out):
        if isinstance(leaf, jax.core.Tracer):
            return False
        try:
            a = np.asarray(leaf)
        except Exception:
            continue
        if a.dtype.kind == "f" and not np.isfinite(a).all():
            return True
    return False


def _nan_corrupt(out):
    """Replace concrete float outputs with NaNs (fault kind="nan")."""
    import jax
    import jax.numpy as jnp

    def corrupt(x):
        if isinstance(x, jax.core.Tracer) or not hasattr(x, "dtype"):
            return x
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.full_like(x, jnp.nan)
        return x

    return jax.tree_util.tree_map(corrupt, out)


def _reference_call(tunable: Tunable, spec: DispatchSpec, args, kwargs):
    ref = spec.reference_for(tunable)
    if ref is None:
        raise TypeError(
            f"tunable {tunable.name!r} has no reference implementation to "
            "dispatch to in reference mode; declare one via @tunable("
            "reference=...) or DispatchSpec(reference=...)"
        )
    return ref(*args, **kwargs)


def _as_tunable(t: Union[str, Tunable]) -> Tunable:
    if isinstance(t, Tunable):
        return t
    try:
        return get_tunable(t)
    except KeyError:
        ensure_registered()
        return get_tunable(t)


def ensure_registered() -> None:
    """Import the modules whose @tunable decorators populate the registry.

    This is the ONE list of tunable-bearing modules (the campaign planner's
    ``_register_tunables`` delegates here) — extend it when a new module
    grows ``@tunable`` sites. The upward imports are deliberately lazy:
    they run at first dispatch-by-name, never at ``repro.core`` import.
    """
    from .. import kernels  # noqa: F401
    from ..models import tunables  # noqa: F401


# ---------------------------------------------------------------------------
# Module-level API (what `repro` re-exports)
# ---------------------------------------------------------------------------


def _root_runtime() -> TunedRuntime:
    global _root
    if _root is None:
        with _root_lock:
            if _root is None:
                _root = TunedRuntime(
                    db=None, mode="auto", policy=None, allow_tune=False,
                    tune_kwargs=None, name="default", _is_root=True,
                )
    return _root


def current_runtime() -> TunedRuntime:
    """The innermost active runtime, or the process-default one."""
    s = _stack.get()
    return s[-1] if s else _root_runtime()


def runtime(
    db: Union[TuningDatabase, None, object] = _INHERIT,
    mode: Union[str, object] = _INHERIT,
    policy: Union[Sequence[ResolutionPolicy], None, object] = _INHERIT,
    allow_tune: Union[bool, object] = _INHERIT,
    tune_kwargs: Union[Dict[str, Any], None, object] = _INHERIT,
    platform: Union[str, None, object] = _INHERIT,
    cache_capacity: Union[int, object] = _INHERIT,
    cache_ttl: Union[float, None, object] = _INHERIT,
    bwd_dispatch: Union[bool, object] = _INHERIT,
    guard: Union[bool, object] = _INHERIT,
    guard_nonfinite: Union[bool, object] = _INHERIT,
    name: str = "",
) -> TunedRuntime:
    """Create a scoped dispatch runtime (use as ``with repro.runtime(...)``)."""
    return TunedRuntime(
        db=db, mode=mode, policy=policy, allow_tune=allow_tune,
        tune_kwargs=tune_kwargs, platform=platform,
        cache_capacity=cache_capacity, cache_ttl=cache_ttl,
        bwd_dispatch=bwd_dispatch, guard=guard,
        guard_nonfinite=guard_nonfinite, name=name,
    )


def dispatch(tunable: Union[str, Tunable], *args,
             config: Optional[Config] = None, **kwargs):
    """Dispatch through whichever runtime is active at the call."""
    return current_runtime().dispatch(tunable, *args, config=config, **kwargs)


def fusion_wins(tunable: Union[str, Tunable], *args, **kwargs) -> bool:
    """Whether the active runtime's database says fusion wins here.

    See :meth:`TunedRuntime.fusion_wins` — the resolution-policy hook the
    model layer consults before routing a site through a fused-epilogue
    tunable (``matmul_bias_act`` / ``rmsnorm_matmul``) instead of its
    unfused dispatch chain.
    """
    return current_runtime().fusion_wins(tunable, *args, **kwargs)


def entry_point(name: str) -> Callable:
    """An auto-generated deployment entry point for a registered tunable.

    The returned callable has the old ``ops.<kernel>`` contract —
    ``fn(*args, config=None, **call_kwargs)`` — and routes through
    :func:`current_runtime`, so it honours whatever scope is active where
    it is *called*, not where it was created.
    """

    def call(*args, config: Optional[Config] = None, **kwargs):
        return current_runtime().dispatch(name, *args, config=config, **kwargs)

    call.__name__ = name
    call.__qualname__ = name
    call.__doc__ = (
        f"Registry-dispatched deployment entry point for tunable {name!r} "
        "(resolution: the active TunedRuntime's policy pipeline)."
    )
    return call
