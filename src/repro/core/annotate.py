"""The annotation layer — `@tunable` is our "pragma".

In the paper, a single-line comment annotation turns a plain loop into a
tuning site without changing program semantics. Here, decorating a function
with :func:`tunable` declares its knob space and default config; the function
itself *is* the transformation: it must accept the knobs as keyword-only
arguments and produce the same math for every valid config. Undecorated
callers see the default config, so — exactly as in the paper — the annotated
program still runs as the reference implementation.

    @tunable("matmul", space=ParamSpace([...]), reference=ref.matmul)
    def matmul(x, w, *, bm, bn, bk): ...

    matmul(x, w)                  # default config (the 'unannotated' program)
    matmul.variant(bm=128, ...)   # one concrete variant (a transformed code)
    matmul.tune(x, w)             # run the autotuner -> best variant
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence

from .params import Config, ParamSpace

_REGISTRY: Dict[str, "Tunable"] = {}


class Tunable:
    def __init__(
        self,
        name: str,
        fn: Callable,
        space: ParamSpace,
        reference: Optional[Callable] = None,
        default: Optional[Config] = None,
        heuristic: Optional[Callable[..., Config]] = None,
    ):
        self.name = name
        self.fn = fn
        self.space = space
        self.reference = reference
        self._default = default
        # Shape-aware default: maps concrete args -> a good starting config
        # (the 'vendor library' baseline the tuner must beat).
        self.heuristic = heuristic
        functools.update_wrapper(self, fn)

    # -- variants -------------------------------------------------------------
    def default_config(self, *args) -> Config:
        if self.heuristic is not None and args:
            cfg = self.heuristic(*args)
            if self.space.is_valid(cfg):
                return cfg
        if self._default is not None:
            return dict(self._default)
        return self.space.default()

    def variant(self, **config) -> Callable:
        """Bind one concrete config — a 'code variant' in the paper's terms."""
        why = self.space.why_invalid(config)
        if why is not None:
            raise ValueError(f"invalid config for {self.name}: {why}")
        return functools.partial(self.fn, **config)

    def __call__(self, *args, **overrides):
        cfg = self.default_config(*args)
        cfg.update(overrides)
        return self.fn(*args, **cfg)

    # -- tuning ----------------------------------------------------------------
    def tune(self, *args, **kwargs):
        from .tuner import autotune  # late import: tuner imports annotate

        return autotune(self, args, **kwargs)

    def __repr__(self) -> str:
        return f"<tunable {self.name} over {self.space!r}>"


def tunable(
    name: str,
    space: ParamSpace,
    reference: Optional[Callable] = None,
    default: Optional[Config] = None,
    heuristic: Optional[Callable[..., Config]] = None,
) -> Callable[[Callable], Tunable]:
    def deco(fn: Callable) -> Tunable:
        t = Tunable(name, fn, space, reference, default, heuristic)
        _REGISTRY[name] = t
        return t

    return deco


def get_tunable(name: str) -> Tunable:
    return _REGISTRY[name]


def registered() -> Dict[str, Tunable]:
    return dict(_REGISTRY)
