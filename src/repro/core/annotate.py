"""The annotation layer — `@tunable` is our "pragma".

In the paper, a single-line comment annotation turns a plain loop into a
tuning site without changing program semantics. Here, decorating a function
with :func:`tunable` declares its knob space and default config; the function
itself *is* the transformation: it must accept the knobs as keyword-only
arguments and produce the same math for every valid config. Undecorated
callers see the default config, so — exactly as in the paper — the annotated
program still runs as the reference implementation.

    @tunable("matmul", space=ParamSpace([...]), reference=ref.matmul)
    def matmul(x, w, *, bm, bn, bk): ...

    matmul(x, w)                  # default config (the 'unannotated' program)
    matmul.variant(bm=128, ...)   # one concrete variant (a transformed code)
    matmul.tune(x, w)             # run the autotuner -> best variant

Deployment is declared here too: the optional ``dispatch=DispatchSpec(...)``
argument tells the dispatch runtime (:mod:`repro.core.runtime`) everything it
needs to auto-generate a deployment entry point — which reference fn backs
the kernel, how to derive the database ``key_extra`` from call kwargs, and
how to canonicalize arguments (e.g. rmsnorm's flatten-to-2D/reshape-back).
A new kernel therefore needs exactly one decorator: no hand-written wrapper
in ``kernels/ops.py``, no planner or serving edits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from .params import Config, ParamSpace

_REGISTRY: Dict[str, "Tunable"] = {}


@dataclasses.dataclass(frozen=True)
class DispatchSpec:
    """Declarative deployment spec for one tunable.

    The dispatch runtime consumes this to build the kernel's deployment
    entry point; every field is optional:

    * ``reference`` — the fallback / reference-mode implementation, called as
      ``reference(*args, **call_kwargs)``. Defaults to the tunable's tuning
      reference (``Tunable.reference``). Always primal-only: a tunable with
      ``residuals > 0`` has a residual-emitting *tuning* reference (so the
      correctness gate compares like structure), and must set this field to
      the plain oracle explicitly.
    * ``key_extra`` — maps the *call kwargs* to the database key suffix
      (e.g. flash attention's ``f"c{causal}w{window}"``), so semantically
      different calls with identical shapes get distinct records.
    * ``canonicalize`` — ``(*args) -> (canon_args, restore)``: rewrites the
      positional args into the layout the kernel (and its db keys) expect,
      plus a function applied to the kernel output to undo the rewrite.
      rmsnorm uses this to flatten ``[..., d] -> [rows, d]`` and reshape
      back. The reference path always sees the *original* args.
    * ``example`` — ``() -> (args, kwargs)``: small representative arguments
      (interpret-mode friendly) used by the registry parity tests and the
      dispatch-overhead benchmark, so coverage of a new kernel is automatic.
    * ``data_parallel_args`` — indices of the *canonical* positional args
      whose leading dim is batch/token-like. Under an active sharded
      ``mesh_context`` the runtime keys the database on the per-device
      *local* shard of those dims (global dim ÷ data-parallel degree), so
      campaign records tuned at local shard shapes exact-hit inside
      jit-sharded traces. Default ``(0,)`` (the row-major convention);
      ``()`` disables localization for a kernel.
    * ``vjp`` — how dispatch differentiates the kernel path.

      * ``"dispatch"`` — the backward pass is itself a set of dispatch
        sites: the bound variant is wrapped in a ``jax.custom_vjp`` whose
        backward calls ``bwd(ct, *canonical_args, **call_kwargs)``, and the
        ``bwd`` callable routes each gradient through the runtime
        (``dispatch(...)`` on the same or a sibling tunable). Every
        backward call then resolves through the policy pipeline with its
        own database key and telemetry rows (phase-tagged ``bwd``), so a
        campaign can pre-tune gradients exactly like forwards. Falls back
        to ``"reference"`` behaviour when the runtime disables backward
        dispatch (``bwd_dispatch=False``) or no ``bwd`` is declared.
      * ``"reference"`` — wraps the bound variant in a ``jax.custom_vjp``
        whose backward pass is the VJP of the reference implementation, so
        tuned kernels are trainable even when the Pallas kernel itself has
        no transpose rule (forward stays the tuned kernel; backward
        recomputes through the reference math).
      * ``"none"`` — leaves the variant bare (for tunables that are never
        differentiated at all). Backward-plane tunables use ``"reference"``
        instead, so ``jax.grad``-of-``jax.grad`` can differentiate *through*
        a dispatched gradient site; the runtime additionally routes any
        dispatch under second-order JVP nesting straight to the reference
        implementation (``custom_vjp`` has no forward-mode rule).
    * ``bwd`` — the backward dispatch plan for ``vjp="dispatch"``: called
      as ``bwd(ct, *canonical_args, **call_kwargs)`` — or, with
      ``residuals > 0``, as ``bwd(ct, *canonical_args, primal_out,
      *aux, **call_kwargs)`` — returns one cotangent per canonical
      positional arg (``None`` for non-differentiable args — integer
      labels and the like).
    * ``residuals`` — the *residual contract*: when > 0, the bound variant
      (and the tuning reference) returns ``(primal, *aux)`` with exactly
      this many auxiliary outputs — forward intermediates the backward
      pass would otherwise recompute (flash attention's lse, rmsnorm's
      inv-rms, softmax-xent's lse). Dispatch saves them into the
      ``custom_vjp`` residuals alongside the canonical args and the
      primal output, and hands all three to the backward plan; callers
      only ever see the primal. Residuals stay *canonical* (the
      ``canonicalize`` restore applies to the primal alone).
    * ``bwd_via`` — the registered tunable names the backward plan
      dispatches, for plans that decompose into *other* kernels' sites
      (the fused-epilogue tunables lower their gradients onto plain
      ``matmul`` / ``rmsnorm_bwd`` records rather than a dedicated
      ``*_bwd`` sibling). The analysis contracts pass verifies these
      against the plan's source instead of requiring a same-name sibling.
    """

    reference: Optional[Callable] = None
    key_extra: Optional[Callable[[Dict[str, Any]], str]] = None
    canonicalize: Optional[Callable[..., Tuple[tuple, Callable]]] = None
    example: Optional[Callable[[], Tuple[tuple, Dict[str, Any]]]] = None
    data_parallel_args: Tuple[int, ...] = (0,)
    vjp: str = "reference"
    bwd: Optional[Callable] = None
    residuals: int = 0
    bwd_via: Tuple[str, ...] = ()

    def reference_for(self, tunable: "Tunable") -> Optional[Callable]:
        return self.reference if self.reference is not None else tunable.reference

    def extra_for(self, call_kwargs: Dict[str, Any]) -> str:
        return self.key_extra(call_kwargs) if self.key_extra else ""

    def canon(self, args: tuple) -> Tuple[tuple, Callable]:
        if self.canonicalize is None:
            return args, lambda out: out
        return self.canonicalize(*args)


class Tunable:
    def __init__(
        self,
        name: str,
        fn: Callable,
        space: ParamSpace,
        reference: Optional[Callable] = None,
        default: Optional[Config] = None,
        heuristic: Optional[Callable[..., Config]] = None,
        dispatch: Optional[DispatchSpec] = None,
    ):
        self.name = name
        self.fn = fn
        self.space = space
        self.reference = reference
        self._default = default
        # Shape-aware default: maps concrete args -> a good starting config
        # (the 'vendor library' baseline the tuner must beat).
        self.heuristic = heuristic
        # Deployment declaration consumed by repro.core.runtime (None means
        # dispatch with defaults: tuning reference, no key_extra, identity
        # canonicalization).
        self.dispatch = dispatch
        functools.update_wrapper(self, fn)

    # -- variants -------------------------------------------------------------
    def default_config(self, *args) -> Config:
        if self.heuristic is not None and args:
            cfg = self.heuristic(*args)
            if self.space.is_valid(cfg):
                return cfg
        if self._default is not None:
            return dict(self._default)
        return self.space.default()

    def variant(self, **config) -> Callable:
        """Bind one concrete config — a 'code variant' in the paper's terms."""
        why = self.space.why_invalid(config)
        if why is not None:
            raise ValueError(f"invalid config for {self.name}: {why}")
        return functools.partial(self.fn, **config)

    def __call__(self, *args, **overrides):
        """Run with the default config, plus validated knob overrides.

        Knob overrides (keys in the space) are merged into the default config
        and the result is validated via ``space.why_invalid`` — an off-domain
        or constraint-violating override raises with the reason, matching
        :meth:`variant`. Non-knob kwargs (``eps``, ``causal``, ``interpret``,
        ...) pass through to the implementation untouched.
        """
        cfg = self.default_config(*args)
        knobs = set(self.space.names)
        passthrough = {k: v for k, v in overrides.items() if k not in knobs}
        cfg.update({k: v for k, v in overrides.items() if k in knobs})
        why = self.space.why_invalid(cfg)
        if why is not None:
            raise ValueError(f"invalid config for {self.name}: {why}")
        return self.fn(*args, **cfg, **passthrough)

    # -- tuning ----------------------------------------------------------------
    def tune(self, *args, **kwargs):
        from .tuner import autotune  # late import: tuner imports annotate

        return autotune(self, args, **kwargs)

    def __repr__(self) -> str:
        return f"<tunable {self.name} over {self.space!r}>"


def tunable(
    name: str,
    space: ParamSpace,
    reference: Optional[Callable] = None,
    default: Optional[Config] = None,
    heuristic: Optional[Callable[..., Config]] = None,
    dispatch: Optional[DispatchSpec] = None,
) -> Callable[[Callable], Tunable]:
    def deco(fn: Callable) -> Tunable:
        t = Tunable(name, fn, space, reference, default, heuristic, dispatch)
        _REGISTRY[name] = t
        return t

    return deco


def get_tunable(name: str) -> Tunable:
    return _REGISTRY[name]


def registered() -> Dict[str, Tunable]:
    return dict(_REGISTRY)
